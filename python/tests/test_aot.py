"""AOT manifest / artifact contract tests.

The manifest is the ABI the Rust coordinator builds against; these tests
pin the parts Rust assumes.
"""

import json
import os
import re

import numpy as np
import pytest

from compile import config as C
from compile.aot import EXEC_META, build_specs, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def test_build_specs_shapes_consistent():
    for name, model, fn, args, insig, outsig in build_specs():
        assert len(args) == len(insig), name
        for a, s in zip(args, insig):
            assert tuple(a.shape) == tuple(s["shape"]), (name, s["name"])
            want = {"f32": np.float32, "i32": np.int32}[s["dtype"]]
            assert a.dtype == want, (name, s["name"])


def test_exec_names_unique():
    names = [s[0] for s in build_specs()]
    assert len(names) == len(set(names))
    # the full planned set
    for required in ("prefill_pallas", "prefill_xla", "decode_pallas",
                     "decode_xla", "ar_prefill", "ar_step", "ar_verify",
                     "train_diff", "train_ar", "trajectory",
                     "draft_ar_prefill", "draft_ar_step", "draft_train_ar",
                     "decode_paged_pallas", "decode_paged_xla",
                     "prefill_batch", "decode_paged_batch",
                     "train_diff_fused", "trajectory_paged"):
        assert required in names, required


def test_exec_meta_geometry():
    """The batched/paged ABI fields the v2 manifest records."""
    assert EXEC_META["prefill_batch"]["batch"] == C.B_DECODE
    assert EXEC_META["decode_paged_batch"]["batch"] == C.B_DECODE
    assert EXEC_META["train_diff_fused"]["batch"] == C.TRAIN_CHUNK
    for name in ("decode_paged_pallas", "decode_paged_xla",
                 "decode_paged_batch"):
        paged = EXEC_META[name]["paged"]
        assert paged == {"page_rows": C.PAGE_ROWS, "max_pages": C.MAX_PAGES}
    assert C.PAGE_ROWS * C.MAX_PAGES == C.S_MAX
    # every meta name must exist as a spec
    names = {s[0] for s in build_specs()}
    assert set(EXEC_META) <= names


# ---- HLO signature goldens for the batched + paged specs: the lowered
#      entry computation must expose exactly the manifest signature
#      (argument order, shapes, dtypes) the Rust loader validates against.

_HLO_GOLDEN_NAMES = ("decode_paged_xla", "prefill_batch",
                     "decode_paged_batch")

_TY = {"f32": "f32", "i32": "s32"}  # manifest dtype -> HLO element type


def _hlo_entry_types(text):
    """(param_types, result_types) of the ENTRY computation, e.g. f32[3,4].

    The HLO text emitter writes the signature as parameter instructions
    plus a ROOT tuple inside the ENTRY block; layouts ({1,0}) are
    stripped.
    """
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    params, res = {}, None
    for l in lines[start + 1:]:
        if l.startswith("}"):
            break
        m = re.match(r"\s*\S+ = (\S+) parameter\((\d+)\)", l)
        if m:
            params[int(m.group(2))] = re.sub(r"\{[^}]*\}", "", m.group(1))
        m = re.match(r"\s*ROOT \S+ = \((?P<tys>.*?)\) tuple\(", l)
        if m:
            res = [re.sub(r"\{[^}]*\}", "", t)
                   for t in m.group("tys").split(", ")]
    assert res is not None and sorted(params) == list(range(len(params)))
    return [params[i] for i in range(len(params))], res


def _sig_type(s):
    dims = ",".join(str(d) for d in s["shape"])
    return f"{_TY[s['dtype']]}[{dims}]"


@pytest.mark.parametrize("name", _HLO_GOLDEN_NAMES)
def test_hlo_signature_golden(name):
    import jax
    spec = next(s for s in build_specs() if s[0] == name)
    _, _, fn, args, insig, outsig = spec
    text = to_hlo_text(jax.jit(fn).lower(*args))
    params, res = _hlo_entry_types(text)
    assert params == [_sig_type(s) for s in insig], name
    assert res == [_sig_type(s) for s in outsig], name


@needs_artifacts
def test_manifest_matches_config():
    m = json.load(open(MANIFEST))
    c = m["constants"]
    assert c["vocab"] == C.VOCAB
    assert c["mask_id"] == C.MASK_ID
    assert c["s_max"] == C.S_MAX
    assert c["window"] == C.WINDOW
    assert c["block"] == C.BLOCK
    assert c["gen_max"] == C.GEN_MAX
    for mname, arch in (("main", C.MAIN), ("draft", C.DRAFT)):
        layout, total = C.param_layout(arch)
        md = m["models"][mname]
        assert md["total_params"] == total
        assert md["param_layout"] == layout


@needs_artifacts
def test_manifest_files_exist_with_digests():
    import hashlib
    m = json.load(open(MANIFEST))
    for e in m["executables"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["name"]
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()[:16]
        assert digest == e["sha256_16"], e["name"]
        # HLO text, parseable header
        head = open(path).read(200)
        assert "HloModule" in head, e["name"]


@needs_artifacts
def test_manifest_signatures_match_specs():
    m = json.load(open(MANIFEST))
    by_name = {e["name"]: e for e in m["executables"]}
    for name, model, fn, args, insig, outsig in build_specs():
        e = by_name[name]
        assert e["model"] == model
        assert e["inputs"] == insig, name
        assert e["outputs"] == outsig, name
