"""AOT manifest / artifact contract tests.

The manifest is the ABI the Rust coordinator builds against; these tests
pin the parts Rust assumes.
"""

import json
import os

import numpy as np
import pytest

from compile import config as C
from compile.aot import build_specs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def test_build_specs_shapes_consistent():
    for name, model, fn, args, insig, outsig in build_specs():
        assert len(args) == len(insig), name
        for a, s in zip(args, insig):
            assert tuple(a.shape) == tuple(s["shape"]), (name, s["name"])
            want = {"f32": np.float32, "i32": np.int32}[s["dtype"]]
            assert a.dtype == want, (name, s["name"])


def test_exec_names_unique():
    names = [s[0] for s in build_specs()]
    assert len(names) == len(set(names))
    # the full planned set
    for required in ("prefill_pallas", "prefill_xla", "decode_pallas",
                     "decode_xla", "ar_prefill", "ar_step", "ar_verify",
                     "train_diff", "train_ar", "trajectory",
                     "draft_ar_prefill", "draft_ar_step", "draft_train_ar"):
        assert required in names, required


@needs_artifacts
def test_manifest_matches_config():
    m = json.load(open(MANIFEST))
    c = m["constants"]
    assert c["vocab"] == C.VOCAB
    assert c["mask_id"] == C.MASK_ID
    assert c["s_max"] == C.S_MAX
    assert c["window"] == C.WINDOW
    assert c["block"] == C.BLOCK
    assert c["gen_max"] == C.GEN_MAX
    for mname, arch in (("main", C.MAIN), ("draft", C.DRAFT)):
        layout, total = C.param_layout(arch)
        md = m["models"][mname]
        assert md["total_params"] == total
        assert md["param_layout"] == layout


@needs_artifacts
def test_manifest_files_exist_with_digests():
    import hashlib
    m = json.load(open(MANIFEST))
    for e in m["executables"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["name"]
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()[:16]
        assert digest == e["sha256_16"], e["name"]
        # HLO text, parseable header
        head = open(path).read(200)
        assert "HloModule" in head, e["name"]


@needs_artifacts
def test_manifest_signatures_match_specs():
    m = json.load(open(MANIFEST))
    by_name = {e["name"]: e for e in m["executables"]}
    for name, model, fn, args, insig, outsig in build_specs():
        e = by_name[name]
        assert e["model"] == model
        assert e["inputs"] == insig, name
        assert e["outputs"] == outsig, name
