"""Paged-attention kernel + batched/fused builder tests.

Ground truth for the paged ABI: the packed-pages layout must be exactly
equivalent to the dense cache image it replaces (same math, permutation-
invariant over entries), the batched builders must match their B=1
singles row-for-row, and the fused train chunk must match sequential
steps. Everything runs in Pallas interpret mode (no device) — these are
the tests the CI python job executes.
"""

import numpy as np
import jax.numpy as jnp

from compile import config as C
from compile import model as M
from compile.kernels.paged_attention import paged_flash_attention
from compile.kernels.ref import attention_ref

NEG_INF = -1e30


def _rng(seed=0):
    return np.random.default_rng(seed)


def _paged_ref(q, k_pages, v_pages, page_index, page_valid, k_win, v_win,
               win_kmask):
    """Dense oracle: flatten pages, build the mask, run attention_ref."""
    h, mp, pr, dh = k_pages.shape
    w = q.shape[1]
    k_all = np.concatenate([k_pages.reshape(h, mp * pr, dh), k_win], axis=1)
    v_all = np.concatenate([v_pages.reshape(h, mp * pr, dh), v_win], axis=1)
    rows = np.arange(pr)[None, :]
    entry_ok = (page_index[:, None] >= 0) & (rows < page_valid[:, None])
    allowed = np.concatenate([entry_ok.reshape(mp * pr), win_kmask > 0.0])
    bias = np.where(allowed[None, :], 0.0, NEG_INF)
    bias = np.broadcast_to(bias, (w, mp * pr + w))
    return np.asarray(attention_ref(jnp.asarray(q), jnp.asarray(k_all),
                                    jnp.asarray(v_all), jnp.asarray(bias)))


def _random_case(rng, h=2, mp=4, pr=8, w=16, dh=8):
    q = rng.standard_normal((h, w, dh), dtype=np.float32)
    k_pages = rng.standard_normal((h, mp, pr, dh), dtype=np.float32)
    v_pages = rng.standard_normal((h, mp, pr, dh), dtype=np.float32)
    k_win = rng.standard_normal((h, w, dh), dtype=np.float32)
    v_win = rng.standard_normal((h, w, dh), dtype=np.float32)
    # entry 2 dead, entry 3 partially valid — the mask must come from the
    # page table, not from zeroed page contents
    page_index = np.array([0, 1, -1, 2], dtype=np.int32)[:mp]
    page_valid = np.array([pr, pr, 0, pr // 2], dtype=np.int32)[:mp]
    win_kmask = (rng.random(w) > 0.25).astype(np.float32)
    win_kmask[0] = 1.0  # at least one live key per query row
    return q, k_pages, v_pages, page_index, page_valid, k_win, v_win, win_kmask


def test_paged_kernel_matches_ref():
    args = _random_case(_rng(1))
    got = np.asarray(paged_flash_attention(
        *(jnp.asarray(a) for a in args), bq=8))
    want = _paged_ref(*args)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_paged_kernel_permutation_invariant():
    q, kp, vp, pidx, pval, kw, vw, wm = _random_case(_rng(2))
    base = np.asarray(paged_flash_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pidx),
        jnp.asarray(pval), jnp.asarray(kw), jnp.asarray(vw), jnp.asarray(wm),
        bq=8))
    perm = np.array([3, 1, 0, 2])
    shuffled = np.asarray(paged_flash_attention(
        jnp.asarray(q), jnp.asarray(kp[:, perm]), jnp.asarray(vp[:, perm]),
        jnp.asarray(pidx[perm]), jnp.asarray(pval[perm]), jnp.asarray(kw),
        jnp.asarray(vw), jnp.asarray(wm), bq=8))
    np.testing.assert_allclose(shuffled, base, atol=1e-5, rtol=1e-5)


TINY = C.Arch(name="tiny", d_model=16, n_layers=2, n_heads=2, d_head=8,
              d_ff=32, s_max=64)


def _tiny_params(rng, arch):
    _, total = C.param_layout(arch)
    return jnp.asarray(rng.standard_normal(total, dtype=np.float32) * 0.05)


def test_decode_paged_matches_dense_decode():
    """Identity page table over a dense cache == the dense decode exec."""
    rng = _rng(3)
    arch, seq, w, pr = TINY, 64, 16, 8
    mp = seq // pr
    L, DKV = arch.n_layers, arch.d_kv
    flat = _tiny_params(rng, arch)
    kcache = rng.standard_normal((L, seq, DKV), dtype=np.float32)
    vcache = rng.standard_normal((L, seq, DKV), dtype=np.float32)
    n_valid = 20  # partial final page
    cache_valid = (np.arange(seq) < n_valid).astype(np.float32)
    win_tokens = rng.integers(5, C.VOCAB, w).astype(np.int32)
    win_pos = (n_valid + np.arange(w)).astype(np.int32)
    win_valid = np.ones(w, dtype=np.float32)

    dense = M.make_decode(arch, "xla", w, seq)(
        flat, jnp.asarray(win_tokens), jnp.asarray(win_pos),
        jnp.asarray(win_valid), jnp.asarray(kcache), jnp.asarray(vcache),
        jnp.asarray(cache_valid))

    k_pages = kcache.reshape(L, mp, pr, DKV)
    v_pages = vcache.reshape(L, mp, pr, DKV)
    page_index = np.arange(mp, dtype=np.int32)
    page_valid = np.clip(n_valid - page_index * pr, 0, pr).astype(np.int32)
    paged = M.make_decode_paged(arch, "xla", w, pr, mp)(
        flat, jnp.asarray(win_tokens), jnp.asarray(win_pos),
        jnp.asarray(win_valid), jnp.asarray(k_pages),
        jnp.asarray(v_pages), jnp.asarray(page_index),
        jnp.asarray(page_valid))
    for d, p in zip(dense, paged):
        np.testing.assert_allclose(np.asarray(p), np.asarray(d),
                                   atol=1e-4, rtol=1e-4)

    # the pallas paged kernel must agree with the xla paged reference at
    # the forward level (the fused head has its own tiling constraints and
    # its own tests — here we pin the attention path)
    params = M.unflatten(flat, arch)
    h_args = (jnp.asarray(win_tokens), jnp.asarray(win_pos),
              jnp.asarray(k_pages), jnp.asarray(v_pages),
              jnp.asarray(page_index), jnp.asarray(page_valid),
              jnp.asarray(win_valid))
    ref = M.forward_window_paged(params, *h_args[:2], *h_args[2:6],
                                 h_args[6], arch, "xla")
    ker = M.forward_window_paged(params, *h_args[:2], *h_args[2:6],
                                 h_args[6], arch, "pallas")
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=1e-4, rtol=1e-4)


def test_decode_paged_batch_matches_single():
    rng = _rng(4)
    arch, seq, w, pr, bd = TINY, 64, 16, 8, 3
    mp = seq // pr
    L, DKV = arch.n_layers, arch.d_kv
    flat = _tiny_params(rng, arch)
    args = dict(
        win_tokens=rng.integers(5, C.VOCAB, (bd, w)).astype(np.int32),
        win_pos=np.tile(np.arange(w, dtype=np.int32), (bd, 1)),
        win_valid=np.ones((bd, w), dtype=np.float32),
        k_pages=rng.standard_normal((bd, L, mp, pr, DKV), dtype=np.float32),
        v_pages=rng.standard_normal((bd, L, mp, pr, DKV), dtype=np.float32),
        page_index=np.tile(np.arange(mp, dtype=np.int32), (bd, 1)),
        page_valid=np.full((bd, mp), pr, dtype=np.int32),
    )
    batched = M.make_decode_paged_batch(arch, "xla", bd, w, pr, mp)(
        flat, *(jnp.asarray(v) for v in args.values()))
    single = M.make_decode_paged(arch, "xla", w, pr, mp)
    for b in range(bd):
        one = single(flat, *(jnp.asarray(v[b]) for v in args.values()))
        for sb, so in zip(batched, one):
            np.testing.assert_allclose(np.asarray(sb[b]), np.asarray(so),
                                       atol=1e-5, rtol=1e-5)


def test_prefill_batch_matches_single():
    rng = _rng(5)
    arch, seq, bd = TINY, 64, 3
    flat = _tiny_params(rng, arch)
    tokens = rng.integers(5, C.VOCAB, (bd, seq)).astype(np.int32)
    valid = (rng.random((bd, seq)) > 0.2).astype(np.float32)
    valid[:, 0] = 1.0
    batched = M.make_prefill_batch(arch, "xla", bd, seq)(
        flat, jnp.asarray(tokens), jnp.asarray(valid))
    single = M.make_prefill(arch, "xla", seq)
    for b in range(bd):
        one = single(flat, jnp.asarray(tokens[b]), jnp.asarray(valid[b]))
        for sb, so in zip(batched, one):
            np.testing.assert_allclose(np.asarray(sb[b]), np.asarray(so),
                                       atol=1e-5, rtol=1e-5)


def test_train_fused_matches_sequential_steps():
    rng = _rng(6)
    arch, chunk, b, seq = TINY, 2, 2, 32
    _, total = C.param_layout(arch)
    flat = _tiny_params(rng, arch)
    m = jnp.zeros(total)
    v = jnp.zeros(total)
    tokens = rng.integers(5, C.VOCAB, (chunk, b, seq)).astype(np.int32)
    labels = rng.integers(5, C.VOCAB, (chunk, b, seq)).astype(np.int32)
    loss_mask = np.ones((chunk, b, seq), dtype=np.float32)
    attn_valid = np.ones((chunk, b, seq), dtype=np.float32)
    lr, ent_w = jnp.float32(1e-3), jnp.float32(0.01)

    step_fn = M.make_train(arch, False, b, seq)
    f_seq, m_seq, v_seq = flat, m, v
    losses = []
    for k in range(chunk):
        f_seq, m_seq, v_seq, loss = step_fn(
            f_seq, m_seq, v_seq, jnp.int32(1 + k), jnp.asarray(tokens[k]),
            jnp.asarray(labels[k]), jnp.asarray(loss_mask[k]),
            jnp.asarray(attn_valid[k]), lr, ent_w)
        losses.append(float(loss))

    fused = M.make_train_fused(arch, False, chunk, b, seq)(
        flat, m, v, jnp.int32(1), jnp.asarray(tokens), jnp.asarray(labels),
        jnp.asarray(loss_mask), jnp.asarray(attn_valid), lr, ent_w)
    np.testing.assert_allclose(np.asarray(fused[0]), np.asarray(f_seq),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fused[3]), np.asarray(losses),
                               atol=1e-6, rtol=1e-6)


def test_trajectory_paged_contract():
    """One token unmasked per step, gen region only, ranks consistent."""
    rng = _rng(7)
    arch, bt, seq, steps = TINY, 2, 64, 32
    flat = _tiny_params(rng, arch)
    prompt_len = seq - steps
    tokens = rng.integers(5, C.VOCAB, (bt, seq)).astype(np.int32)
    tokens[:, prompt_len:] = C.MASK_ID
    attn_valid = np.ones((bt, seq), dtype=np.float32)
    gen_mask = np.zeros((bt, seq), dtype=np.float32)
    gen_mask[:, prompt_len:] = 1.0

    rank, final = M.make_trajectory_paged(arch, bt, seq, steps)(
        flat, jnp.asarray(tokens), jnp.asarray(attn_valid),
        jnp.asarray(gen_mask))
    rank, final = np.asarray(rank), np.asarray(final)
    assert rank.shape == (bt, seq) and final.shape == (bt, seq)
    # prompt positions never ranked, tokens untouched
    assert (rank[:, :prompt_len] == M.RANK_NEVER).all()
    assert (final[:, :prompt_len] == tokens[:, :prompt_len]).all()
    # exactly one unmask per step per row: gen ranks are a permutation
    for b in range(bt):
        gen_ranks = np.sort(rank[b, prompt_len:])
        np.testing.assert_array_equal(gen_ranks, np.arange(steps))
    # every unmasked position carries a real (non-MASK) token
    assert (final[:, prompt_len:] != C.MASK_ID).all()
