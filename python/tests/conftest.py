"""Make `compile.*` importable regardless of pytest's invocation cwd
(repo root in CI, `python/` locally)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
