"""L2 model invariants: serving-graph consistency, training, trajectory."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import config as C
from compile import model as M


@pytest.fixture(scope="module")
def flat():
    _, total = C.param_layout(C.MAIN)
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.normal(0.0, 0.02, total), jnp.float32)


def _prefill(flat, tokens, valid, variant="xla", seq=C.S_MAX):
    return jax.jit(M.make_prefill(C.MAIN, variant, seq))(flat, tokens, valid)


def test_param_layout_contiguous():
    for arch in (C.MAIN, C.DRAFT):
        layout, total = C.param_layout(arch)
        off = 0
        for spec in layout:
            assert spec["offset"] == off
            assert spec["size"] == int(np.prod(spec["shape"]))
            off += spec["size"]
        assert off == total


def test_unflatten_roundtrip(flat):
    params = M.unflatten(flat, C.MAIN)
    layout, _ = C.param_layout(C.MAIN)
    for spec in layout:
        seg = np.asarray(flat)[spec["offset"]:spec["offset"] + spec["size"]]
        np.testing.assert_array_equal(
            np.asarray(params[spec["name"]]).ravel(), seg)


def test_prefill_pallas_equals_xla(flat):
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(2, C.VOCAB, C.S_MAX), jnp.int32)
    valid = jnp.asarray((np.arange(C.S_MAX) < 200).astype(np.float32))
    kp, vp, ap, cp, ep = _prefill(flat, tokens, valid, "pallas")
    kx, vx, ax, cx, ex = _prefill(flat, tokens, valid, "xla")
    n = 200  # only valid positions are defined
    np.testing.assert_allclose(np.asarray(kp)[:, :n], np.asarray(kx)[:, :n],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ap)[:n], np.asarray(ax)[:n])
    np.testing.assert_allclose(np.asarray(cp)[:n], np.asarray(cx)[:n],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ep)[:n], np.asarray(ex)[:n],
                               rtol=1e-3, atol=1e-4)


def test_decode_window_only_matches_prefill(flat):
    """With an empty cache, decoding window positions 0..W-1 must equal a
    prefill over the same W tokens (bidirectional attention over the same
    visible set)."""
    rng = np.random.default_rng(1)
    w = C.WINDOW
    toks = rng.integers(2, C.VOCAB, w).astype(np.int32)

    full_tokens = jnp.asarray(np.concatenate(
        [toks, np.zeros(C.S_MAX - w, np.int32)]))
    valid = jnp.asarray((np.arange(C.S_MAX) < w).astype(np.float32))
    _, _, a_ref, c_ref, e_ref = _prefill(flat, full_tokens, valid, "xla")

    decode = jax.jit(M.make_decode(C.MAIN, "xla", w, C.S_MAX))
    kc = jnp.zeros((C.MAIN.n_layers, C.S_MAX, C.MAIN.d_kv), jnp.float32)
    a, c, e, _, _ = decode(
        flat, jnp.asarray(toks), jnp.arange(w, dtype=jnp.int32),
        jnp.ones(w, jnp.float32), kc, kc, jnp.zeros(C.S_MAX, jnp.float32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref)[:w])
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref)[:w],
                               rtol=1e-4, atol=1e-5)


def test_ar_cache_exactness(flat):
    """AR prefix caching is exact: full causal prefill == prompt prefill +
    windowed verify, at the window positions."""
    rng = np.random.default_rng(2)
    n_prompt, w = 100, C.VERIFY_W
    toks = rng.integers(2, C.VOCAB, n_prompt + w).astype(np.int32)
    pad = np.zeros(C.S_MAX - n_prompt - w, np.int32)

    full = jnp.asarray(np.concatenate([toks, pad]))
    valid_full = jnp.asarray(
        (np.arange(C.S_MAX) < n_prompt + w).astype(np.float32))
    ar_prefill = jax.jit(M.make_ar_prefill(C.MAIN, C.S_MAX))
    _, _, a_ref, c_ref, _ = ar_prefill(flat, full, valid_full)

    prompt_only = jnp.asarray(np.concatenate([toks[:n_prompt], np.zeros(
        C.S_MAX - n_prompt, np.int32)]))
    valid_p = jnp.asarray((np.arange(C.S_MAX) < n_prompt).astype(np.float32))
    kc, vc, _, _, _ = ar_prefill(flat, prompt_only, valid_p)

    verify = jax.jit(M.make_ar_verify(C.MAIN, w, C.S_MAX))
    a, c, e, _, _ = verify(
        flat, jnp.asarray(toks[n_prompt:]),
        jnp.arange(n_prompt, n_prompt + w, dtype=jnp.int32),
        jnp.ones(w, jnp.float32), kc, vc, valid_p)
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(a_ref)[n_prompt:n_prompt + w])
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(c_ref)[n_prompt:n_prompt + w],
        rtol=1e-4, atol=1e-5)


def test_train_step_decreases_loss(flat):
    """~40 AdamW steps on a fixed batch must drive masked-CE down."""
    rng = np.random.default_rng(3)
    B, S = C.B_TRAIN, C.S_TRAIN
    tokens = rng.integers(5, C.VOCAB, (B, S)).astype(np.int32)
    labels = tokens.copy()
    mask_pos = rng.random((B, S)) < 0.3
    tokens[mask_pos] = C.MASK_ID
    loss_mask = mask_pos.astype(np.float32)
    attn_valid = np.ones((B, S), np.float32)

    step_fn = jax.jit(M.make_train(C.MAIN, False, B, S))
    p = flat
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    losses = []
    for i in range(1, 61):
        p, m, v, loss = step_fn(
            p, m, v, jnp.int32(i), jnp.asarray(tokens), jnp.asarray(labels),
            jnp.asarray(loss_mask), jnp.asarray(attn_valid),
            jnp.float32(6e-3), jnp.float32(0.0))
        losses.append(float(loss))
    assert losses[-1] < 0.75 * losses[0], losses[::12]


def test_trajectory_properties(flat):
    """Ranks: one per step, unique, block-ordered, confined to gen region."""
    B, S, G = C.B_TRAJ, C.S_TRAIN, C.GEN_TRAIN
    rng = np.random.default_rng(4)
    prompt_len = 40
    tokens = np.full((B, S), C.MASK_ID, np.int32)
    tokens[:, :prompt_len] = rng.integers(5, C.VOCAB, (B, prompt_len))
    attn_valid = np.zeros((B, S), np.float32)
    attn_valid[:, :prompt_len + G] = 1.0
    gen_mask = np.zeros((B, S), np.float32)
    gen_mask[:, prompt_len:prompt_len + G] = 1.0

    traj = jax.jit(M.make_trajectory(C.MAIN, B, S, G))
    rank, final = traj(flat, jnp.asarray(tokens), jnp.asarray(attn_valid),
                       jnp.asarray(gen_mask))
    rank = np.asarray(rank)
    final = np.asarray(final)

    for b in range(B):
        gen_ranks = rank[b, prompt_len:prompt_len + G]
        # every gen position unmasked exactly once, ranks = {0..G-1}
        assert sorted(gen_ranks.tolist()) == list(range(G))
        # prompt/padding never ranked
        assert np.all(rank[b, :prompt_len] == M.RANK_NEVER)
        assert np.all(rank[b, prompt_len + G:] == M.RANK_NEVER)
        # block-diffusion order: all of block i before any of block i+1
        blocks = gen_ranks.reshape(G // C.BLOCK, C.BLOCK)
        for i in range(len(blocks) - 1):
            assert blocks[i].max() < blocks[i + 1].min()
        # no mask tokens remain in the gen region
        assert np.all(final[b, prompt_len:prompt_len + G] != C.MASK_ID)
