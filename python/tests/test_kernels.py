"""L1 kernel correctness: Pallas kernels vs pure-jnp oracle.

Hypothesis sweeps shapes and input distributions; the oracle (ref.py) is
the ground truth the Rust runtime's numerics ultimately trace back to.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels.fused_head import fused_head
from compile.kernels.ref import attention_ref, head_ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, scale=1.0, dtype=np.float32):
    return jnp.asarray(rng.normal(0.0, scale, shape), dtype)


# --------------------------------------------------------------- attention

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.sampled_from([1, 2, 4]),
    nq=st.sampled_from([1, 2, 8]),
    nkv=st.sampled_from([1, 2, 10]),
    dh=st.sampled_from([8, 24, 32]),
    scale=st.sampled_from([0.1, 1.0, 4.0]),
)
def test_flash_attention_matches_ref(seed, h, nq, nkv, dh, scale):
    rng = np.random.default_rng(seed)
    sq, skv = nq * 48, nkv * 48
    q = _rand(rng, (h, sq, dh), scale)
    k = _rand(rng, (h, skv, dh), scale)
    v = _rand(rng, (h, skv, dh), scale)
    # random mask; guarantee at least one allowed key per query
    mask = rng.random((sq, skv)) < 0.5
    mask[:, 0] = True
    bias = jnp.where(jnp.asarray(mask), 0.0, -1e30).astype(jnp.float32)
    out = flash_attention(q, k, v, bias)
    ref = attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_fully_masked_rows_match_ref():
    """Fully-masked query rows are never read by the graphs (they belong to
    padding); the kernel must still agree with the oracle there (both
    degrade to uniform attention over the masked keys)."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 48, 24))
    k = _rand(rng, (2, 96, 24))
    v = _rand(rng, (2, 96, 24))
    bias = jnp.full((48, 96), -1e30, jnp.float32)
    out = flash_attention(q, k, v, bias)
    ref = attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_causal_pattern():
    """Causal bias through the bidirectional kernel matches the oracle."""
    rng = np.random.default_rng(7)
    q = _rand(rng, (4, 96, 24))
    k = _rand(rng, (4, 96, 24))
    v = _rand(rng, (4, 96, 24))
    i = np.arange(96)
    bias = jnp.where(jnp.asarray(i[None, :] <= i[:, None]), 0.0, -1e30)
    bias = bias.astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, bias)),
        np.asarray(attention_ref(q, k, v, bias)), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- fused head

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    ns=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([48, 96]),
    v=st.sampled_from([64, 128]),
    scale=st.sampled_from([0.5, 2.0, 8.0]),
)
def test_fused_head_matches_ref(seed, ns, d, v, scale):
    rng = np.random.default_rng(seed)
    s = ns * 48
    h = _rand(rng, (s, d), scale)
    e = _rand(rng, (v, d), 0.5)
    a, c, ent = fused_head(h, e, bv=min(64, v))
    ar, cr, er = head_ref(h, e)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(er),
                               rtol=1e-4, atol=1e-4)


def test_fused_head_entropy_bounds():
    """0 <= H <= log V, and a peaked distribution has low entropy."""
    rng = np.random.default_rng(3)
    h = _rand(rng, (48, 96))
    e = _rand(rng, (128, 96), 0.02)  # near-uniform logits
    _, conf, ent = fused_head(h, e)
    ent = np.asarray(ent)
    assert np.all(ent >= -1e-4) and np.all(ent <= np.log(128) + 1e-4)
    # near-uniform logits => entropy close to log V, confidence near 1/V
    assert np.all(ent > 0.9 * np.log(128))
    assert np.all(np.asarray(conf) < 0.1)


def test_fused_head_peaked_distribution():
    e = jnp.eye(128, 96, dtype=jnp.float32)
    h = jnp.tile(e[7] * 50.0, (48, 1))
    a, c, ent = fused_head(h, e)
    assert np.all(np.asarray(a) == 7)
    assert np.all(np.asarray(c) > 0.999)
    assert np.all(np.asarray(ent) < 1e-2)
