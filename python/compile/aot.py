"""AOT pipeline: lower every Layer-2 graph to HLO text + write manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the runtime's XLA
(xla_extension 0.5.1, via the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

The manifest is the ABI between this build step and the Rust coordinator:
executable signatures (argument order, shapes, dtypes), the flat parameter
layout of each model, and every compile-time constant. Rust refuses to run
against a manifest whose constants disagree with its own config.

Usage: python -m compile.aot --out-dir ../artifacts [--only name,...]
       python -m compile.aot --dump-specs   # entry-point JSON, no lowering
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as C
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sig(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


F32, I32 = jnp.float32, jnp.int32

# Manifest ABI version. v2: executables may carry "batch" / "paged"
# fields and the constants include page/batch geometry. Bump this (and
# the accepted range in rust/src/runtime/manifest.rs) together — d3lint's
# abi-drift rule cross-checks the two.
FORMAT_VERSION = 2

# Paged executable ABI: page geometry baked into the paged specs and
# recorded per-executable in the manifest (format_version 2) so the Rust
# loader can refuse a page-table layout it did not compile for.
PAGED_ABI = {"page_rows": C.PAGE_ROWS, "max_pages": C.MAX_PAGES}

# Extra manifest fields per executable (absent = unbatched, unpaged). A
# format_version-1 manifest has none of these; the Rust loader treats the
# absence as "no batched/paged entries" and keeps the per-item/staged path.
EXEC_META = {
    "prefill_batch": {"batch": C.B_DECODE},
    "decode_paged_pallas": {"paged": PAGED_ABI},
    "decode_paged_xla": {"paged": PAGED_ABI},
    "decode_paged_batch": {"batch": C.B_DECODE, "paged": PAGED_ABI},
    "train_diff_fused": {"batch": C.TRAIN_CHUNK},
}


def build_specs():
    """Return [(exec_name, model_name, fn, arg_specs, input_sig, output_sig)]."""
    main, draft = C.MAIN, C.DRAFT
    _, p_main = C.param_layout(main)
    _, p_draft = C.param_layout(draft)
    S, W, ST, B = C.S_MAX, C.WINDOW, C.S_TRAIN, C.B_TRAIN
    MP, PR, BD = C.MAX_PAGES, C.PAGE_ROWS, C.B_DECODE
    L, DKV = main.n_layers, main.d_kv
    LD, DKVD = draft.n_layers, draft.d_kv

    specs = []

    def add(name, model, fn, args, insig, outsig):
        specs.append((name, model, fn, args, insig, outsig))

    # ---- dLLM serving graphs (pallas + xla hot-path variants)
    for variant in ("pallas", "xla"):
        add(
            f"prefill_{variant}", "main",
            M.make_prefill(main, variant, S),
            [_spec((p_main,), F32), _spec((S,), I32), _spec((S,), F32)],
            [_sig("params", (p_main,), "f32"), _sig("tokens", (S,), "i32"),
             _sig("valid", (S,), "f32")],
            [_sig("kcache", (L, S, DKV), "f32"),
             _sig("vcache", (L, S, DKV), "f32"),
             _sig("argmax", (S,), "i32"), _sig("conf", (S,), "f32"),
             _sig("entropy", (S,), "f32")],
        )
        add(
            f"decode_{variant}", "main",
            M.make_decode(main, variant, W, S),
            [_spec((p_main,), F32), _spec((W,), I32), _spec((W,), I32),
             _spec((W,), F32), _spec((L, S, DKV), F32),
             _spec((L, S, DKV), F32), _spec((S,), F32)],
            [_sig("params", (p_main,), "f32"),
             _sig("win_tokens", (W,), "i32"), _sig("win_pos", (W,), "i32"),
             _sig("win_valid", (W,), "f32"),
             _sig("kcache", (L, S, DKV), "f32"),
             _sig("vcache", (L, S, DKV), "f32"),
             _sig("cache_valid", (S,), "f32")],
            [_sig("argmax", (W,), "i32"), _sig("conf", (W,), "f32"),
             _sig("entropy", (W,), "f32"),
             _sig("k_win", (L, W, DKV), "f32"),
             _sig("v_win", (L, W, DKV), "f32")],
        )

    # ---- paged decode: reads packed KV pages + page table in place
    #      (retires the host-side dense KvStaging gather)
    for variant in ("pallas", "xla"):
        add(
            f"decode_paged_{variant}", "main",
            M.make_decode_paged(main, variant, W, PR, MP),
            [_spec((p_main,), F32), _spec((W,), I32), _spec((W,), I32),
             _spec((W,), F32), _spec((L, MP, PR, DKV), F32),
             _spec((L, MP, PR, DKV), F32), _spec((MP,), I32),
             _spec((MP,), I32)],
            [_sig("params", (p_main,), "f32"),
             _sig("win_tokens", (W,), "i32"), _sig("win_pos", (W,), "i32"),
             _sig("win_valid", (W,), "f32"),
             _sig("k_pages", (L, MP, PR, DKV), "f32"),
             _sig("v_pages", (L, MP, PR, DKV), "f32"),
             _sig("page_index", (MP,), "i32"),
             _sig("page_valid", (MP,), "i32")],
            [_sig("argmax", (W,), "i32"), _sig("conf", (W,), "f32"),
             _sig("entropy", (W,), "f32"),
             _sig("k_win", (L, W, DKV), "f32"),
             _sig("v_win", (L, W, DKV), "f32")],
        )

    # ---- batched serving executables: one device call per coalesced
    #      same-shape round in SessionPool::step_round
    add(
        "prefill_batch", "main",
        M.make_prefill_batch(main, "xla", BD, S),
        [_spec((p_main,), F32), _spec((BD, S), I32), _spec((BD, S), F32)],
        [_sig("params", (p_main,), "f32"), _sig("tokens", (BD, S), "i32"),
         _sig("valid", (BD, S), "f32")],
        [_sig("kcache", (BD, L, S, DKV), "f32"),
         _sig("vcache", (BD, L, S, DKV), "f32"),
         _sig("argmax", (BD, S), "i32"), _sig("conf", (BD, S), "f32"),
         _sig("entropy", (BD, S), "f32")],
    )
    add(
        "decode_paged_batch", "main",
        M.make_decode_paged_batch(main, "xla", BD, W, PR, MP),
        [_spec((p_main,), F32), _spec((BD, W), I32), _spec((BD, W), I32),
         _spec((BD, W), F32), _spec((BD, L, MP, PR, DKV), F32),
         _spec((BD, L, MP, PR, DKV), F32), _spec((BD, MP), I32),
         _spec((BD, MP), I32)],
        [_sig("params", (p_main,), "f32"),
         _sig("win_tokens", (BD, W), "i32"),
         _sig("win_pos", (BD, W), "i32"),
         _sig("win_valid", (BD, W), "f32"),
         _sig("k_pages", (BD, L, MP, PR, DKV), "f32"),
         _sig("v_pages", (BD, L, MP, PR, DKV), "f32"),
         _sig("page_index", (BD, MP), "i32"),
         _sig("page_valid", (BD, MP), "i32")],
        [_sig("argmax", (BD, W), "i32"), _sig("conf", (BD, W), "f32"),
         _sig("entropy", (BD, W), "f32"),
         _sig("k_win", (BD, L, W, DKV), "f32"),
         _sig("v_win", (BD, L, W, DKV), "f32")],
    )

    # ---- AR graphs (baseline + spec-decode), for main and draft models
    for mname, arch, ptot, ll, dkv in (
            ("main", main, p_main, L, DKV),
            ("draft", draft, p_draft, LD, DKVD)):
        prefix = "" if mname == "main" else "draft_"
        add(
            f"{prefix}ar_prefill", mname,
            M.make_ar_prefill(arch, S),
            [_spec((ptot,), F32), _spec((S,), I32), _spec((S,), F32)],
            [_sig("params", (ptot,), "f32"), _sig("tokens", (S,), "i32"),
             _sig("valid", (S,), "f32")],
            [_sig("kcache", (ll, S, dkv), "f32"),
             _sig("vcache", (ll, S, dkv), "f32"),
             _sig("argmax", (S,), "i32"), _sig("conf", (S,), "f32"),
             _sig("entropy", (S,), "f32")],
        )
        for wname, w in (("ar_step", 1), ("ar_verify", C.VERIFY_W)):
            if mname == "draft" and wname == "ar_verify":
                continue  # the draft only proposes one token at a time
            add(
                f"{prefix}{wname}", mname,
                M.make_ar_verify(arch, w, S),
                [_spec((ptot,), F32), _spec((w,), I32), _spec((w,), I32),
                 _spec((w,), F32), _spec((ll, S, dkv), F32),
                 _spec((ll, S, dkv), F32), _spec((S,), F32)],
                [_sig("params", (ptot,), "f32"),
                 _sig("win_tokens", (w,), "i32"),
                 _sig("win_pos", (w,), "i32"),
                 _sig("win_valid", (w,), "f32"),
                 _sig("kcache", (ll, S, dkv), "f32"),
                 _sig("vcache", (ll, S, dkv), "f32"),
                 _sig("cache_valid", (S,), "f32")],
                [_sig("argmax", (w,), "i32"), _sig("conf", (w,), "f32"),
                 _sig("entropy", (w,), "f32"),
                 _sig("k_win", (ll, w, dkv), "f32"),
                 _sig("v_win", (ll, w, dkv), "f32")],
            )

    # ---- training graphs
    for tname, mname, arch, ptot, causal in (
            ("train_diff", "main", main, p_main, False),
            ("train_ar", "main", main, p_main, True),
            ("draft_train_ar", "draft", draft, p_draft, True)):
        add(
            tname, mname,
            M.make_train(arch, causal, B, ST),
            [_spec((ptot,), F32), _spec((ptot,), F32), _spec((ptot,), F32),
             _spec((), I32), _spec((B, ST), I32), _spec((B, ST), I32),
             _spec((B, ST), F32), _spec((B, ST), F32), _spec((), F32),
             _spec((), F32)],
            [_sig("params", (ptot,), "f32"), _sig("m", (ptot,), "f32"),
             _sig("v", (ptot,), "f32"), _sig("step", (), "i32"),
             _sig("tokens", (B, ST), "i32"), _sig("labels", (B, ST), "i32"),
             _sig("loss_mask", (B, ST), "f32"),
             _sig("attn_valid", (B, ST), "f32"), _sig("lr", (), "f32"),
             _sig("ent_weight", (), "f32")],
            [_sig("params_out", (ptot,), "f32"), _sig("m_out", (ptot,), "f32"),
             _sig("v_out", (ptot,), "f32"), _sig("loss", (), "f32")],
        )

    # ---- fused multi-step training: one device call per TRAIN_CHUNK steps
    K = C.TRAIN_CHUNK
    add(
        "train_diff_fused", "main",
        M.make_train_fused(main, False, K, B, ST),
        [_spec((p_main,), F32), _spec((p_main,), F32), _spec((p_main,), F32),
         _spec((), I32), _spec((K, B, ST), I32), _spec((K, B, ST), I32),
         _spec((K, B, ST), F32), _spec((K, B, ST), F32), _spec((), F32),
         _spec((), F32)],
        [_sig("params", (p_main,), "f32"), _sig("m", (p_main,), "f32"),
         _sig("v", (p_main,), "f32"), _sig("step", (), "i32"),
         _sig("tokens", (K, B, ST), "i32"),
         _sig("labels", (K, B, ST), "i32"),
         _sig("loss_mask", (K, B, ST), "f32"),
         _sig("attn_valid", (K, B, ST), "f32"), _sig("lr", (), "f32"),
         _sig("ent_weight", (), "f32")],
        [_sig("params_out", (p_main,), "f32"),
         _sig("m_out", (p_main,), "f32"), _sig("v_out", (p_main,), "f32"),
         _sig("loss", (K,), "f32")],
    )

    # ---- pseudo-trajectory extractor
    BT = C.B_TRAJ
    add(
        "trajectory", "main",
        M.make_trajectory(main, BT, ST, C.GEN_TRAIN),
        [_spec((p_main,), F32), _spec((BT, ST), I32), _spec((BT, ST), F32),
         _spec((BT, ST), F32)],
        [_sig("params", (p_main,), "f32"), _sig("tokens", (BT, ST), "i32"),
         _sig("attn_valid", (BT, ST), "f32"),
         _sig("gen_mask", (BT, ST), "f32")],
        [_sig("rank", (BT, ST), "i32"), _sig("final_tokens", (BT, ST), "i32")],
    )
    # cached variant: window-only scan over a frozen, device-resident
    # prompt cache (same signature; the serving path's approximate scheme)
    add(
        "trajectory_paged", "main",
        M.make_trajectory_paged(main, BT, ST, C.GEN_TRAIN),
        [_spec((p_main,), F32), _spec((BT, ST), I32), _spec((BT, ST), F32),
         _spec((BT, ST), F32)],
        [_sig("params", (p_main,), "f32"), _sig("tokens", (BT, ST), "i32"),
         _sig("attn_valid", (BT, ST), "f32"),
         _sig("gen_mask", (BT, ST), "f32")],
        [_sig("rank", (BT, ST), "i32"), _sig("final_tokens", (BT, ST), "i32")],
    )
    return specs


def arch_dict(a: C.Arch):
    layout, total = C.param_layout(a)
    return {
        "name": a.name, "d_model": a.d_model, "n_layers": a.n_layers,
        "n_heads": a.n_heads, "d_head": a.d_head, "d_ff": a.d_ff,
        "vocab": a.vocab, "s_max": a.s_max, "d_kv": a.d_kv,
        "total_params": total, "param_layout": layout,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated executable names to (re)build")
    ap.add_argument("--dump-specs", action="store_true",
                    help="print entry-point names + format_version as "
                         "JSON (for d3lint --abi-spec) and exit")
    args = ap.parse_args()
    if args.dump_specs:
        # One entry per line: d3lint's reader is line-oriented, not a
        # general JSON parser.
        names = [name for name, *_ in build_specs()]
        print("{")
        print(f'  "format_version": {FORMAT_VERSION},')
        print('  "entry_points": [')
        for i, name in enumerate(names):
            comma = "," if i + 1 < len(names) else ""
            print(f'    {{"name": {json.dumps(name)}}}{comma}')
        print("  ]")
        print("}")
        return
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(filter(None, args.only.split(",")))

    executables = []
    for name, mname, fn, arg_specs, insig, outsig in build_specs():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        if (not only or name in only) or not os.path.exists(path):
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"  {name}: {len(text)} chars -> {fname}")
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()[:16]
        entry = {
            "name": name, "file": fname, "model": mname,
            "inputs": insig, "outputs": outsig, "sha256_16": digest,
        }
        entry.update(EXEC_META.get(name, {}))
        executables.append(entry)

    manifest = {
        # The Rust loader accepts v1 manifests too (no batched/paged
        # entries -> per-item and staged fallback paths).
        "format_version": FORMAT_VERSION,
        "constants": {
            "vocab": C.VOCAB, "pad_id": C.PAD_ID, "mask_id": C.MASK_ID,
            "eos_id": C.EOS_ID, "bos_id": C.BOS_ID, "sep_id": C.SEP_ID,
            "s_max": C.S_MAX, "s_train": C.S_TRAIN, "gen_max": C.GEN_MAX,
            "gen_train": C.GEN_TRAIN, "window": C.WINDOW, "block": C.BLOCK,
            "verify_w": C.VERIFY_W, "b_train": C.B_TRAIN,
            "b_traj": C.B_TRAJ, "rank_never": M.RANK_NEVER,
            "page_rows": C.PAGE_ROWS, "max_pages": C.MAX_PAGES,
            "b_decode": C.B_DECODE, "train_chunk": C.TRAIN_CHUNK,
        },
        "models": {"main": arch_dict(C.MAIN), "draft": arch_dict(C.DRAFT)},
        "executables": executables,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(executables)} executables)")


if __name__ == "__main__":
    main()
