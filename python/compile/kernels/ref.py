"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth (pytest compares the Pallas kernels
against them) and also the bodies of the `--variant xla` executables, which
let the Rust benches ablate Pallas-kernel vs XLA-fused hot paths.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, bias):
    """Masked multi-head attention.

    q: [H, Sq, Dh], k/v: [H, Skv, Dh], bias: [Sq, Skv] additive
    (0 = allowed, large negative = disallowed). Returns [H, Sq, Dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale + bias[None, :, :]
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def head_ref(h, embed, vbias=None):
    """Tied-embedding decode head with fused statistics.

    h: [S, D] (already final-normed), embed: [V, D], vbias: optional [V]
    additive logit bias (special-token suppression).
    Returns (argmax_id i32[S], confidence f32[S], entropy f32[S]) where
    confidence is the softmax probability of the argmax token and entropy is
    the softmax entropy in nats.
    """
    logits = h @ embed.T  # [S, V]
    if vbias is not None:
        logits = logits + vbias[None, :]
    m = jnp.max(logits, axis=-1)
    z = jnp.exp(logits - m[:, None])
    s = jnp.sum(z, axis=-1)
    p = z / s[:, None]
    argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    conf = jnp.max(p, axis=-1)
    # H = logZ - E[logit] = (log s + m) - sum(l * e^{l-m}) / s
    t = jnp.sum(logits * z, axis=-1)
    entropy = (jnp.log(s) + m) - t / s
    return argmax, conf, entropy
