"""Layer-1 Pallas kernel: fused decode head (argmax / confidence / entropy).

The entropy-based multi-block scheduler (paper §3.2) consumes only three
per-position statistics of the output distribution: the argmax token id, its
softmax probability ("confidence"), and the softmax entropy. Materialising
the full [S, V] logits in HBM just to reduce them on the host would waste
the bandwidth the paper's speedups come from, so this kernel fuses the tied
head matmul with an online reduction over vocab tiles:

  running state per query row: m (max logit), s = sum e^{l-m},
  t = sum l*e^{l-m}, best logit + best id;
  entropy = (log s + m) - t/s,   confidence = e^{best - m} / s.

Logits never leave the kernel. Runs under interpret=True on CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _head_kernel(h_ref, e_ref, vbias_ref, amax_ref, conf_ref, ent_ref,
                 m_ref, s_ref, t_ref, best_ref, bid_ref,
                 *, n_v_tiles: int, bv: int):
    v_idx = pl.program_id(1)

    @pl.when(v_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        bid_ref[...] = jnp.zeros_like(bid_ref)

    h = h_ref[...]          # [BS, D]
    e = e_ref[...]          # [BV, D]
    logits = jnp.dot(h, e.T, preferred_element_type=jnp.float32)  # [BS, BV]
    logits = logits + vbias_ref[...][None, :]  # special-token suppression

    # --- running argmax over vocab tiles
    tile_best = jnp.max(logits, axis=-1)
    tile_arg = jnp.argmax(logits, axis=-1).astype(jnp.int32) + v_idx * bv
    take = tile_best > best_ref[...]
    bid_ref[...] = jnp.where(take, tile_arg, bid_ref[...])
    best_ref[...] = jnp.maximum(best_ref[...], tile_best)

    # --- running logsumexp + sum(l * e^l) with max-rescaling
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, tile_best)
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(logits - m_cur[:, None])
    s_ref[...] = s_ref[...] * corr + jnp.sum(p, axis=-1)
    t_ref[...] = t_ref[...] * corr + jnp.sum(logits * p, axis=-1)
    m_ref[...] = m_cur

    @pl.when(v_idx == n_v_tiles - 1)
    def _finalize():
        s = s_ref[...]
        m = m_ref[...]
        amax_ref[...] = bid_ref[...]
        conf_ref[...] = jnp.exp(best_ref[...] - m) / s
        ent_ref[...] = (jnp.log(s) + m) - t_ref[...] / s


@functools.partial(jax.jit, static_argnames=("bs", "bv"))
def fused_head(h, embed, vbias=None, bs: int = 48, bv: int = 64):
    """Tied-head decode statistics via the Pallas fused kernel.

    h: [S, D] (final-normed hidden states), embed: [V, D], vbias: [V]
    additive logit bias (large negative entries suppress special tokens the
    model must never emit — PAD/MASK/BOS/SEP).
    Returns (argmax i32[S], confidence f32[S], entropy f32[S]).
    """
    s, d = h.shape
    v = embed.shape[0]
    assert s % bs == 0 and v % bv == 0, (s, v, bs, bv)
    n_s, n_v = s // bs, v // bv
    if vbias is None:
        vbias = jnp.zeros((v,), jnp.float32)

    kernel = functools.partial(_head_kernel, n_v_tiles=n_v, bv=bv)
    return pl.pallas_call(
        kernel,
        grid=(n_s, n_v),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bv,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bs,), jnp.float32),
            pltpu.VMEM((bs,), jnp.float32),
            pltpu.VMEM((bs,), jnp.float32),
            pltpu.VMEM((bs,), jnp.float32),
            pltpu.VMEM((bs,), jnp.int32),
        ],
        interpret=True,
    )(h, embed, vbias)
