"""Layer-1 Pallas kernel: bidirectional flash attention with additive bias.

The paper's dLLM hot spot is full-sequence bidirectional attention executed
once per decoding round. The paper's testbed implements it with CUDA
thread-blocks over shared memory; here the same HBM<->scratchpad schedule is
expressed TPU-style with `BlockSpec`s over VMEM tiles (see DESIGN.md
§Hardware-Adaptation):

  * grid = (heads, q_tiles, kv_tiles), kv innermost so the online-softmax
    accumulator lives in scratch across the kv sweep of each (head, q_tile);
  * QK^T and PV contractions are MXU-shaped matmuls over (BQ, Dh) x (Dh, BK)
    and (BQ, BK) x (BK, Dh) tiles;
  * masking (cache-validity / window-validity / causal) arrives as an
    additive bias tile, so one kernel serves prefill, windowed multi-block
    decode, and AR verification.

Runs under interpret=True (CPU PJRT cannot execute Mosaic custom-calls);
real-TPU VMEM/MXU estimates are in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, n_kv_tiles: int, scale: float):
    """One (head, q_tile, kv_tile) grid step of online-softmax attention."""
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, :]  # [BQ, Dh]
    k = k_ref[0, :, :]  # [BK, Dh]
    v = v_ref[0, :, :]  # [BK, Dh]
    bias = bias_ref[...]  # [BQ, BK]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + bias

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])  # [BQ, BK]

    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kv_idx == n_kv_tiles - 1)
    def _finalize():
        # Fully-masked rows (l == 0) only occur for padding queries; emit 0.
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def flash_attention(q, k, v, bias, bq: int = 48, bk: int = 48):
    """Masked multi-head attention via the Pallas flash kernel.

    q: [H, Sq, Dh], k/v: [H, Skv, Dh], bias: [Sq, Skv] additive.
    Sq must divide by bq and Skv by bk. Returns [H, Sq, Dh] f32.
    """
    h, sq, dh = q.shape
    skv = k.shape[1]
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    n_q, n_kv = sq // bq, skv // bk
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_flash_kernel, n_kv_tiles=n_kv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hh, iq, ik: (hh, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda hh, iq, ik: (hh, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda hh, iq, ik: (hh, ik, 0)),
            pl.BlockSpec((bq, bk), lambda hh, iq, ik: (iq, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hh, iq, ik: (hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, bias)
