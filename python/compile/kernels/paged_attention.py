"""Layer-1 Pallas kernel: paged-attention decode over the block KV pool.

The serving hot path used to feed the decode executable a dense
`[L, S_max, d_kv]` cache image re-gathered on the host (`KvStaging`).
This kernel consumes the page-table arguments `KvView::page_args` already
produces instead — a page index list and per-page valid counts — so the
executable reads KV pages in place:

  * the KV cache arrives as up to `MAX_PAGES` page-shaped entries of
    `PAGE_ROWS` rows each, in arbitrary order (attention is permutation-
    invariant over keys; positional information is baked into the cached
    K/V vectors themselves);
  * `page_index` (i32[MP], scalar-prefetched to SMEM) marks live entries
    (logical page id, or -1 for a dead slot) and `page_valid` (i32[MP])
    gives each entry's valid row count — both are consumed *inside* the
    kernel to build the key mask, so no host-side gather, zeroing, or
    dense validity image exists anywhere on the path;
  * the decode window's own K/V ride along as `W / PAGE_ROWS` extra
    kv-grid steps after the pages, masked by `win_kmask`.

Grid = (heads, q_tiles, MP + W/PAGE_ROWS), kv innermost: the same
online-softmax schedule as `attention.flash_attention`, with the kv sweep
walking pages first and window tiles last. Runs under interpret=True
(CPU PJRT cannot execute Mosaic custom-calls), like every kernel here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pidx_ref, pval_ref, q_ref, kp_ref, vp_ref, kw_ref, vw_ref,
                  wmask_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, mp: int, n_kv: int, rows: int, scale: float):
    """One (head, q_tile, kv_entry) grid step of paged online-softmax."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, :]  # [BQ, Dh]
    is_page = ik < mp

    # Both candidate tiles are resident (their BlockSpecs clamp the index);
    # the grid position selects which one this step attends to.
    k = jnp.where(is_page, kp_ref[0, 0, :, :], kw_ref[0, :, :])  # [PR, Dh]
    v = jnp.where(is_page, vp_ref[0, 0, :, :], vw_ref[0, :, :])

    # Key mask from the page table: entry `ik` is attendable at row r iff
    # it is live (page_index >= 0) and r < page_valid. Window tiles use the
    # window validity mask instead.
    entry = jnp.minimum(ik, mp - 1)
    r = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], rows), 1)
    page_ok = (pidx_ref[entry] >= 0) & (r < pval_ref[entry])
    win_ok = (wmask_ref[...] > 0.0)[None, :]
    mask = jnp.where(is_page, page_ok, win_ok)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])  # [BQ, PR]

    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == n_kv - 1)
    def _finalize():
        # Fully-masked rows (l == 0) only occur for padding queries; emit 0.
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq",))
def paged_flash_attention(q, k_pages, v_pages, page_index, page_valid,
                          k_win, v_win, win_kmask, bq: int = 48):
    """Paged masked attention for the windowed decode step.

    q: [H, W, Dh] window queries; k_pages/v_pages: [H, MP, PR, Dh] packed
    live KV pages (arbitrary order); page_index i32[MP] (logical page id,
    -1 = dead entry), page_valid i32[MP] (valid rows per entry);
    k_win/v_win: [H, W, Dh] the window's own KV; win_kmask f32[W] (> 0 =
    attendable window key). W must divide by bq and by PR. Returns
    [H, W, Dh] f32.
    """
    h, w, dh = q.shape
    mp, pr = k_pages.shape[1], k_pages.shape[2]
    assert w % bq == 0 and w % pr == 0, (w, bq, pr)
    n_q, n_win = w // bq, w // pr
    n_kv = mp + n_win
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_paged_kernel, mp=mp, n_kv=n_kv, rows=pr,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hh, iq, ik, pi, pv: (hh, iq, 0)),
            pl.BlockSpec(
                (1, 1, pr, dh),
                lambda hh, iq, ik, pi, pv: (hh, jnp.minimum(ik, mp - 1), 0, 0)),
            pl.BlockSpec(
                (1, 1, pr, dh),
                lambda hh, iq, ik, pi, pv: (hh, jnp.minimum(ik, mp - 1), 0, 0)),
            pl.BlockSpec(
                (1, pr, dh),
                lambda hh, iq, ik, pi, pv:
                (hh, jnp.clip(ik - mp, 0, n_win - 1), 0)),
            pl.BlockSpec(
                (1, pr, dh),
                lambda hh, iq, ik, pi, pv:
                (hh, jnp.clip(ik - mp, 0, n_win - 1), 0)),
            pl.BlockSpec(
                (pr,),
                lambda hh, iq, ik, pi, pv: (jnp.clip(ik - mp, 0, n_win - 1),)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh),
                               lambda hh, iq, ik, pi, pv: (hh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((h, w, dh), jnp.float32),
        interpret=True,
    )(page_index, page_valid, q, k_pages, v_pages, k_win, v_win, win_kmask)
