"""Shared architecture / constants for the d3LLM reproduction.

Single source of truth for every compile-time constant: the AOT pipeline
(aot.py) bakes these into the HLO executables and records them in
artifacts/manifest.json, which the Rust coordinator treats as ABI.

Scaled to the single-core PJRT-CPU testbed (see DESIGN.md §1): the paper's
7-8B dLLMs become ~0.4M-param models with identical architecture class
(bidirectional masked-diffusion transformer, block size 32, tied
embeddings).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

# ---------------------------------------------------------------- vocabulary
VOCAB = 128
PAD_ID = 0
MASK_ID = 1
EOS_ID = 2
BOS_ID = 3
SEP_ID = 4

# ---------------------------------------------------------------- sequence geometry
S_MAX = 384      # serving sequence capacity (prompt + generation)
S_TRAIN = 192    # training / trajectory sequence length
GEN_MAX = 128    # serving generation region capacity (4 blocks)
GEN_TRAIN = 96   # trajectory extraction unmask steps (3 blocks)
WINDOW = 96      # decode window: up to 3 concurrently active blocks
BLOCK = 32       # diffusion block size (paper: 32)
VERIFY_W = 16    # speculative-decoding verification window
B_TRAIN = 8      # training batch
B_TRAJ = 8       # trajectory-extraction batch

# ---------------------------------------------------------------- paged KV / batch geometry
PAGE_ROWS = 32   # KV page height (rows) — matches the Rust pool's block-aligned pages
MAX_PAGES = S_MAX // PAGE_ROWS  # page-table length of one session (12)
B_DECODE = 4     # batch of the batched serving executables (prefill/decode)
TRAIN_CHUNK = 4  # optimizer steps fused into one train_diff_fused call

# ---------------------------------------------------------------- kernel tiling
BQ = 48          # attention query tile
BK = 48          # attention key tile
BS_HEAD = 48     # fused-head sequence tile
BV_HEAD = 64     # fused-head vocab tile


@dataclass(frozen=True)
class Arch:
    """Transformer architecture hyperparameters."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    vocab: int = VOCAB
    s_max: int = S_MAX

    @property
    def d_kv(self) -> int:
        return self.n_heads * self.d_head


MAIN = Arch(name="main", d_model=96, n_layers=3, n_heads=4, d_head=24, d_ff=384)
DRAFT = Arch(name="draft", d_model=48, n_layers=1, n_heads=2, d_head=24, d_ff=192)


def param_specs(arch: Arch) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Flat parameter layout: (name, shape, init) in canonical order.

    init is one of "normal" (std=0.02), "zeros", "ones". The Rust side owns
    actual initialisation and checkpointing; this layout is the contract.
    """
    specs: List[Tuple[str, Tuple[int, ...], str]] = [
        ("embed", (arch.vocab, arch.d_model), "normal"),
        ("pos", (arch.s_max, arch.d_model), "normal"),
    ]
    for l in range(arch.n_layers):
        p = f"layer{l}."
        specs += [
            (p + "ln1", (arch.d_model,), "ones"),
            (p + "wq", (arch.d_model, arch.d_kv), "normal"),
            (p + "wk", (arch.d_model, arch.d_kv), "normal"),
            (p + "wv", (arch.d_model, arch.d_kv), "normal"),
            (p + "wo", (arch.d_kv, arch.d_model), "normal"),
            (p + "ln2", (arch.d_model,), "ones"),
            (p + "w1", (arch.d_model, arch.d_ff), "normal"),
            (p + "w2", (arch.d_ff, arch.d_model), "normal"),
        ]
    specs.append(("lnf", (arch.d_model,), "ones"))
    return specs


def param_layout(arch: Arch):
    """[(name, shape, offset, size, init)] plus total length."""
    out = []
    off = 0
    for name, shape, init in param_specs(arch):
        size = 1
        for s in shape:
            size *= s
        out.append({"name": name, "shape": list(shape), "offset": off,
                    "size": size, "init": init})
        off += size
    return out, off
