"""Layer-2 JAX compute graphs for the d3LLM reproduction.

A single bidirectional transformer architecture (tied embeddings, RMSNorm,
GELU MLP) instantiated as several AOT graphs:

  * prefill        — full-sequence forward: KV cache for every position +
                     fused head stats. Doubles as the no-cache forward used
                     by vanilla decoding and by the KV-refresh mechanism.
  * decode         — windowed forward (<=3 active blocks) against the
                     block-approximate KV cache: the multi-block hot path.
  * ar_prefill     — causal forward (AR baseline / spec-decode target).
  * ar_verify      — causal windowed forward with cache (W=16 for
                     speculative verification, W=1 for plain AR decoding).
  * train          — fused fwd + bwd + AdamW step, diffusion (bidirectional)
                     or AR (causal) objective, with optional certainty-
                     forcing entropy regularisation (dParallel-style).
  * trajectory     — the paper's pseudo-trajectory extractor: a 96-step
                     on-device lax.scan that unmasks exactly one token per
                     step (restricted to the earliest incomplete block, i.e.
                     a block-diffusion teacher) and records the unmask step
                     of every position.

Serving graphs (prefill/decode) call the Pallas kernels (variant="pallas")
or the pure-jnp oracle (variant="xla") so the Rust benches can ablate the
two hot-path implementations. Training/trajectory graphs use the jnp path
(autodiff through the interpret-mode kernel is not exercised; the math is
identical and ref-tested).

Parameters are a single flat f32 vector; see config.param_layout.
"""

from typing import Dict

import jax
import jax.numpy as jnp

from .config import (Arch, BLOCK, BOS_ID, GEN_TRAIN, MASK_ID, PAD_ID,
                     SEP_ID, param_layout)
from .kernels.attention import flash_attention
from .kernels.paged_attention import paged_flash_attention
from .kernels.ref import attention_ref, head_ref
from .kernels.fused_head import fused_head

NEG_INF = -1e30
RANK_NEVER = 100_000  # rank sentinel: position never unmasked by teacher


# --------------------------------------------------------------------------
# parameter (un)flattening
# --------------------------------------------------------------------------

def unflatten(p: jnp.ndarray, arch: Arch) -> Dict[str, jnp.ndarray]:
    layout, total = param_layout(arch)
    assert p.shape == (total,), (p.shape, total)
    out = {}
    for spec in layout:
        seg = jax.lax.dynamic_slice(p, (spec["offset"],), (spec["size"],))
        out[spec["name"]] = seg.reshape(spec["shape"])
    return out


def rms(x, w, eps=1e-6):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _split_heads(x, arch: Arch):
    """[S, H*Dh] -> [H, S, Dh]"""
    s = x.shape[0]
    return x.reshape(s, arch.n_heads, arch.d_head).transpose(1, 0, 2)


def _merge_heads(x, arch: Arch):
    """[H, S, Dh] -> [S, H*Dh]"""
    return x.transpose(1, 0, 2).reshape(x.shape[1], arch.d_kv)


def _split_page_heads(x, arch: Arch):
    """[MP, PR, H*Dh] -> [H, MP, PR, Dh]"""
    mp, pr, _ = x.shape
    return x.reshape(mp, pr, arch.n_heads, arch.d_head).transpose(2, 0, 1, 3)


def _attn(q, k, v, bias, variant: str):
    if variant == "pallas":
        return flash_attention(q, k, v, bias)
    return attention_ref(q, k, v, bias)


def vocab_bias(arch: Arch):
    """Additive logit bias suppressing tokens the model must never emit
    (PAD / MASK / BOS / SEP). Standard dLLM practice: without it an
    untrained or off-distribution model can 'unmask' a position back to
    MASK and stall the decoding loop."""
    b = jnp.zeros((arch.vocab,), jnp.float32)
    return b.at[jnp.array([PAD_ID, MASK_ID, BOS_ID, SEP_ID])].set(NEG_INF)


def _head(h, embed, variant: str, arch: Arch):
    vb = vocab_bias(arch)
    if variant == "pallas":
        return fused_head(h, embed, vb)
    return head_ref(h, embed, vb)


# --------------------------------------------------------------------------
# single-sequence forward (serving graphs)
# --------------------------------------------------------------------------

def forward_single(params: Dict, tokens, pos_ids, bias, arch: Arch,
                   variant: str):
    """Forward one unbatched sequence; returns (h_final_normed, kv list).

    tokens/pos_ids: i32[S]; bias: f32[S, S] additive attention bias.
    kv list: per layer (k, v) of shape [S, H*Dh] — the cacheable states.
    """
    x = params["embed"][tokens] + params["pos"][pos_ids]
    kvs = []
    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k = hn @ params[p + "wk"]
        v = hn @ params[p + "wv"]
        kvs.append((k, v))
        o = _attn(_split_heads(q, arch), _split_heads(k, arch),
                  _split_heads(v, arch), bias, variant)
        x = x + _merge_heads(o, arch) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    return rms(x, params["lnf"]), kvs


def forward_window(params: Dict, win_tokens, win_pos, kcache, vcache,
                   bias, arch: Arch, variant: str):
    """Forward the active window against the KV cache.

    win_tokens/win_pos: i32[W]; kcache/vcache: f32[L, S, H*Dh];
    bias: f32[W, S+W]. Returns (h_final_normed [W, D], k_win, v_win
    [L, W, H*Dh]).
    """
    x = params["embed"][win_tokens] + params["pos"][win_pos]
    k_wins, v_wins = [], []
    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k_w = hn @ params[p + "wk"]
        v_w = hn @ params[p + "wv"]
        k_wins.append(k_w)
        v_wins.append(v_w)
        k_all = jnp.concatenate([kcache[l], k_w], axis=0)
        v_all = jnp.concatenate([vcache[l], v_w], axis=0)
        o = _attn(_split_heads(q, arch), _split_heads(k_all, arch),
                  _split_heads(v_all, arch), bias, variant)
        x = x + _merge_heads(o, arch) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    return (rms(x, params["lnf"]),
            jnp.stack(k_wins), jnp.stack(v_wins))


def forward_window_paged(params: Dict, win_tokens, win_pos, k_pages, v_pages,
                         page_index, page_valid, win_valid, arch: Arch,
                         variant: str):
    """Forward the active window against packed KV pages read in place.

    win_tokens/win_pos: i32[W]; k_pages/v_pages: f32[L, MP, PR, H*Dh] —
    up to MP live pages in arbitrary order (attention is permutation-
    invariant over keys; positions live inside the cached K/V vectors);
    page_index: i32[MP] logical page id per entry (-1 = dead entry);
    page_valid: i32[MP] valid rows per entry; win_valid: f32[W].
    Returns (h_final_normed [W, D], k_win, v_win [L, W, H*Dh]).

    No dense [S_max]-proportional cache image or validity vector exists on
    this path — the mask is derived entry-locally from the page table.
    """
    mp, pr = k_pages.shape[1], k_pages.shape[2]
    w = win_tokens.shape[0]
    x = params["embed"][win_tokens] + params["pos"][win_pos]
    rows = jnp.arange(pr, dtype=jnp.int32)[None, :]
    entry_ok = (page_index[:, None] >= 0) & (rows < page_valid[:, None])
    allowed = jnp.concatenate([entry_ok.reshape(mp * pr), win_valid > 0.0])
    bias = jnp.broadcast_to(
        jnp.where(allowed[None, :], 0.0, NEG_INF), (w, mp * pr + w))
    k_wins, v_wins = [], []
    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k_w = hn @ params[p + "wk"]
        v_w = hn @ params[p + "wv"]
        k_wins.append(k_w)
        v_wins.append(v_w)
        if variant == "pallas":
            o = paged_flash_attention(
                _split_heads(q, arch),
                _split_page_heads(k_pages[l], arch),
                _split_page_heads(v_pages[l], arch),
                page_index, page_valid,
                _split_heads(k_w, arch), _split_heads(v_w, arch), win_valid,
                bq=48 if w % 48 == 0 else w)
        else:
            # reference path: packed pages are already key-major — a
            # reshape (not a gather) concatenates them with the window
            k_all = jnp.concatenate(
                [k_pages[l].reshape(mp * pr, arch.d_kv), k_w], axis=0)
            v_all = jnp.concatenate(
                [v_pages[l].reshape(mp * pr, arch.d_kv), v_w], axis=0)
            o = attention_ref(_split_heads(q, arch),
                              _split_heads(k_all, arch),
                              _split_heads(v_all, arch), bias)
        x = x + _merge_heads(o, arch) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    return (rms(x, params["lnf"]),
            jnp.stack(k_wins), jnp.stack(v_wins))


# --------------------------------------------------------------------------
# graph builders (each returns a jit-able fn over concrete shapes)
# --------------------------------------------------------------------------

def make_prefill(arch: Arch, variant: str, seq: int):
    """tokens i32[S], valid f32[S] -> (kcache, vcache, argmax, conf, ent)."""

    def fn(flat, tokens, valid):
        params = unflatten(flat, arch)
        pos_ids = jnp.arange(seq, dtype=jnp.int32)
        bias = jnp.where(valid[None, :] > 0.0, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (seq, seq))
        h, kvs = forward_single(params, tokens, pos_ids, bias, arch, variant)
        amax, conf, ent = _head(h, params["embed"], variant, arch)
        kcache = jnp.stack([k for k, _ in kvs])
        vcache = jnp.stack([v for _, v in kvs])
        return kcache, vcache, amax, conf, ent

    return fn


def make_decode(arch: Arch, variant: str, window: int, seq: int):
    """Windowed multi-block decode step against the approximate KV cache."""

    def fn(flat, win_tokens, win_pos, win_valid, kcache, vcache, cache_valid):
        params = unflatten(flat, arch)
        allowed = jnp.concatenate([cache_valid, win_valid])  # [S+W]
        bias = jnp.where(allowed[None, :] > 0.0, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (window, seq + window))
        h, k_win, v_win = forward_window(
            params, win_tokens, win_pos, kcache, vcache, bias, arch, variant)
        amax, conf, ent = _head(h, params["embed"], variant, arch)
        return amax, conf, ent, k_win, v_win

    return fn


def make_decode_paged(arch: Arch, variant: str, window: int, page_rows: int,
                      max_pages: int):
    """Windowed decode step reading packed KV pages in place.

    The paged twin of `make_decode`: instead of a dense [L, S, d_kv] cache
    image plus a dense validity vector, it takes up to `max_pages` packed
    page entries and the page-table arguments (`page_index`, `page_valid`)
    the Rust `KvView::page_args` produces. Serves both cache layouts: a
    paged pool passes its live pages as-is; a dense cache is presented as
    an identity-table page view (contiguous row slices, no gather).
    """

    def fn(flat, win_tokens, win_pos, win_valid, k_pages, v_pages,
           page_index, page_valid):
        params = unflatten(flat, arch)
        h, k_win, v_win = forward_window_paged(
            params, win_tokens, win_pos, k_pages, v_pages, page_index,
            page_valid, win_valid, arch, variant)
        amax, conf, ent = _head(h, params["embed"], variant, arch)
        return amax, conf, ent, k_win, v_win

    return fn


def make_prefill_batch(arch: Arch, variant: str, batch: int, seq: int):
    """B>1 prefill: one device call for a coalesced same-shape round."""
    single = make_prefill(arch, variant, seq)

    def fn(flat, tokens, valid):
        return jax.vmap(single, in_axes=(None, 0, 0))(flat, tokens, valid)

    return fn


def make_decode_paged_batch(arch: Arch, variant: str, batch: int, window: int,
                            page_rows: int, max_pages: int):
    """B>1 paged decode: every item carries its own page table."""
    single = make_decode_paged(arch, variant, window, page_rows, max_pages)

    def fn(flat, win_tokens, win_pos, win_valid, k_pages, v_pages,
           page_index, page_valid):
        return jax.vmap(single, in_axes=(None, 0, 0, 0, 0, 0, 0, 0))(
            flat, win_tokens, win_pos, win_valid, k_pages, v_pages,
            page_index, page_valid)

    return fn


def make_ar_prefill(arch: Arch, seq: int):
    """Causal full forward: caches + next-token stats at every position."""

    def fn(flat, tokens, valid):
        params = unflatten(flat, arch)
        pos_ids = jnp.arange(seq, dtype=jnp.int32)
        i = jnp.arange(seq)
        causal = (i[None, :] <= i[:, None])
        bias = jnp.where(causal & (valid[None, :] > 0.0), 0.0, NEG_INF)
        h, kvs = forward_single(params, tokens, pos_ids, bias, arch, "xla")
        amax, conf, ent = head_ref(h, params["embed"], vocab_bias(arch))
        return (jnp.stack([k for k, _ in kvs]),
                jnp.stack([v for _, v in kvs]), amax, conf, ent)

    return fn


def make_ar_verify(arch: Arch, window: int, seq: int):
    """Causal windowed forward with cache: spec-decode verify / AR step.

    Window position i attends to valid cache entries plus window positions
    <= i. Output slot i carries next-token stats for window position i.
    """

    def fn(flat, win_tokens, win_pos, win_valid, kcache, vcache, cache_valid):
        params = unflatten(flat, arch)
        i = jnp.arange(window)
        win_causal = (i[None, :] <= i[:, None]) & (win_valid[None, :] > 0.0)
        cache_allowed = jnp.broadcast_to(cache_valid[None, :] > 0.0,
                                         (window, seq))
        allowed = jnp.concatenate([cache_allowed, win_causal], axis=1)
        bias = jnp.where(allowed, 0.0, NEG_INF)
        h, k_win, v_win = forward_window(
            params, win_tokens, win_pos, kcache, vcache, bias, arch, "xla")
        amax, conf, ent = head_ref(h, params["embed"], vocab_bias(arch))
        return amax, conf, ent, k_win, v_win

    return fn


# --------------------------------------------------------------------------
# batched forward + training
# --------------------------------------------------------------------------

def forward_batch_logits(params: Dict, tokens, bias, arch: Arch):
    """tokens i32[B, S], bias f32[B, S, S] -> logits f32[B, S, V]."""
    _, s = tokens.shape
    pos_ids = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][tokens] + params["pos"][pos_ids][None, :, :]

    def batched_attn(q, k, v):
        # q/k/v: [B, S, H*Dh]
        def one(qi, ki, vi, bi):
            return attention_ref(_split_heads(qi, arch),
                                 _split_heads(ki, arch),
                                 _split_heads(vi, arch), bi)
        o = jax.vmap(one)(q, k, v, bias)  # [B, H, S, Dh]
        return jax.vmap(lambda oi: _merge_heads(oi, arch))(o)

    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k = hn @ params[p + "wk"]
        v = hn @ params[p + "wv"]
        x = x + batched_attn(q, k, v) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    h = rms(x, params["lnf"])
    return h @ params["embed"].T


def make_train(arch: Arch, causal: bool, batch: int, seq: int):
    """Fused fwd + bwd + AdamW step.

    Inputs: flat params/m/v f32[P], step i32[], tokens/labels i32[B,S],
    loss_mask/attn_valid f32[B,S], lr f32[], ent_weight f32[].
    Outputs: params', m', v', loss.

    Loss: masked CE against labels + ent_weight * masked mean prediction
    entropy (the certainty-forcing regulariser of dParallel, reused by the
    paper's own recipe, §A.7).
    """
    b1, b2, eps, wd = 0.9, 0.999, 1e-8, 0.01

    def loss_fn(flat, tokens, labels, loss_mask, attn_valid, ent_weight):
        params = unflatten(flat, arch)
        allowed = attn_valid[:, None, :] > 0.0  # keys must be valid
        if causal:
            i = jnp.arange(seq)
            allowed = allowed & (i[None, :] <= i[:, None])[None, :, :]
        bias = jnp.where(allowed, 0.0, NEG_INF)
        logits = forward_batch_logits(params, tokens, bias, arch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
        ce_loss = jnp.sum(ce * loss_mask) / denom
        p = jnp.exp(logp)
        ent = -jnp.sum(p * logp, axis=-1)
        ent_loss = jnp.sum(ent * loss_mask) / denom
        return ce_loss + ent_weight * ent_loss

    def fn(flat, m, v, step, tokens, labels, loss_mask, attn_valid, lr,
           ent_weight):
        loss, g = jax.value_and_grad(loss_fn)(
            flat, tokens, labels, loss_mask, attn_valid, ent_weight)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        t = step.astype(jnp.float32)
        mhat = m2 / (1.0 - b1 ** t)
        vhat = v2 / (1.0 - b2 ** t)
        new = flat - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * flat)
        return new, m2, v2, loss

    return fn


def make_train_fused(arch: Arch, causal: bool, chunk: int, batch: int,
                     seq: int):
    """`chunk` fused fwd+bwd+AdamW steps in one on-device lax.scan.

    Same per-step math as `make_train`; tokens/labels/masks carry a
    leading [chunk] axis and the optimizer state threads through the scan,
    so a training chunk costs one device call instead of `chunk`.
    Outputs: params', m', v', loss f32[chunk].
    """
    step_fn = make_train(arch, causal, batch, seq)

    def fn(flat, m, v, step, tokens, labels, loss_mask, attn_valid, lr,
           ent_weight):
        def body(carry, xs):
            f, mm, vv, st = carry
            t, lb, lm, av = xs
            f2, m2, v2, loss = step_fn(f, mm, vv, st, t, lb, lm, av, lr,
                                       ent_weight)
            return (f2, m2, v2, st + 1), loss

        (f2, m2, v2, _), losses = jax.lax.scan(
            body, (flat, m, v, step),
            (tokens, labels, loss_mask, attn_valid))
        return f2, m2, v2, losses

    return fn


# --------------------------------------------------------------------------
# pseudo-trajectory extraction (paper §3.1)
# --------------------------------------------------------------------------

def make_trajectory(arch: Arch, batch: int, seq: int, steps: int = GEN_TRAIN):
    """Teacher decoding-order extractor, fully on device.

    Inputs: flat f32[P], tokens i32[B,S] (prompt + MASK gen region),
    attn_valid f32[B,S], gen_mask f32[B,S].
    Outputs: rank i32[B,S] (step at which the teacher unmasked the
    position; RANK_NEVER for prompt/padding), final tokens i32[B,S].

    Exactly one token is unmasked per step (paper: "we constrain the
    teacher model to unmask exactly one token at each decoding step"),
    restricted to the earliest incomplete block — the teacher is a block
    diffusion model with block size 32 — selecting the highest-confidence
    masked position. Generation continues past EOS so every gen position
    receives a rank.
    """

    def fn(flat, tokens, attn_valid, gen_mask):
        params = unflatten(flat, arch)
        allowed = attn_valid[:, None, :] > 0.0
        bias = jnp.where(allowed, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (batch, seq, seq))
        iota = jnp.arange(seq, dtype=jnp.int32)[None, :]
        gen = gen_mask > 0.0
        gen_start = jnp.argmax(gen_mask, axis=1).astype(jnp.int32)  # [B]
        rel = iota - gen_start[:, None]
        block_id = jnp.where(gen, rel // BLOCK, jnp.int32(10**6))

        vb = vocab_bias(arch)[None, None, :]

        def step_fn(carry, step):
            toks, rank = carry
            logits = forward_batch_logits(params, toks, bias, arch) + vb
            pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
            masked = (toks == MASK_ID) & gen
            cur_block = jnp.min(
                jnp.where(masked, block_id, jnp.int32(10**6)), axis=1)  # [B]
            selectable = masked & (block_id == cur_block[:, None])
            score = jnp.where(selectable, conf, -1.0)
            j = jnp.argmax(score, axis=1)  # [B]
            any_m = jnp.any(selectable, axis=1)
            hit = (iota == j[:, None]) & any_m[:, None]
            toks = jnp.where(hit, pred, toks)
            rank = jnp.where(hit & (rank == RANK_NEVER), step, rank)
            return (toks, rank), None

        rank0 = jnp.full((batch, seq), RANK_NEVER, dtype=jnp.int32)
        (toks, rank), _ = jax.lax.scan(
            step_fn, (tokens, rank0), jnp.arange(steps, dtype=jnp.int32))
        return rank, toks

    return fn


def make_trajectory_paged(arch: Arch, batch: int, seq: int,
                          steps: int = GEN_TRAIN):
    """Pseudo-trajectory extractor over a frozen, device-resident KV cache.

    Same I/O contract as `make_trajectory`, but the scan re-runs only the
    generation window: the prompt KV is prefilled once and read in place
    every step (the serving path's block-approximate cache scheme) instead
    of re-running the full [B, S] forward `steps` times. The extracted
    order is therefore the *cached-decode* teacher order — the ordering
    the serving hot path actually executes — and the per-step attention
    cost drops from S^2 to W*(S+W).
    """
    w = steps  # the gen region is one window wide (GEN_TRAIN)

    def fn(flat, tokens, attn_valid, gen_mask):
        params = unflatten(flat, arch)
        pos_ids = jnp.arange(seq, dtype=jnp.int32)
        gen = gen_mask > 0.0
        gen_start = jnp.argmax(gen_mask, axis=1).astype(jnp.int32)  # [B]
        win_pos = (gen_start[:, None]
                   + jnp.arange(w, dtype=jnp.int32)[None, :])  # [B, w]

        # one bidirectional prefill (MASKs in place) builds the cache
        bias_full = jnp.where(attn_valid[:, None, :] > 0.0, 0.0, NEG_INF)
        bias_full = jnp.broadcast_to(bias_full, (batch, seq, seq))

        def one_prefill(t, bias):
            _, kvs = forward_single(params, t, pos_ids, bias, arch, "xla")
            return (jnp.stack([k for k, _ in kvs]),
                    jnp.stack([v for _, v in kvs]))

        kcache, vcache = jax.vmap(one_prefill)(tokens, bias_full)

        # window queries attend to frozen non-gen cache keys plus the
        # window's own live keys (gen keys in the cache are stale MASKs)
        cache_ok = (attn_valid > 0.0) & ~gen  # [B, S]
        win_ok = jnp.take_along_axis(gen_mask, win_pos, axis=1) > 0.0
        allowed = jnp.concatenate([cache_ok, win_ok], axis=1)  # [B, S+w]
        bias_w = jnp.broadcast_to(
            jnp.where(allowed[:, None, :], 0.0, NEG_INF),
            (batch, w, seq + w))

        vb = vocab_bias(arch)[None, None, :]
        iota = jnp.arange(seq, dtype=jnp.int32)[None, :]
        block_id_w = (jnp.arange(w, dtype=jnp.int32) // BLOCK)[None, :]

        def step_fn(carry, step):
            toks, rank = carry
            win_toks = jnp.take_along_axis(toks, win_pos, axis=1)  # [B, w]

            def one_win(wt, wp, kc, vc, b):
                h, _, _ = forward_window(params, wt, wp, kc, vc, b, arch,
                                         "xla")
                return h

            h = jax.vmap(one_win)(win_toks, win_pos, kcache, vcache, bias_w)
            logits = h @ params["embed"].T + vb  # [B, w, V]
            pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
            masked = (win_toks == MASK_ID) & win_ok
            cur_block = jnp.min(
                jnp.where(masked, block_id_w, jnp.int32(10**6)), axis=1)
            selectable = masked & (block_id_w == cur_block[:, None])
            score = jnp.where(selectable, conf, -1.0)
            j = jnp.argmax(score, axis=1)  # [B], window-relative
            any_m = jnp.any(selectable, axis=1)
            j_abs = jnp.take_along_axis(win_pos, j[:, None], axis=1)[:, 0]
            pred_j = jnp.take_along_axis(pred, j[:, None], axis=1)[:, 0]
            hit = (iota == j_abs[:, None]) & any_m[:, None]
            toks = jnp.where(hit, pred_j[:, None], toks)
            rank = jnp.where(hit & (rank == RANK_NEVER), step, rank)
            return (toks, rank), None

        rank0 = jnp.full((batch, seq), RANK_NEVER, dtype=jnp.int32)
        (toks, rank), _ = jax.lax.scan(
            step_fn, (tokens, rank0), jnp.arange(steps, dtype=jnp.int32))
        return rank, toks

    return fn
