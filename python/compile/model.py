"""Layer-2 JAX compute graphs for the d3LLM reproduction.

A single bidirectional transformer architecture (tied embeddings, RMSNorm,
GELU MLP) instantiated as several AOT graphs:

  * prefill        — full-sequence forward: KV cache for every position +
                     fused head stats. Doubles as the no-cache forward used
                     by vanilla decoding and by the KV-refresh mechanism.
  * decode         — windowed forward (<=3 active blocks) against the
                     block-approximate KV cache: the multi-block hot path.
  * ar_prefill     — causal forward (AR baseline / spec-decode target).
  * ar_verify      — causal windowed forward with cache (W=16 for
                     speculative verification, W=1 for plain AR decoding).
  * train          — fused fwd + bwd + AdamW step, diffusion (bidirectional)
                     or AR (causal) objective, with optional certainty-
                     forcing entropy regularisation (dParallel-style).
  * trajectory     — the paper's pseudo-trajectory extractor: a 96-step
                     on-device lax.scan that unmasks exactly one token per
                     step (restricted to the earliest incomplete block, i.e.
                     a block-diffusion teacher) and records the unmask step
                     of every position.

Serving graphs (prefill/decode) call the Pallas kernels (variant="pallas")
or the pure-jnp oracle (variant="xla") so the Rust benches can ablate the
two hot-path implementations. Training/trajectory graphs use the jnp path
(autodiff through the interpret-mode kernel is not exercised; the math is
identical and ref-tested).

Parameters are a single flat f32 vector; see config.param_layout.
"""

from typing import Dict

import jax
import jax.numpy as jnp

from .config import (Arch, BLOCK, BOS_ID, GEN_TRAIN, MASK_ID, PAD_ID,
                     SEP_ID, param_layout)
from .kernels.attention import flash_attention
from .kernels.ref import attention_ref, head_ref
from .kernels.fused_head import fused_head

NEG_INF = -1e30
RANK_NEVER = 100_000  # rank sentinel: position never unmasked by teacher


# --------------------------------------------------------------------------
# parameter (un)flattening
# --------------------------------------------------------------------------

def unflatten(p: jnp.ndarray, arch: Arch) -> Dict[str, jnp.ndarray]:
    layout, total = param_layout(arch)
    assert p.shape == (total,), (p.shape, total)
    out = {}
    for spec in layout:
        seg = jax.lax.dynamic_slice(p, (spec["offset"],), (spec["size"],))
        out[spec["name"]] = seg.reshape(spec["shape"])
    return out


def rms(x, w, eps=1e-6):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _split_heads(x, arch: Arch):
    """[S, H*Dh] -> [H, S, Dh]"""
    s = x.shape[0]
    return x.reshape(s, arch.n_heads, arch.d_head).transpose(1, 0, 2)


def _merge_heads(x, arch: Arch):
    """[H, S, Dh] -> [S, H*Dh]"""
    return x.transpose(1, 0, 2).reshape(x.shape[1], arch.d_kv)


def _attn(q, k, v, bias, variant: str):
    if variant == "pallas":
        return flash_attention(q, k, v, bias)
    return attention_ref(q, k, v, bias)


def vocab_bias(arch: Arch):
    """Additive logit bias suppressing tokens the model must never emit
    (PAD / MASK / BOS / SEP). Standard dLLM practice: without it an
    untrained or off-distribution model can 'unmask' a position back to
    MASK and stall the decoding loop."""
    b = jnp.zeros((arch.vocab,), jnp.float32)
    return b.at[jnp.array([PAD_ID, MASK_ID, BOS_ID, SEP_ID])].set(NEG_INF)


def _head(h, embed, variant: str, arch: Arch):
    vb = vocab_bias(arch)
    if variant == "pallas":
        return fused_head(h, embed, vb)
    return head_ref(h, embed, vb)


# --------------------------------------------------------------------------
# single-sequence forward (serving graphs)
# --------------------------------------------------------------------------

def forward_single(params: Dict, tokens, pos_ids, bias, arch: Arch,
                   variant: str):
    """Forward one unbatched sequence; returns (h_final_normed, kv list).

    tokens/pos_ids: i32[S]; bias: f32[S, S] additive attention bias.
    kv list: per layer (k, v) of shape [S, H*Dh] — the cacheable states.
    """
    x = params["embed"][tokens] + params["pos"][pos_ids]
    kvs = []
    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k = hn @ params[p + "wk"]
        v = hn @ params[p + "wv"]
        kvs.append((k, v))
        o = _attn(_split_heads(q, arch), _split_heads(k, arch),
                  _split_heads(v, arch), bias, variant)
        x = x + _merge_heads(o, arch) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    return rms(x, params["lnf"]), kvs


def forward_window(params: Dict, win_tokens, win_pos, kcache, vcache,
                   bias, arch: Arch, variant: str):
    """Forward the active window against the KV cache.

    win_tokens/win_pos: i32[W]; kcache/vcache: f32[L, S, H*Dh];
    bias: f32[W, S+W]. Returns (h_final_normed [W, D], k_win, v_win
    [L, W, H*Dh]).
    """
    x = params["embed"][win_tokens] + params["pos"][win_pos]
    k_wins, v_wins = [], []
    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k_w = hn @ params[p + "wk"]
        v_w = hn @ params[p + "wv"]
        k_wins.append(k_w)
        v_wins.append(v_w)
        k_all = jnp.concatenate([kcache[l], k_w], axis=0)
        v_all = jnp.concatenate([vcache[l], v_w], axis=0)
        o = _attn(_split_heads(q, arch), _split_heads(k_all, arch),
                  _split_heads(v_all, arch), bias, variant)
        x = x + _merge_heads(o, arch) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    return (rms(x, params["lnf"]),
            jnp.stack(k_wins), jnp.stack(v_wins))


# --------------------------------------------------------------------------
# graph builders (each returns a jit-able fn over concrete shapes)
# --------------------------------------------------------------------------

def make_prefill(arch: Arch, variant: str, seq: int):
    """tokens i32[S], valid f32[S] -> (kcache, vcache, argmax, conf, ent)."""

    def fn(flat, tokens, valid):
        params = unflatten(flat, arch)
        pos_ids = jnp.arange(seq, dtype=jnp.int32)
        bias = jnp.where(valid[None, :] > 0.0, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (seq, seq))
        h, kvs = forward_single(params, tokens, pos_ids, bias, arch, variant)
        amax, conf, ent = _head(h, params["embed"], variant, arch)
        kcache = jnp.stack([k for k, _ in kvs])
        vcache = jnp.stack([v for _, v in kvs])
        return kcache, vcache, amax, conf, ent

    return fn


def make_decode(arch: Arch, variant: str, window: int, seq: int):
    """Windowed multi-block decode step against the approximate KV cache."""

    def fn(flat, win_tokens, win_pos, win_valid, kcache, vcache, cache_valid):
        params = unflatten(flat, arch)
        allowed = jnp.concatenate([cache_valid, win_valid])  # [S+W]
        bias = jnp.where(allowed[None, :] > 0.0, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (window, seq + window))
        h, k_win, v_win = forward_window(
            params, win_tokens, win_pos, kcache, vcache, bias, arch, variant)
        amax, conf, ent = _head(h, params["embed"], variant, arch)
        return amax, conf, ent, k_win, v_win

    return fn


def make_ar_prefill(arch: Arch, seq: int):
    """Causal full forward: caches + next-token stats at every position."""

    def fn(flat, tokens, valid):
        params = unflatten(flat, arch)
        pos_ids = jnp.arange(seq, dtype=jnp.int32)
        i = jnp.arange(seq)
        causal = (i[None, :] <= i[:, None])
        bias = jnp.where(causal & (valid[None, :] > 0.0), 0.0, NEG_INF)
        h, kvs = forward_single(params, tokens, pos_ids, bias, arch, "xla")
        amax, conf, ent = head_ref(h, params["embed"], vocab_bias(arch))
        return (jnp.stack([k for k, _ in kvs]),
                jnp.stack([v for _, v in kvs]), amax, conf, ent)

    return fn


def make_ar_verify(arch: Arch, window: int, seq: int):
    """Causal windowed forward with cache: spec-decode verify / AR step.

    Window position i attends to valid cache entries plus window positions
    <= i. Output slot i carries next-token stats for window position i.
    """

    def fn(flat, win_tokens, win_pos, win_valid, kcache, vcache, cache_valid):
        params = unflatten(flat, arch)
        i = jnp.arange(window)
        win_causal = (i[None, :] <= i[:, None]) & (win_valid[None, :] > 0.0)
        cache_allowed = jnp.broadcast_to(cache_valid[None, :] > 0.0,
                                         (window, seq))
        allowed = jnp.concatenate([cache_allowed, win_causal], axis=1)
        bias = jnp.where(allowed, 0.0, NEG_INF)
        h, k_win, v_win = forward_window(
            params, win_tokens, win_pos, kcache, vcache, bias, arch, "xla")
        amax, conf, ent = head_ref(h, params["embed"], vocab_bias(arch))
        return amax, conf, ent, k_win, v_win

    return fn


# --------------------------------------------------------------------------
# batched forward + training
# --------------------------------------------------------------------------

def forward_batch_logits(params: Dict, tokens, bias, arch: Arch):
    """tokens i32[B, S], bias f32[B, S, S] -> logits f32[B, S, V]."""
    _, s = tokens.shape
    pos_ids = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][tokens] + params["pos"][pos_ids][None, :, :]

    def batched_attn(q, k, v):
        # q/k/v: [B, S, H*Dh]
        def one(qi, ki, vi, bi):
            return attention_ref(_split_heads(qi, arch),
                                 _split_heads(ki, arch),
                                 _split_heads(vi, arch), bi)
        o = jax.vmap(one)(q, k, v, bias)  # [B, H, S, Dh]
        return jax.vmap(lambda oi: _merge_heads(oi, arch))(o)

    for l in range(arch.n_layers):
        p = f"layer{l}."
        hn = rms(x, params[p + "ln1"])
        q = hn @ params[p + "wq"]
        k = hn @ params[p + "wk"]
        v = hn @ params[p + "wv"]
        x = x + batched_attn(q, k, v) @ params[p + "wo"]
        hn2 = rms(x, params[p + "ln2"])
        x = x + jax.nn.gelu(hn2 @ params[p + "w1"]) @ params[p + "w2"]
    h = rms(x, params["lnf"])
    return h @ params["embed"].T


def make_train(arch: Arch, causal: bool, batch: int, seq: int):
    """Fused fwd + bwd + AdamW step.

    Inputs: flat params/m/v f32[P], step i32[], tokens/labels i32[B,S],
    loss_mask/attn_valid f32[B,S], lr f32[], ent_weight f32[].
    Outputs: params', m', v', loss.

    Loss: masked CE against labels + ent_weight * masked mean prediction
    entropy (the certainty-forcing regulariser of dParallel, reused by the
    paper's own recipe, §A.7).
    """
    b1, b2, eps, wd = 0.9, 0.999, 1e-8, 0.01

    def loss_fn(flat, tokens, labels, loss_mask, attn_valid, ent_weight):
        params = unflatten(flat, arch)
        allowed = attn_valid[:, None, :] > 0.0  # keys must be valid
        if causal:
            i = jnp.arange(seq)
            allowed = allowed & (i[None, :] <= i[:, None])[None, :, :]
        bias = jnp.where(allowed, 0.0, NEG_INF)
        logits = forward_batch_logits(params, tokens, bias, arch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
        ce_loss = jnp.sum(ce * loss_mask) / denom
        p = jnp.exp(logp)
        ent = -jnp.sum(p * logp, axis=-1)
        ent_loss = jnp.sum(ent * loss_mask) / denom
        return ce_loss + ent_weight * ent_loss

    def fn(flat, m, v, step, tokens, labels, loss_mask, attn_valid, lr,
           ent_weight):
        loss, g = jax.value_and_grad(loss_fn)(
            flat, tokens, labels, loss_mask, attn_valid, ent_weight)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        t = step.astype(jnp.float32)
        mhat = m2 / (1.0 - b1 ** t)
        vhat = v2 / (1.0 - b2 ** t)
        new = flat - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * flat)
        return new, m2, v2, loss

    return fn


# --------------------------------------------------------------------------
# pseudo-trajectory extraction (paper §3.1)
# --------------------------------------------------------------------------

def make_trajectory(arch: Arch, batch: int, seq: int, steps: int = GEN_TRAIN):
    """Teacher decoding-order extractor, fully on device.

    Inputs: flat f32[P], tokens i32[B,S] (prompt + MASK gen region),
    attn_valid f32[B,S], gen_mask f32[B,S].
    Outputs: rank i32[B,S] (step at which the teacher unmasked the
    position; RANK_NEVER for prompt/padding), final tokens i32[B,S].

    Exactly one token is unmasked per step (paper: "we constrain the
    teacher model to unmask exactly one token at each decoding step"),
    restricted to the earliest incomplete block — the teacher is a block
    diffusion model with block size 32 — selecting the highest-confidence
    masked position. Generation continues past EOS so every gen position
    receives a rank.
    """

    def fn(flat, tokens, attn_valid, gen_mask):
        params = unflatten(flat, arch)
        allowed = attn_valid[:, None, :] > 0.0
        bias = jnp.where(allowed, 0.0, NEG_INF)
        bias = jnp.broadcast_to(bias, (batch, seq, seq))
        iota = jnp.arange(seq, dtype=jnp.int32)[None, :]
        gen = gen_mask > 0.0
        gen_start = jnp.argmax(gen_mask, axis=1).astype(jnp.int32)  # [B]
        rel = iota - gen_start[:, None]
        block_id = jnp.where(gen, rel // BLOCK, jnp.int32(10**6))

        vb = vocab_bias(arch)[None, None, :]

        def step_fn(carry, step):
            toks, rank = carry
            logits = forward_batch_logits(params, toks, bias, arch) + vb
            pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
            masked = (toks == MASK_ID) & gen
            cur_block = jnp.min(
                jnp.where(masked, block_id, jnp.int32(10**6)), axis=1)  # [B]
            selectable = masked & (block_id == cur_block[:, None])
            score = jnp.where(selectable, conf, -1.0)
            j = jnp.argmax(score, axis=1)  # [B]
            any_m = jnp.any(selectable, axis=1)
            hit = (iota == j[:, None]) & any_m[:, None]
            toks = jnp.where(hit, pred, toks)
            rank = jnp.where(hit & (rank == RANK_NEVER), step, rank)
            return (toks, rank), None

        rank0 = jnp.full((batch, seq), RANK_NEVER, dtype=jnp.int32)
        (toks, rank), _ = jax.lax.scan(
            step_fn, (tokens, rank0), jnp.arange(steps, dtype=jnp.int32))
        return rank, toks

    return fn
