"""Render the paper's figures from results/*.csv (build-time only).

Optional: requires matplotlib. The bench harnesses emit the CSV series;
this script turns them into PNGs mirroring the paper's Figures 1 and 4-10
(accuracy-parallelism curves, AUP histograms, radar charts).

  python plots/plot_figures.py [--results results] [--out results/plots]
"""

import argparse
import csv
import math
import os
from collections import defaultdict


def read_csv(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def curves(results, out, plt):
    for family in ("llada", "dream", "coder"):
        path = os.path.join(results, f"curves_{family}.csv")
        if not os.path.exists(path):
            continue
        rows = read_csv(path)
        tasks = sorted({r["task"] for r in rows})
        fig, axes = plt.subplots(1, len(tasks),
                                 figsize=(4 * len(tasks), 3.4))
        if len(tasks) == 1:
            axes = [axes]
        for ax, task in zip(axes, tasks):
            series = defaultdict(list)
            for r in rows:
                if r["task"] == task:
                    series[r["method"]].append(
                        (float(r["tpf"]), float(r["acc"])))
            for method, pts in series.items():
                pts.sort()
                ax.plot([p[0] for p in pts], [p[1] for p in pts],
                        marker="o", label=method)
            ax.set_title(task)
            ax.set_xlabel("TPF (parallelism)")
            ax.set_ylabel("accuracy (%)")
            ax.grid(alpha=0.3)
        axes[-1].legend(fontsize=7)
        fig.suptitle(f"Accuracy-parallelism curves — {family} family")
        fig.tight_layout()
        fig.savefig(os.path.join(out, f"curves_{family}.png"), dpi=120)
        print(f"wrote curves_{family}.png")


def radar(results, out, plt):
    for family in ("llada", "dream", "coder"):
        path = os.path.join(results, f"radar_{family}.csv")
        if not os.path.exists(path):
            continue
        rows = read_csv(path)
        tasks = sorted({r["task"] for r in rows})
        methods = sorted({r["method"] for r in rows})
        aup = {(r["task"], r["method"]): float(r["aup"]) for r in rows}
        # normalise per task so the radar is comparable
        angles = [2 * math.pi * i / len(tasks) for i in range(len(tasks))]
        fig = plt.figure(figsize=(5, 5))
        ax = fig.add_subplot(111, polar=True)
        for m in methods:
            vals = []
            for t in tasks:
                best = max(aup.get((t, mm), 1e-9) for mm in methods)
                vals.append(aup.get((t, m), 0.0) / best)
            ax.plot(angles + angles[:1], vals + vals[:1], marker="o",
                    label=m)
            ax.fill(angles + angles[:1], vals + vals[:1], alpha=0.08)
        ax.set_xticks(angles)
        ax.set_xticklabels(tasks, fontsize=7)
        ax.set_title(f"AUP radar — {family} family (normalised)")
        ax.legend(fontsize=6, loc="lower right")
        fig.savefig(os.path.join(out, f"radar_{family}.png"), dpi=120)
        print(f"wrote radar_{family}.png")

        # histogram variant (Figures 6/8/10 left panels)
        fig, ax = plt.subplots(figsize=(6, 3.2))
        width = 0.8 / len(methods)
        for i, m in enumerate(methods):
            xs = [j + i * width for j in range(len(tasks))]
            ax.bar(xs, [aup.get((t, m), 0.0) for t in tasks], width,
                   label=m)
        ax.set_xticks([j + 0.4 for j in range(len(tasks))])
        ax.set_xticklabels(tasks, fontsize=7)
        ax.set_ylabel("AUP")
        ax.legend(fontsize=6)
        fig.tight_layout()
        fig.savefig(os.path.join(out, f"aup_hist_{family}.png"), dpi=120)
        print(f"wrote aup_hist_{family}.png")


def figure1(results, out, plt):
    path = os.path.join(results, "figure1_aup_illustration.csv")
    if not os.path.exists(path):
        return
    rows = read_csv(path)
    tpf = [float(r["tpf"]) for r in rows]
    acc = [float(r["acc"]) for r in rows]
    wacc = [float(r["weighted_acc"]) for r in rows]
    fig, ax = plt.subplots(figsize=(5, 3.4))
    ax.plot(tpf, acc, marker="o", label="accuracy")
    ax.plot(tpf, wacc, marker="s", label="weighted accuracy (AUP integrand)")
    ax.fill_between(tpf, wacc, alpha=0.2)
    ax.set_xlabel("parallelism (TPF)")
    ax.set_ylabel("accuracy (%)")
    ax.set_title("AUP: weighted area under the accuracy-parallelism curve")
    ax.legend(fontsize=8)
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "figure1_aup.png"), dpi=120)
    print("wrote figure1_aup.png")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--out", default="results/plots")
    args = ap.parse_args()
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not available; CSVs in results/ are the figures")
        return
    os.makedirs(args.out, exist_ok=True)
    figure1(args.results, args.out, plt)
    curves(args.results, args.out, plt)
    radar(args.results, args.out, plt)


if __name__ == "__main__":
    main()
