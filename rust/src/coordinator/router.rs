//! Fleet router: prefix-affinity placement over N engine-worker replicas.
//!
//! Each replica owns its own engine, `SessionPool` and `SharedKvPool`, so
//! a prompt's prefilled pages live in exactly one pool — placement decides
//! whether the next same-prefix request re-prefills from scratch or adopts
//! those pages for free. The router therefore keys placement on the *same*
//! prefix-chain hash the pools index pages by (`kv_pool::chain_hashes`,
//! exposed as `prefix_routing_key`): rendezvous/HRW hashing over that key
//! sends same-prefix traffic to one stable home replica, and keeps doing
//! so with minimal disruption when replicas die (only keys homed on the
//! dead replica move).
//!
//! Placement policy, in order:
//!   1. keyed request, home replica can take it  -> affinity hit
//!   2. keyed, home backlogged past the request's deadline budget (or its
//!      queue full) while a sibling fits         -> backlog spill to the
//!      least-loaded fitting sibling (the home batcher would shed what a
//!      sibling could meet)
//!   3. keyed, nobody fits                       -> home anyway; its
//!      batcher owns the shed/retry answer
//!   4. no key (short prompt, no-cache strategy, artifacts absent)
//!                                               -> least-loaded replica
//!
//! The placement core (`RouterCore`) is pure and threadless — workers
//! publish load through `ReplicaGauge` atomics and the core only reads
//! them — so determinism tests and the fleet bench drive it directly. The
//! `Router` wrapper adds the per-replica job channels and the death/drain
//! behavior: a send to a dead replica marks it dead and re-places, and
//! `reroute` lets a dying worker push its salvaged queue to survivors.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::model::kv_pool::{prefix_routing_key, rendezvous_score};
use crate::runtime::manifest::Constants;
use crate::runtime::Manifest;
use crate::tokenizer::Tokenizer;

use super::protocol::GenRequest;
use super::{Job, ServerCfg};

/// Live load snapshot one engine worker publishes every cycle. The router
/// reads these without any cross-thread locking; staleness is bounded by
/// one worker round and only costs placement quality, never correctness.
pub struct ReplicaGauge {
    /// Cleared when the replica's engine worker exits (crash or drain).
    pub alive: AtomicBool,
    /// Jobs waiting in the replica's admission queue.
    pub queue_depth: AtomicU64,
    /// Live interleaved sessions on the replica.
    pub active_sessions: AtomicU64,
    /// The replica batcher's estimated queue wait in ms (depth x observed
    /// round time), the same figure its shed/retry hints use.
    pub est_wait_ms: AtomicU64,
}

impl ReplicaGauge {
    fn new() -> ReplicaGauge {
        ReplicaGauge {
            alive: AtomicBool::new(true),
            queue_depth: AtomicU64::new(0),
            active_sessions: AtomicU64::new(0),
            est_wait_ms: AtomicU64::new(0),
        }
    }
}

/// Where one request went, and why — the counters the stats protocol
/// exports are keyed on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// HRW home of the request's prefix chain.
    Affinity(usize),
    /// Home too backlogged for the deadline budget; least-loaded sibling.
    Spill(usize),
    /// No routing key: least-loaded replica.
    Cold(usize),
}

impl Placement {
    pub fn replica(&self) -> usize {
        match *self {
            Placement::Affinity(r) | Placement::Spill(r)
            | Placement::Cold(r) => r,
        }
    }
}

/// Pure placement core: gauges in, replica index out. Fleet-wide routing
/// counters live here so the threadless test/bench harnesses see the same
/// accounting the server exports.
pub struct RouterCore {
    gauges: Vec<Arc<ReplicaGauge>>,
    /// Per-replica queue capacity (a full queue never takes spilled work).
    max_queue: usize,
    /// Keyed requests placed on their HRW home (counter).
    pub affinity_hits: AtomicU64,
    /// Keyed requests spilled off a backlogged home to a sibling (counter).
    pub affinity_spills: AtomicU64,
    /// Keyless requests placed least-loaded (counter).
    pub cold_placements: AtomicU64,
    /// Salvaged jobs re-routed off a dead replica (counter).
    pub jobs_rerouted: AtomicU64,
    /// Replicas that died (transitioned alive -> dead) (counter).
    pub replica_deaths: AtomicU64,
    /// Acceptor-side protocol errors (unparseable request lines), counted
    /// fleet-wide — they never reach a replica.
    pub conn_errors: AtomicU64,
}

impl RouterCore {
    pub fn new(workers: usize, max_queue: usize) -> RouterCore {
        let workers = workers.max(1);
        RouterCore {
            gauges: (0..workers).map(|_| Arc::new(ReplicaGauge::new()))
                                .collect(),
            max_queue: max_queue.max(1),
            affinity_hits: AtomicU64::new(0),
            affinity_spills: AtomicU64::new(0),
            cold_placements: AtomicU64::new(0),
            jobs_rerouted: AtomicU64::new(0),
            replica_deaths: AtomicU64::new(0),
            conn_errors: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.gauges.len()
    }

    pub fn gauge(&self, r: usize) -> Arc<ReplicaGauge> {
        self.gauges[r].clone()
    }

    pub fn alive(&self, r: usize) -> bool {
        // ordering: SeqCst pairs with mark_dead's swap (and the clean-exit
        // store in engine_worker) so a replica marked dead before queue
        // salvage is never elected by a racing placement; the sender-slot
        // teardown itself is serialized by Router's senders mutex
        self.gauges[r].alive.load(Ordering::SeqCst)
    }

    pub fn alive_count(&self) -> usize {
        (0..self.workers()).filter(|&r| self.alive(r)).count()
    }

    /// Idempotent: only the alive -> dead transition counts a death.
    pub fn mark_dead(&self, r: usize) {
        // ordering: SeqCst swap is the publish side of `alive` (above);
        // the swap also makes the death count exactly-once under races
        if self.gauges[r].alive.swap(false, Ordering::SeqCst) {
            self.replica_deaths.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Can replica `r` take one more job and still meet `budget_ms`?
    fn fits(&self, r: usize, budget_ms: Option<u64>) -> bool {
        let g = &self.gauges[r];
        if g.queue_depth.load(Ordering::Relaxed) >= self.max_queue as u64 {
            return false;
        }
        match budget_ms {
            None => true,
            Some(b) => g.est_wait_ms.load(Ordering::Relaxed) <= b,
        }
    }

    /// Deterministic load order: queue depth, then live sessions, then
    /// estimated wait, then index (stable tie-break).
    fn load_key(&self, r: usize) -> (u64, u64, u64, usize) {
        let g = &self.gauges[r];
        (g.queue_depth.load(Ordering::Relaxed),
         g.active_sessions.load(Ordering::Relaxed),
         g.est_wait_ms.load(Ordering::Relaxed),
         r)
    }

    /// Least-loaded live replica (`None` when the whole fleet is dead).
    pub fn least_loaded_alive(&self) -> Option<usize> {
        (0..self.workers())
            .filter(|&r| self.alive(r))
            .min_by_key(|&r| self.load_key(r))
    }

    /// HRW home of `key` among live replicas.
    fn home_of(&self, key: u64) -> Option<usize> {
        (0..self.workers())
            .filter(|&r| self.alive(r))
            .max_by_key(|&r| (rendezvous_score(key, r as u64), r))
    }

    /// Place one request. `key` is the prefix-chain routing key (`None` =
    /// cold), `budget_ms` the request's deadline budget for the backlog
    /// check. Returns `None` only when no replica is alive.
    pub fn place(&self, key: Option<u64>, budget_ms: Option<u64>)
                 -> Option<Placement> {
        match key {
            None => {
                let r = self.least_loaded_alive()?;
                self.cold_placements.fetch_add(1, Ordering::Relaxed);
                Some(Placement::Cold(r))
            }
            Some(k) => {
                let home = self.home_of(k)?;
                if self.fits(home, budget_ms) {
                    self.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    return Some(Placement::Affinity(home));
                }
                // the home batcher would shed this: spill to the least-
                // loaded sibling that can still meet it, if any
                let sibling = (0..self.workers())
                    .filter(|&r| r != home && self.alive(r)
                                 && self.fits(r, budget_ms))
                    .min_by_key(|&r| self.load_key(r));
                match sibling {
                    Some(r) => {
                        self.affinity_spills.fetch_add(1, Ordering::Relaxed);
                        Some(Placement::Spill(r))
                    }
                    None => {
                        // nobody can meet it: keep affinity and let the
                        // home's deadline-aware admission answer the shed
                        self.affinity_hits.fetch_add(1, Ordering::Relaxed);
                        Some(Placement::Affinity(home))
                    }
                }
            }
        }
    }
}

/// Channel-owning router the acceptor dispatches through. Senders are
/// `Option` so a dead replica's channel can be dropped (its worker then
/// sees `Disconnected` and drains) while indices stay stable.
pub struct Router {
    core: Arc<RouterCore>,
    senders: Mutex<Vec<Option<mpsc::Sender<Job>>>>,
}

impl Router {
    pub fn new(core: Arc<RouterCore>, senders: Vec<mpsc::Sender<Job>>)
               -> Router {
        assert_eq!(core.workers(), senders.len());
        Router {
            core,
            senders: Mutex::new(senders.into_iter().map(Some).collect()),
        }
    }

    pub fn core(&self) -> &Arc<RouterCore> {
        &self.core
    }

    /// Send to replica `r`; on failure (channel gone — the worker died
    /// between placement and send) the job is handed back and the replica
    /// marked dead so the next placement skips it.
    fn try_send(&self, r: usize, job: Job) -> std::result::Result<(), Job> {
        // a thread that panicked holding this lock can only have been
        // mutating one Option slot; the Vec itself stays structurally
        // sound, so recover the poisoned state instead of dying
        let mut senders =
            self.senders.lock().unwrap_or_else(|p| p.into_inner());
        let sent = match senders.get(r).and_then(|s| s.as_ref()) {
            Some(tx) => tx.send(job).map_err(|mpsc::SendError(j)| j),
            None => Err(job),
        };
        match sent {
            Ok(()) => Ok(()),
            Err(job) => {
                if let Some(slot) = senders.get_mut(r) {
                    *slot = None;
                }
                drop(senders);
                self.core.mark_dead(r);
                Err(job)
            }
        }
    }

    /// Place and deliver one request. Re-places on dead-replica races;
    /// each failed send kills one replica, so this terminates. Errors
    /// only when the whole fleet is dead.
    pub fn dispatch(&self, key: Option<u64>, budget_ms: Option<u64>,
                    mut job: Job) -> Result<()> {
        loop {
            let p = self.core.place(key, budget_ms)
                .ok_or_else(|| anyhow!("no live replicas"))?;
            match self.try_send(p.replica(), job) {
                Ok(()) => return Ok(()),
                Err(j) => job = j,
            }
        }
    }

    /// Graceful-drain path: a dying worker pushes a salvaged queued job to
    /// the least-loaded survivor. The job already paid its placement
    /// counters once, so this only counts the re-route. When the whole
    /// fleet is dead the job is handed back so the caller can still send
    /// an error reply on its connection.
    pub fn reroute(&self, mut job: Job) -> std::result::Result<(), Job> {
        self.core.jobs_rerouted.fetch_add(1, Ordering::Relaxed);
        loop {
            let r = match self.core.least_loaded_alive() {
                Some(r) => r,
                None => return Err(job),
            };
            match self.try_send(r, job) {
                Ok(()) => return Ok(()),
                Err(j) => job = j,
            }
        }
    }

    /// Mark a replica dead and drop its channel. Called by the replica's
    /// own wrapper on fatal error, *before* it salvages its queue, so
    /// re-routes cannot bounce back to it.
    pub fn drop_replica(&self, r: usize) {
        self.core.mark_dead(r);
        let mut senders =
            self.senders.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(slot) = senders.get_mut(r) {
            *slot = None;
        }
    }

    /// Shutdown: drop every sender so each worker sees `Disconnected`
    /// once its queue drains, finishes its live sessions, and exits.
    pub fn close_intake(&self) {
        let mut senders =
            self.senders.lock().unwrap_or_else(|p| p.into_inner());
        for s in senders.iter_mut() {
            *s = None;
        }
    }
}

/// Enough of the serving manifest to compute, acceptor-side, the same
/// prefix-chain hash the replica pools index pages by. Loaded once at
/// startup; `None` (artifacts absent, paged serving disabled, or a single
/// worker) degrades every placement to cold/least-loaded, which for one
/// replica is exact and for a key-less fleet is plain load balancing.
pub struct RouteKeyCtx {
    tk: Tokenizer,
    c: Constants,
    layers: usize,
    d_kv: usize,
}

impl RouteKeyCtx {
    pub fn load(dir: &str) -> Option<RouteKeyCtx> {
        let m = Manifest::load(dir).ok()?;
        let spec = m.model("main").ok()?.clone();
        let tk = Tokenizer::new(m.constants.vocab).ok()?;
        Some(RouteKeyCtx {
            tk,
            c: m.constants,
            layers: spec.n_layers,
            d_kv: spec.d_kv,
        })
    }

    /// Routing key for one request: tokenize, resolve the decode config,
    /// and hash the first prompt page under the request's prefix tag —
    /// exactly the chain hash `PagedKv::admit` will look up on the
    /// replica. `None` (short prompt, no-cache strategy, bad request)
    /// means no pages to be affine to; the request places cold and any
    /// real error surfaces on the replica, which owns error replies.
    pub fn key_for(&self, cfg: &ServerCfg, req: &GenRequest) -> Option<u64> {
        let prompt = self.tk.encode(&req.prompt).ok()?;
        let dcfg = super::request_cfg(cfg, req).ok()?;
        // gen_len only affects span_rows, not the prefix tag/rows the
        // routing key hashes, so 0 is fine here
        let geo = crate::decode::kv_admission_geometry(&dcfg, &self.c,
                                                       prompt.len(), 0);
        prefix_routing_key(&geo.prefix_tag, self.layers, self.d_kv,
                           self.c.block, &prompt, geo.prefix_rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(workers: usize, max_queue: usize) -> RouterCore {
        RouterCore::new(workers, max_queue)
    }

    #[test]
    fn keyed_placement_is_deterministic_and_stable() {
        let c = core(4, 8);
        let k = 0xDEAD_BEEF_u64;
        let first = c.place(Some(k), None).unwrap();
        for _ in 0..10 {
            assert_eq!(c.place(Some(k), None).unwrap(), first);
        }
        match first {
            Placement::Affinity(_) => {}
            other => panic!("expected affinity placement, got {other:?}"),
        }
    }

    #[test]
    fn hrw_moves_only_keys_homed_on_the_dead_replica() {
        let c = core(4, 8);
        let keys: Vec<u64> = (0..64).map(|i| 0x9E37_79B9 ^ (i * 7919)).collect();
        let before: Vec<usize> =
            keys.iter().map(|&k| c.place(Some(k), None).unwrap().replica())
                .collect();
        // keys spread over more than one replica (sanity on the hash)
        let mut seen = before.clone();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() > 1, "all 64 keys landed on one replica");
        let victim = before[0];
        c.mark_dead(victim);
        for (i, &k) in keys.iter().enumerate() {
            let after = c.place(Some(k), None).unwrap().replica();
            assert_ne!(after, victim);
            if before[i] != victim {
                // HRW minimal disruption: surviving homes don't move
                assert_eq!(after, before[i]);
            }
        }
    }

    #[test]
    fn cold_goes_least_loaded() {
        let c = core(3, 8);
        c.gauge(0).queue_depth.store(5, Ordering::Relaxed);
        c.gauge(1).queue_depth.store(2, Ordering::Relaxed);
        c.gauge(2).queue_depth.store(2, Ordering::Relaxed);
        // tie between 1 and 2 breaks to the lower index
        assert_eq!(c.place(None, None).unwrap(), Placement::Cold(1));
        assert_eq!(c.cold_placements.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn backlogged_home_spills_to_fitting_sibling() {
        let c = core(2, 4);
        let k = 42u64;
        let home = c.place(Some(k), None).unwrap().replica();
        let other = 1 - home;
        // full queue on the home: a keyed request must spill
        c.gauge(home).queue_depth.store(4, Ordering::Relaxed);
        assert_eq!(c.place(Some(k), None).unwrap(), Placement::Spill(other));
        // deadline budget version: home est-wait exceeds the budget
        c.gauge(home).queue_depth.store(0, Ordering::Relaxed);
        c.gauge(home).est_wait_ms.store(500, Ordering::Relaxed);
        assert_eq!(c.place(Some(k), Some(100)).unwrap(),
                   Placement::Spill(other));
        // generous budget: affinity wins again
        assert_eq!(c.place(Some(k), Some(1000)).unwrap(),
                   Placement::Affinity(home));
        assert_eq!(c.affinity_spills.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn nobody_fits_keeps_affinity_for_the_shed_answer() {
        let c = core(2, 2);
        let k = 7u64;
        let home = c.place(Some(k), None).unwrap().replica();
        c.gauge(0).queue_depth.store(2, Ordering::Relaxed);
        c.gauge(1).queue_depth.store(2, Ordering::Relaxed);
        assert_eq!(c.place(Some(k), None).unwrap(),
                   Placement::Affinity(home));
    }

    #[test]
    fn dead_fleet_places_nothing() {
        let c = core(2, 8);
        c.mark_dead(0);
        c.mark_dead(1);
        assert!(c.place(Some(1), None).is_none());
        assert!(c.place(None, None).is_none());
        assert_eq!(c.replica_deaths.load(Ordering::Relaxed), 2);
        // idempotent: re-marking doesn't double count
        c.mark_dead(0);
        assert_eq!(c.replica_deaths.load(Ordering::Relaxed), 2);
    }
}
