//! Serving coordinator: a threaded JSON-line TCP server in front of an
//! interleaved multi-session decode engine.
//!
//! Topology (the offline registry has no tokio; std threads + channels):
//!
//!   acceptor thread --- per-connection reader threads
//!        |  (mpsc)                |  parse JSON-line requests
//!        v                        v
//!   fleet router  <-- prefix-affinity placement (`router.rs`): HRW over
//!        |            the request's prefix-chain hash, least-loaded for
//!        |            cold keys, backlog-aware spill to siblings
//!        +----------+----------+
//!        v          v          v
//!   replica 0   replica 1 ... replica N-1   (`--workers N`; each owns
//!        |            its own batcher — bounded priority queue with
//!        |            backpressure — PJRT Engine + checkpoint, shared
//!        |            paged KV pool, and `scheduler::SessionPool`
//!        |            round-robining one decode round per live
//!        |            `DecodeSession` per cycle, retiring finished
//!        |            sessions and admitting queued jobs between rounds)
//!        |
//!        v  per-request reply channel
//!   connection writer
//!
//! All replicas share one service epoch, so absolute deadlines and
//! per-class latency gauges are on a common clock and fleet aggregates
//! stay comparable. A replica that dies drains gracefully: its queued
//! jobs re-route to survivors and its in-flight sessions retire with an
//! error reply instead of hanging their connections.
//!
//! Every strategy (d3llm / d2f / ar / vanilla / fast-dllm / dparallel /
//! spec) decodes as a resumable `DecodeSession` over the unified
//! `DecodePolicy` API, so every request interleaves — one pool can even
//! mix strategies per request — and `SessionPool::step_round` coalesces
//! the same-shape forwards of a round into one batched backend call.
//! `spec` requests are admitted when the worker was started with a
//! `--draft` checkpoint (`ServerCfg::draft`); without one they fail
//! per-request. With `max_concurrent_sessions = 1` the worker
//! degenerates to the classic batch=1 loop token-for-token.
//!
//! With `kv_budget_mb > 0` the worker serves over a shared paged KV pool
//! (`model::kv_pool`): admission checks the page budget (jobs wait
//! queued under page pressure instead of failing), same-prefix requests
//! adopt already-prefilled pages — skipping their prompt-prefill forward
//! on a full-prefix hit — and retirement releases pages back to the
//! pool, keeping prefix-indexed ones reclaimable for future hits. Pool
//! occupancy and hit rates are exported through `{"cmd":"stats"}`.
//!
//! Serving is deadline-aware end to end: requests carry an SLO class
//! and/or an explicit `deadline_ms` budget (protocol.rs), the batcher
//! orders EDF within priority and sheds unmeetable work at admission
//! with a `retry_after_ms` hint (queue depth x observed round time, fed
//! back from the worker's own rounds), and the session pool schedules
//! runnable sessions EDF under `slo_round_width` pressure — overdue
//! sessions yield their round slot to work that can still make its
//! budget, and a preempted session simply pauses (sessions are
//! resumable, so pausing is *not scheduling a round*; resume is
//! bit-identical). Per-class served/shed/deadline-miss counters and
//! queue/decode latency land in `{"cmd":"stats"}`.
//!
//! The engine worker pre-compiles the executables its strategy needs, so
//! first-request latency is decode, not XLA compilation. Queue depth,
//! active-session count and per-session progress are exported through the
//! `{"cmd":"stats"}` protocol request.

pub mod batcher;
pub mod protocol;
pub mod router;
pub mod scheduler;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::decode::{self, AdaptiveCfg, AdaptiveController, DecodeCfg,
                    DecodeSession, LoadSignal, SessionProgress, Strategy,
                    WIDTH_HIST_BUCKETS};
use crate::model::kv_pool::{is_pool_exhausted, KvPoolCfg, SharedKvPool};
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;
use crate::train::TrainCfg;

use batcher::{Admission, Batcher};
use protocol::{GenRequest, GenResponse, Request, SloClass};
use scheduler::SessionPool;

#[derive(Debug, Clone)]
pub struct ServerCfg {
    pub host: String,
    pub port: u16,
    pub ckpt: String,
    pub strategy: Strategy,
    pub variant: String,
    pub max_queue: usize,
    /// Interleaving width: how many resumable decode sessions the engine
    /// worker keeps live at once (1 = classic batch=1 serving).
    pub max_concurrent_sessions: usize,
    /// Draft checkpoint name (under checkpoints/) for speculative
    /// decoding; `None` leaves `spec` requests unadmittable.
    pub draft: Option<String>,
    /// Shared paged KV pool budget in MiB; 0 serves with dense
    /// per-session caches (the pre-pool behavior).
    pub kv_budget_mb: usize,
    /// Sessions stepped per round under EDF pressure; 0 = unlimited
    /// (every runnable session steps, the pre-SLO behavior).
    pub slo_round_width: usize,
    /// Engine-worker replicas behind the fleet router (data parallel,
    /// each with its own engine + KV pool); 1 = the classic
    /// single-worker topology.
    pub workers: usize,
    /// Preemption spill threshold: a session paused this many consecutive
    /// rounds releases its paged KV to the reclaimable set and re-prefills
    /// on resume (prefix adoption makes that cheap); 0 disables spilling.
    pub spill_after_rounds: usize,
    /// Adaptive parallelism controller (`decode::adaptive`): mode `off`
    /// preserves the static decode path bit-for-bit; `load` couples
    /// thresholds and block widths to replica backlog, bounded by the
    /// config's hard accuracy floor.
    pub adaptive: AdaptiveCfg,
    /// full decode configuration; per-request `strategy` switches presets,
    /// otherwise this config is used verbatim
    pub decode: Option<crate::decode::DecodeCfg>,
}

/// One accepted generate request in flight between the router and a
/// replica (pub so `router.rs` can carry it through placement).
pub struct Job {
    pub req: GenRequest,
    pub reply: mpsc::Sender<String>,
}

/// Metadata carried through the session pool for each admitted job.
struct ActiveJob {
    reply: mpsc::Sender<String>,
    queue_ms: f64,
    class: SloClass,
}

#[derive(Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub queue_ms_total: AtomicU64,
    pub decode_ms_total: AtomicU64,
    /// Jobs waiting in the admission queue (gauge).
    pub queue_depth: AtomicU64,
    /// Live interleaved sessions (gauge).
    pub active_sessions: AtomicU64,
    /// Total session steps issued by the worker.
    pub steps_total: AtomicU64,
    /// Sessions ever admitted to the pool.
    pub admitted_total: AtomicU64,
    /// Configured interleaving width (set once at startup).
    pub max_concurrent: AtomicU64,
    // ---- SLO / admission counters
    /// Jobs turned away early with a retry-after hint (counter).
    pub shed_total: AtomicU64,
    /// Queued jobs displaced by a more urgent newcomer (counter).
    pub evicted_total: AtomicU64,
    /// Sessions retired past their deadline budget (counter).
    pub deadline_miss_total: AtomicU64,
    /// Runnable sessions left unscheduled by EDF width pressure (counter).
    pub preempted_rounds: AtomicU64,
    /// Per-class counters, indexed by `SloClass::idx()`.
    pub served_by_class: [AtomicU64; 3],
    pub shed_by_class: [AtomicU64; 3],
    pub deadline_miss_by_class: [AtomicU64; 3],
    /// Per-class latency totals (ms), for mean-latency gauges.
    pub queue_ms_by_class: [AtomicU64; 3],
    pub decode_ms_by_class: [AtomicU64; 3],
    // ---- paged KV pool gauges (all zero when serving dense)
    /// Page-budget ceiling of the shared KV pool.
    pub kv_pages_total: AtomicU64,
    /// Pages referenced by live sessions (gauge).
    pub kv_pages_in_use: AtomicU64,
    /// Retired-but-prefix-indexed pages kept for future hits (gauge).
    pub kv_pages_reclaimable: AtomicU64,
    /// Prompt pages adopted from the prefix index (counter).
    pub kv_prefix_hits: AtomicU64,
    /// Prompt-prefill forwards skipped via full-prefix hits (counter).
    pub kv_prefill_skips: AtomicU64,
    /// Pages rewritten by KV-refresh installs (counter).
    pub kv_pages_refreshed: AtomicU64,
    /// Pages skipped by incremental refresh (counter).
    pub kv_refresh_skips: AtomicU64,
    /// Copy-on-write page copies (counter).
    pub kv_cow_copies: AtomicU64,
    /// Pages released back to the pool by preemption spill (counter).
    pub kv_pages_spilled: AtomicU64,
    /// Spilled pages rebuilt by re-prefill at resume, i.e. not re-adopted
    /// from the prefix index (counter).
    pub kv_pages_reprefilled: AtomicU64,
    // ---- adaptive parallelism controller (all zero in `off` mode)
    /// Last emitted selection threshold x1000 (gauge, on the emitting
    /// session's metric scale).
    pub adaptive_threshold_milli: AtomicU64,
    /// Budget adjustments toward throughput — width widened (counter).
    pub adaptive_up: AtomicU64,
    /// Budget adjustments toward accuracy — width narrowed (counter).
    pub adaptive_down: AtomicU64,
    /// Histogram of emitted block widths (bucket = `min(width, 7)`).
    pub adaptive_width_hist: [AtomicU64; WIDTH_HIST_BUCKETS],
    /// Per-session progress snapshots, refreshed every worker cycle.
    pub sessions: Mutex<Vec<(String, SessionProgress)>>,
}

/// Run the server until a shutdown request arrives.
pub fn serve(cfg: ServerCfg) -> Result<()> {
    let addr = format!("{}:{}", cfg.host, cfg.port);
    let listener =
        TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
    let workers = cfg.workers.max(1);
    eprintln!(
        "[serve] listening on {addr} (ckpt={}, strategy={}, sessions={}, \
         workers={workers})",
        cfg.ckpt,
        cfg.strategy.name(),
        cfg.max_concurrent_sessions
    );

    let core = Arc::new(router::RouterCore::new(workers, cfg.max_queue));
    let shutdown = Arc::new(AtomicBool::new(false));
    // one service epoch shared by every replica: absolute deadlines and
    // per-class latency gauges are on a common clock fleet-wide
    let epoch = Instant::now();

    let mut senders = Vec::with_capacity(workers);
    let mut receivers = Vec::with_capacity(workers);
    let mut replicas: Vec<Arc<ServerStats>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = mpsc::channel::<Job>();
        senders.push(tx);
        receivers.push(rx);
        let stats = Arc::new(ServerStats::default());
        stats.max_concurrent.store(
            cfg.max_concurrent_sessions.max(1) as u64, Ordering::Relaxed);
        replicas.push(stats);
    }
    let rt = Arc::new(router::Router::new(core.clone(), senders));
    let replicas = Arc::new(replicas);

    // ---- engine-worker replicas (each owns its non-Sync PJRT engine)
    let mut handles = Vec::with_capacity(workers);
    for (r, rx) in receivers.into_iter().enumerate() {
        let wcfg = cfg.clone();
        let wstats = replicas[r].clone();
        let wshutdown = shutdown.clone();
        let wrouter = rt.clone();
        let gauge = core.gauge(r);
        handles.push(std::thread::spawn(move || {
            engine_worker(r, wcfg, rx, wstats, gauge, wrouter, wshutdown,
                          epoch);
        }));
    }

    // routing-key context: only worth loading when placement has a choice
    // and a paged pool to be affine to; absent artifacts degrade every
    // placement to cold/least-loaded
    let keyctx = if workers > 1 && cfg.kv_budget_mb > 0 {
        router::RouteKeyCtx::load("artifacts").map(Arc::new)
    } else {
        None
    };

    // ---- accept loop
    listener.set_nonblocking(true)?;
    loop {
        // ordering: SeqCst load pairs with the Shutdown request's store —
        // a single flag with no dependent data, so any ordering is
        // correct; SeqCst documents "not a perf-sensitive gauge"
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn_cfg = cfg.clone();
                let conn_rt = rt.clone();
                let conn_replicas = replicas.clone();
                let conn_key = keyctx.clone();
                let sd = shutdown.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, conn_cfg, conn_rt,
                                                conn_replicas, conn_key, sd)
                    {
                        eprintln!("[serve] connection error: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    rt.close_intake();
    for h in handles {
        let _ = h.join();
    }
    eprintln!("[serve] shut down cleanly");
    Ok(())
}

fn handle_conn(stream: TcpStream, cfg: ServerCfg,
               rt: Arc<router::Router>,
               replicas: Arc<Vec<Arc<ServerStats>>>,
               keyctx: Option<Arc<router::RouteKeyCtx>>,
               shutdown: Arc<AtomicBool>)
               -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_request(&line) {
            Ok(Request::Shutdown) => {
                // ordering: SeqCst store publishes the shutdown flag to
                // the accept loop and every replica loop (see the paired
                // loads); plain flag, correctness not ordering-sensitive
                shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", protocol::err_response("", "shutting down"))?;
                break;
            }
            Ok(Request::Stats) => {
                writeln!(writer, "{}",
                         protocol::fleet_stats_response(&replicas,
                                                        rt.core()))?;
            }
            Ok(Request::Generate(req)) => {
                let key =
                    keyctx.as_ref().and_then(|kc| kc.key_for(&cfg, &req));
                let budget_ms = req.deadline_ms;
                let (reply_tx, reply_rx) = mpsc::channel();
                if let Err(e) =
                    rt.dispatch(key, budget_ms, Job { req, reply: reply_tx })
                {
                    writeln!(writer, "{}",
                             protocol::err_response("", &format!("{e}")))?;
                    continue;
                }
                let response = reply_rx
                    .recv()
                    .unwrap_or_else(|_| protocol::err_response("", "worker died"));
                writeln!(writer, "{response}")?;
            }
            Err(e) => {
                rt.core().conn_errors.fetch_add(1, Ordering::Relaxed);
                writeln!(writer, "{}", protocol::err_response("", &format!("{e}")))?;
            }
        }
    }
    let _ = peer;
    Ok(())
}

/// Resolve the effective decode config for one request.
fn request_cfg(cfg: &ServerCfg, req: &GenRequest) -> Result<DecodeCfg> {
    let mut dcfg = match (&req.strategy, &cfg.decode) {
        (Some(s), _) => DecodeCfg::preset(
            Strategy::parse(s).ok_or_else(|| anyhow!("bad strategy"))?),
        (None, Some(d)) => d.clone(),
        (None, None) => DecodeCfg::preset(cfg.strategy),
    };
    dcfg.variant = cfg.variant.clone();
    Ok(dcfg)
}

/// Shared request preamble for both decode paths: tokenize the prompt and
/// clamp the requested generation length to the lowered geometry.
fn prepare_request(eng: &Engine, tk: &Tokenizer, req: &GenRequest)
                   -> Result<(Vec<i32>, usize)> {
    let prompt = tk.encode(&req.prompt)?;
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    let c = &eng.manifest.constants;
    let gen_len = req
        .gen_len
        .unwrap_or(96)
        .min(c.gen_max)
        .next_multiple_of(c.block)
        .min(c.s_max.saturating_sub(prompt.len()) / c.block * c.block);
    if gen_len == 0 {
        return Err(anyhow!("prompt too long"));
    }
    Ok((prompt, gen_len))
}

/// Admission decision for the peeked queue head.
enum Verdict {
    /// Build and admit a session now (resolved request geometry).
    Admit(DecodeCfg, Vec<i32>, usize),
    /// Malformed or unserveable request: pop and answer the error.
    Reject(anyhow::Error),
    /// Valid but no page budget yet: leave queued, stop admitting.
    Wait,
}

/// One replica's thread body: run the engine loop, and on a fatal error
/// drain gracefully — mark the replica dead (so the router stops placing
/// here and re-routes can't bounce back), retire in-flight sessions with
/// an error reply instead of hanging their connections, and re-route
/// every salvaged queued job to the surviving replicas.
fn engine_worker(replica: usize, cfg: ServerCfg, jobs: mpsc::Receiver<Job>,
                 stats: Arc<ServerStats>, gauge: Arc<router::ReplicaGauge>,
                 rt: Arc<router::Router>, shutdown: Arc<AtomicBool>,
                 epoch: Instant) {
    let mut batcher: Batcher<Job> = Batcher::new(cfg.max_queue);
    let mut pool: SessionPool<ActiveJob> = SessionPool::new();
    let result = run_replica(replica, &cfg, &jobs, &mut batcher, &mut pool,
                             &stats, &gauge, &shutdown, epoch);
    match result {
        Ok(()) => {
            // clean exit (shutdown or intake drained): queue and pool are
            // empty by contract, nothing to salvage
            // ordering: SeqCst matches RouterCore::alive/mark_dead, so a
            // drained replica is never re-elected by a racing placement
            gauge.alive.store(false, Ordering::SeqCst);
        }
        Err(e) => {
            eprintln!("[serve] replica {replica} failed: {e:#}");
            rt.drop_replica(replica);
            for (id, tag) in pool.drain_sessions() {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tag.reply.send(protocol::err_response(
                    &id, "replica failed; session aborted"));
            }
            let mut salvaged: Vec<Job> = Vec::new();
            while let Some(q) = batcher.pop() {
                salvaged.push(q.payload);
            }
            while let Ok(job) = jobs.try_recv() {
                salvaged.push(job);
            }
            for job in salvaged {
                if let Err(job) = rt.reroute(job) {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(protocol::err_response(
                        &job.req.id, "no live replicas"));
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_replica(replica: usize, cfg: &ServerCfg, jobs: &mpsc::Receiver<Job>,
               batcher: &mut Batcher<Job>,
               pool: &mut SessionPool<ActiveJob>, stats: &ServerStats,
               gauge: &router::ReplicaGauge, shutdown: &AtomicBool,
               epoch: Instant) -> Result<()> {
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    let tk = Tokenizer::new(c.vocab)?;
    let params = ParamStore::load(TrainCfg::ckpt_path(
        std::path::Path::new("checkpoints"),
        &cfg.ckpt,
    ))?;
    params.check(eng.manifest.model("main")?)?;

    // optional draft checkpoint: with it loaded, `spec` requests admit
    // like any other strategy (DecodeSession::with_draft)
    let draft_params = match &cfg.draft {
        Some(name) => {
            let ps = ParamStore::load(TrainCfg::ckpt_path(
                std::path::Path::new("checkpoints"),
                name,
            ))?;
            if let Ok(spec) = eng.manifest.model("draft") {
                ps.check(spec)?;
            }
            eprintln!(
                "[serve] draft checkpoint `{name}` loaded (spec decoding \
                 enabled)"
            );
            Some(ps)
        }
        None => None,
    };

    // shared paged KV pool (page size = decode block, budget in MiB)
    let kv_pool = if cfg.kv_budget_mb > 0 {
        let spec = eng.manifest.model("main")?;
        let pool_cfg = KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: cfg.kv_budget_mb << 20,
        };
        let pool = SharedKvPool::new(pool_cfg);
        eprintln!(
            "[serve] replica {replica}: paged KV pool: {} pages of {} rows \
             ({} MiB budget)",
            pool.max_pages(), c.block, cfg.kv_budget_mb
        );
        Some(pool)
    } else {
        None
    };

    // pre-compile every admissible strategy's executables once (any
    // request may switch strategy per-request, and a compile inside the
    // serving round would stall the whole interleaved pool). The
    // configured strategy's executables stay fail-fast at startup; other
    // strategies' names absent from the manifest are skipped, their
    // requests will fail per-request instead.
    let mut execs = decode::strategy_exec_names(cfg.strategy, &cfg.variant);
    for s in Strategy::ALL {
        if s == cfg.strategy {
            continue;
        }
        for name in decode::strategy_exec_names(s, &cfg.variant) {
            if !execs.contains(&name) && eng.manifest.exec(&name).is_ok() {
                execs.push(name);
            }
        }
    }
    let exec_refs: Vec<&str> = execs.iter().map(|s| s.as_str()).collect();
    eng.warmup(&exec_refs)?;
    eprintln!("[serve] replica {replica}: engine ready ({} executables warm)",
              exec_refs.len());

    let max_live = cfg.max_concurrent_sessions.max(1);
    *pool = match &kv_pool {
        Some(kv) => SessionPool::new().with_kv_pool(kv.clone()),
        None => SessionPool::new(),
    };
    pool.set_round_width(cfg.slo_round_width);
    pool.set_spill_after_rounds(cfg.spill_after_rounds);
    // per-replica adaptive parallelism controller: in `load` mode it
    // couples selection thresholds / block widths to this replica's
    // backlog (hard accuracy floor enforced inside `budget_for`); in
    // `off` mode it never emits a budget and decoding is bit-identical
    // to the static configuration
    let mut ctrl = AdaptiveController::new(cfg.adaptive.clone());
    if ctrl.cfg.pool_full == 0 {
        // a full session pool is load even when the queue has drained:
        // default the occupancy term to this replica's pool capacity
        ctrl.cfg.pool_full = cfg.max_concurrent_sessions;
    }
    if ctrl.enabled() {
        eprintln!(
            "[serve] replica {replica}: adaptive controller on \
             (mode={}, conf_floor={}, entropy_ceiling={})",
            ctrl.cfg.mode.name(), ctrl.cfg.conf_floor,
            ctrl.cfg.entropy_ceiling
        );
    }
    let mut disconnected = false;
    // serving clock: wall milliseconds on the fleet-shared service epoch
    // (every replica reads the same `epoch`, so absolute deadlines and
    // per-class latency aggregates are comparable across the fleet);
    // tests/benches drive a virtual clock instead

    loop {
        // ordering: SeqCst load pairs with the Shutdown request's store
        // (see handle_conn); plain flag, once per scheduling round
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let now_ms = epoch.elapsed().as_millis() as u64;
        pool.set_now_ms(now_ms);
        // ---- drain the channel into the priority queue (deadline-aware
        //      admission: on overflow the least-urgent job — newcomer or
        //      queued — is answered with a retry-after hint and dropped)
        loop {
            match jobs.try_recv() {
                Ok(job) => admit_to_queue(batcher, stats, job, now_ms),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }

        // ---- admit queued jobs: every strategy is a resumable policy
        //      session, so everything joins the interleaving pool. The
        //      queue head is *peeked* for the page-budget check, so a
        //      request waiting for pages keeps its FIFO position and its
        //      enqueue timestamp (strict head-of-line order within
        //      priority — later small requests cannot starve it). A
        //      waiting head re-resolves its geometry each cycle and an
        //      admitted one probes the prefix index up to three times
        //      (required_pages_for + can_admit + PagedKv::admit) — each
        //      O(prompt_len) on one request per cycle, accepted to keep
        //      required_pages the single source of truth inside the pool.
        while pool.len() < max_live {
            let verdict = match batcher.peek() {
                None => break,
                Some(queued) => {
                    let req = &queued.payload.req;
                    match request_cfg(&cfg, req).and_then(|dcfg| {
                        prepare_request(&eng, &tk, req)
                            .map(|(prompt, gen_len)| (dcfg, prompt, gen_len))
                    }) {
                        Err(e) => Verdict::Reject(e),
                        Ok((dcfg, prompt, gen_len)) => {
                            match pool.kv_pool() {
                                None => {
                                    Verdict::Admit(dcfg, prompt, gen_len)
                                }
                                Some(kv) => {
                                    // admission checks the page budget: a
                                    // request that could never fit fails
                                    // fast; one that can fit later stays
                                    // queued (reclaimable pages are
                                    // evicted on demand by the allocator,
                                    // so they never block admission). The
                                    // never-fits bound charges the pages
                                    // the request would actually draw —
                                    // prefix pages expected to be adopted
                                    // from an indexed chain are credited
                                    // (`required_pages_for`), so prefix-
                                    // heavy requests whose no-sharing
                                    // worst case exceeds the budget still
                                    // admit while their chain is indexed;
                                    // if the chain is evicted the bound
                                    // degrades to the worst case on the
                                    // next cycle's re-probe.
                                    let geo = decode::kv_admission_geometry(
                                        &dcfg, &c, prompt.len(), gen_len);
                                    if kv.required_pages_for(
                                        &prompt, &geo.prefix_tag,
                                        geo.prefix_rows, geo.span_rows,
                                        geo.causal_prefix)
                                        > kv.max_pages()
                                    {
                                        Verdict::Reject(anyhow!(
                                            "request span exceeds the kv \
                                             pool budget"))
                                    } else if !kv.can_admit(
                                        &prompt, &geo.prefix_tag,
                                        geo.prefix_rows, geo.span_rows,
                                        geo.causal_prefix)
                                    {
                                        Verdict::Wait
                                    } else {
                                        Verdict::Admit(dcfg, prompt,
                                                       gen_len)
                                    }
                                }
                            }
                        }
                    }
                }
            };
            match verdict {
                // no page budget right now: leave the head queued (seq +
                // queue-time intact) until sessions retire
                Verdict::Wait => break,
                Verdict::Reject(e) => {
                    // the head we just peeked; if the queue somehow raced
                    // empty, stop admitting this cycle instead of dying
                    let Some(queued) = batcher.pop() else { break };
                    reply_err(stats, &queued.payload, &e);
                }
                Verdict::Admit(dcfg, prompt, gen_len) => {
                    // build the session BEFORE popping the queue head, so
                    // a page-budget failure between the `can_admit` probe
                    // and `PagedKv::admit` (e.g. the prefix chain was
                    // evicted mid-round and the requirement grew) leaves
                    // the request queued with its FIFO slot and enqueue
                    // timestamp intact instead of killing it
                    let draft =
                        draft_params.as_ref().map(|d| d.data.as_slice());
                    let admitted = match pool.kv_pool() {
                        Some(kv) => {
                            let kv = kv.clone();
                            DecodeSession::with_pool(&eng, dcfg, &prompt,
                                                     gen_len, draft, &kv)
                        }
                        None => DecodeSession::with_draft(&eng, dcfg,
                                                          &prompt, gen_len,
                                                          draft),
                    };
                    match admitted {
                        Ok(session) => {
                            // the peeked head; dropping the just-built
                            // session releases its pages if this races
                            let Some(queued) = batcher.pop() else {
                                break;
                            };
                            let queue_ms = queued.queue_ms();
                            let deadline_at_ms = queued.deadline_at_ms;
                            let job = queued.payload;
                            pool.admit_deadline(
                                job.req.id.clone(),
                                ActiveJob {
                                    reply: job.reply,
                                    queue_ms,
                                    class: job.req.slo,
                                },
                                session,
                                deadline_at_ms,
                            );
                        }
                        Err(e) if is_pool_exhausted(&e)
                            && !pool.is_empty() =>
                        {
                            // conservative fallback: wait for live
                            // sessions to release pages, then re-probe
                            break;
                        }
                        Err(e) => {
                            let Some(queued) = batcher.pop() else {
                                break;
                            };
                            reply_err(stats, &queued.payload, &e);
                        }
                    }
                }
            }
        }

        // ---- publish gauges + per-session progress (the pool is the
        //      single source of truth for its own counters)
        stats.queue_depth.store(batcher.len() as u64, Ordering::Relaxed);
        stats
            .active_sessions
            .store(pool.len() as u64, Ordering::Relaxed);
        // load snapshot the router places by (same figures the stats
        // protocol exports, read lock-free by the acceptor side)
        gauge.queue_depth.store(batcher.len() as u64, Ordering::Relaxed);
        gauge
            .active_sessions
            .store(pool.len() as u64, Ordering::Relaxed);
        gauge.est_wait_ms.store(batcher.estimated_wait_ms().ceil() as u64,
                                Ordering::Relaxed);
        stats.steps_total.store(pool.steps_total, Ordering::Relaxed);
        stats
            .admitted_total
            .store(pool.admitted_total, Ordering::Relaxed);
        stats.shed_total.store(batcher.shed_total, Ordering::Relaxed);
        stats
            .evicted_total
            .store(batcher.evicted_total, Ordering::Relaxed);
        stats
            .deadline_miss_total
            .store(pool.deadline_miss_total, Ordering::Relaxed);
        stats
            .preempted_rounds
            .store(pool.preempted_total, Ordering::Relaxed);
        if let Ok(mut s) = stats.sessions.lock() {
            *s = pool.progress();
        }
        if let Some(kv) = pool.kv_pool() {
            let u = kv.usage();
            let ks = kv.stats();
            stats.kv_pages_total.store(u.max_pages as u64,
                                       Ordering::Relaxed);
            stats.kv_pages_in_use.store(u.in_use as u64, Ordering::Relaxed);
            stats
                .kv_pages_reclaimable
                .store(u.reclaimable as u64, Ordering::Relaxed);
            stats.kv_prefix_hits.store(ks.prefix_hits, Ordering::Relaxed);
            stats
                .kv_prefill_skips
                .store(ks.prefill_skips, Ordering::Relaxed);
            stats
                .kv_pages_refreshed
                .store(ks.pages_refreshed, Ordering::Relaxed);
            stats
                .kv_refresh_skips
                .store(ks.refresh_skips, Ordering::Relaxed);
            stats.kv_cow_copies.store(ks.cow_copies, Ordering::Relaxed);
            stats
                .kv_pages_spilled
                .store(ks.pages_spilled, Ordering::Relaxed);
            stats
                .kv_pages_reprefilled
                .store(ks.pages_reprefilled, Ordering::Relaxed);
        }

        if pool.is_empty() {
            // only block when there is truly nothing to do; with jobs
            // still queued, loop straight back into admission
            if batcher.is_empty() {
                if disconnected {
                    return Ok(());
                }
                match jobs.recv_timeout(std::time::Duration::from_millis(50))
                {
                    Ok(job) => {
                        // the blocking wait advanced the clock; deadline
                        // admission must see the post-sleep time
                        let now_ms = epoch.elapsed().as_millis() as u64;
                        admit_to_queue(batcher, stats, job, now_ms);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Ok(());
                    }
                }
            }
            continue;
        }

        // ---- adaptive budgets: observe this round's load, hand each
        //      live session its budget, and export the controller gauges
        if ctrl.enabled() {
            ctrl.observe(&LoadSignal {
                queue_depth: batcher.len(),
                active_sessions: pool.len(),
                est_wait_ms: batcher.estimated_wait_ms(),
                round_ms: batcher.round_ms(),
            });
            pool.set_budgets(|dcfg, res| {
                ctrl.budget_for(dcfg.metric, res.mean_commit_entropy())
            });
            publish_adaptive(stats, &ctrl);
        }

        // ---- one interleaved round: each live session advances one step
        //      (its duration feeds the batcher's shed/retry estimate)
        let t_round = Instant::now();
        let finished = pool.step_round(&eng, &params.data);
        batcher.observe_round_ms(t_round.elapsed().as_secs_f64() * 1e3);
        for f in finished {
            let line = match f.result {
                Ok(r) => {
                    let resp = GenResponse {
                        id: f.id.clone(),
                        text: tk.decode(&r.tokens),
                        tpf: r.tpf(),
                        forwards: r.forwards,
                        gen_tokens: r.tokens.len(),
                        tokens: r.tokens,
                        queue_ms: f.tag.queue_ms,
                        // engine time of this session's own steps (its
                        // share of batched forwards included)
                        decode_ms: f.busy_secs * 1e3,
                        slo: f.tag.class.name().to_string(),
                        deadline_missed: f.deadline_missed,
                    };
                    record_served(stats, &resp, f.tag.class);
                    protocol::ok_response(&resp)
                }
                Err(e) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    protocol::err_response(&f.id, &format!("{e:#}"))
                }
            };
            let _ = f.tag.reply.send(line);
        }
    }
    Ok(())
}

fn reply_err(stats: &ServerStats, job: &Job, e: &anyhow::Error) {
    stats.errors.fetch_add(1, Ordering::Relaxed);
    let _ = job
        .reply
        .send(protocol::err_response(&job.req.id, &format!("{e:#}")));
}

/// Bounds-checked per-class counter bump: the `*_by_class` arrays are
/// indexed by `SloClass::idx()`, in range by construction, but the
/// serving path must stay panic-free — an out-of-range bump is dropped.
fn bump_class(arr: &[std::sync::atomic::AtomicU64], i: usize, v: u64) {
    if let Some(a) = arr.get(i) {
        a.fetch_add(v, Ordering::Relaxed);
    }
}

/// Run one incoming job through deadline-aware queue admission. Displaced
/// and shed work is answered immediately with a `retry_after_ms` hint (the
/// estimated queue drain time) and counted against its SLO class.
fn admit_to_queue(batcher: &mut Batcher<Job>, stats: &ServerStats, job: Job,
                  now_ms: u64) {
    let pri = job.req.priority;
    let deadline_at_ms = job.req.deadline_ms.map(|b| now_ms + b);
    match batcher.admit(job, pri, deadline_at_ms, now_ms) {
        Admission::Admitted(None) => {}
        Admission::Admitted(Some(evicted)) => {
            let retry = batcher.estimated_wait_ms().max(1.0).ceil() as u64;
            let j = evicted.payload;
            bump_class(&stats.shed_by_class, j.req.slo.idx(), 1);
            let _ = j.reply.send(protocol::shed_response(
                &j.req.id,
                "displaced by higher-priority load",
                retry,
            ));
        }
        Admission::Shed { payload: j, retry_after_ms } => {
            bump_class(&stats.shed_by_class, j.req.slo.idx(), 1);
            let _ = j.reply.send(protocol::shed_response(
                &j.req.id,
                "queue overloaded",
                retry_after_ms,
            ));
        }
    }
}

/// Export the adaptive controller's gauges into the replica stats (read
/// by the `{"cmd":"stats"}` protocol).
fn publish_adaptive(stats: &ServerStats, ctrl: &AdaptiveController) {
    let g = &ctrl.gauges;
    stats
        .adaptive_threshold_milli
        .store(g.threshold_milli, Ordering::Relaxed);
    stats.adaptive_up.store(g.adjust_up, Ordering::Relaxed);
    stats.adaptive_down.store(g.adjust_down, Ordering::Relaxed);
    for (slot, v) in stats.adaptive_width_hist.iter().zip(g.width_hist) {
        slot.store(v, Ordering::Relaxed);
    }
}

fn record_served(stats: &ServerStats, r: &GenResponse, class: SloClass) {
    stats.served.fetch_add(1, Ordering::Relaxed);
    stats
        .queue_ms_total
        .fetch_add(r.queue_ms as u64, Ordering::Relaxed);
    stats
        .decode_ms_total
        .fetch_add(r.decode_ms as u64, Ordering::Relaxed);
    let i = class.idx();
    bump_class(&stats.served_by_class, i, 1);
    bump_class(&stats.queue_ms_by_class, i, r.queue_ms as u64);
    bump_class(&stats.decode_ms_by_class, i, r.decode_ms as u64);
    if r.deadline_missed {
        bump_class(&stats.deadline_miss_by_class, i, 1);
    }
}

/// Blocking client helper (examples + integration tests).
pub fn client_request(addr: &str, line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Ok(resp.trim().to_string())
}
