//! Serving coordinator: a threaded JSON-line TCP server in front of an
//! interleaved multi-session decode engine.
//!
//! Topology (the offline registry has no tokio; std threads + channels):
//!
//!   acceptor thread --- per-connection reader threads
//!        |  (mpsc)                |  parse JSON-line requests
//!        v                        v
//!   router/batcher  <-- bounded priority queue, backpressure
//!        |   admit up to `max_concurrent_sessions`
//!        v
//!   engine worker (owns PJRT Engine + checkpoint; round-robins one
//!        |          decode round per live `DecodeSession` per cycle —
//!        |          `scheduler::SessionPool` — retiring finished
//!        |          sessions and admitting queued jobs between rounds)
//!        |
//!        v  per-request reply channel
//!   connection writer
//!
//! Every strategy (d3llm / d2f / ar / vanilla / fast-dllm / dparallel /
//! spec) decodes as a resumable `DecodeSession` over the unified
//! `DecodePolicy` API, so every request interleaves — one pool can even
//! mix strategies per request — and `SessionPool::step_round` coalesces
//! the same-shape forwards of a round into one batched backend call.
//! (`spec` sessions need a draft checkpoint the worker does not load
//! yet, so spec requests fail at admission — see the ROADMAP `--draft`
//! item.) With `max_concurrent_sessions = 1` the worker degenerates to
//! the classic batch=1 loop token-for-token.
//!
//! The engine worker pre-compiles the executables its strategy needs, so
//! first-request latency is decode, not XLA compilation. Queue depth,
//! active-session count and per-session progress are exported through the
//! `{"cmd":"stats"}` protocol request.

pub mod batcher;
pub mod protocol;
pub mod scheduler;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::decode::{self, DecodeCfg, DecodeSession, SessionProgress,
                    Strategy};
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;
use crate::train::TrainCfg;

use batcher::{Admission, Batcher};
use protocol::{GenRequest, GenResponse, Request};
use scheduler::SessionPool;

#[derive(Debug, Clone)]
pub struct ServerCfg {
    pub host: String,
    pub port: u16,
    pub ckpt: String,
    pub strategy: Strategy,
    pub variant: String,
    pub max_queue: usize,
    /// Interleaving width: how many resumable decode sessions the engine
    /// worker keeps live at once (1 = classic batch=1 serving).
    pub max_concurrent_sessions: usize,
    /// full decode configuration; per-request `strategy` switches presets,
    /// otherwise this config is used verbatim
    pub decode: Option<crate::decode::DecodeCfg>,
}

struct Job {
    req: GenRequest,
    reply: mpsc::Sender<String>,
}

/// Metadata carried through the session pool for each admitted job.
struct ActiveJob {
    reply: mpsc::Sender<String>,
    queue_ms: f64,
}

#[derive(Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub queue_ms_total: AtomicU64,
    pub decode_ms_total: AtomicU64,
    /// Jobs waiting in the admission queue (gauge).
    pub queue_depth: AtomicU64,
    /// Live interleaved sessions (gauge).
    pub active_sessions: AtomicU64,
    /// Total session steps issued by the worker.
    pub steps_total: AtomicU64,
    /// Sessions ever admitted to the pool.
    pub admitted_total: AtomicU64,
    /// Configured interleaving width (set once at startup).
    pub max_concurrent: AtomicU64,
    /// Per-session progress snapshots, refreshed every worker cycle.
    pub sessions: Mutex<Vec<(String, SessionProgress)>>,
}

/// Run the server until a shutdown request arrives.
pub fn serve(cfg: ServerCfg) -> Result<()> {
    let addr = format!("{}:{}", cfg.host, cfg.port);
    let listener =
        TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
    eprintln!(
        "[serve] listening on {addr} (ckpt={}, strategy={}, sessions={})",
        cfg.ckpt,
        cfg.strategy.name(),
        cfg.max_concurrent_sessions
    );

    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let stats = Arc::new(ServerStats::default());
    stats
        .max_concurrent
        .store(cfg.max_concurrent_sessions.max(1) as u64, Ordering::Relaxed);
    let shutdown = Arc::new(AtomicBool::new(false));

    // ---- engine worker (owns the non-Sync PJRT engine)
    let worker_cfg = cfg.clone();
    let worker_stats = stats.clone();
    let worker_shutdown = shutdown.clone();
    let worker = std::thread::spawn(move || {
        if let Err(e) =
            engine_worker(worker_cfg, job_rx, worker_stats, worker_shutdown)
        {
            eprintln!("[serve] engine worker failed: {e:#}");
        }
    });

    // ---- accept loop
    listener.set_nonblocking(true)?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = job_tx.clone();
                let st = stats.clone();
                let sd = shutdown.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, tx, st, sd) {
                        eprintln!("[serve] connection error: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    drop(job_tx);
    let _ = worker.join();
    eprintln!("[serve] shut down cleanly");
    Ok(())
}

fn handle_conn(stream: TcpStream, jobs: mpsc::Sender<Job>,
               stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>)
               -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_request(&line) {
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", protocol::err_response("", "shutting down"))?;
                break;
            }
            Ok(Request::Stats) => {
                writeln!(writer, "{}", protocol::stats_response(&stats))?;
            }
            Ok(Request::Generate(req)) => {
                let (reply_tx, reply_rx) = mpsc::channel();
                jobs.send(Job { req, reply: reply_tx })
                    .map_err(|_| anyhow!("engine worker gone"))?;
                let response = reply_rx
                    .recv()
                    .unwrap_or_else(|_| protocol::err_response("", "worker died"));
                writeln!(writer, "{response}")?;
            }
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                writeln!(writer, "{}", protocol::err_response("", &format!("{e}")))?;
            }
        }
    }
    let _ = peer;
    Ok(())
}

/// Resolve the effective decode config for one request.
fn request_cfg(cfg: &ServerCfg, req: &GenRequest) -> Result<DecodeCfg> {
    let mut dcfg = match (&req.strategy, &cfg.decode) {
        (Some(s), _) => DecodeCfg::preset(
            Strategy::parse(s).ok_or_else(|| anyhow!("bad strategy"))?),
        (None, Some(d)) => d.clone(),
        (None, None) => DecodeCfg::preset(cfg.strategy),
    };
    dcfg.variant = cfg.variant.clone();
    Ok(dcfg)
}

/// Shared request preamble for both decode paths: tokenize the prompt and
/// clamp the requested generation length to the lowered geometry.
fn prepare_request(eng: &Engine, tk: &Tokenizer, req: &GenRequest)
                   -> Result<(Vec<i32>, usize)> {
    let prompt = tk.encode(&req.prompt)?;
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    let c = &eng.manifest.constants;
    let gen_len = req
        .gen_len
        .unwrap_or(96)
        .min(c.gen_max)
        .next_multiple_of(c.block)
        .min(c.s_max.saturating_sub(prompt.len()) / c.block * c.block);
    if gen_len == 0 {
        return Err(anyhow!("prompt too long"));
    }
    Ok((prompt, gen_len))
}

fn engine_worker(cfg: ServerCfg, jobs: mpsc::Receiver<Job>,
                 stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>)
                 -> Result<()> {
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    let tk = Tokenizer::new(c.vocab)?;
    let params = ParamStore::load(TrainCfg::ckpt_path(
        std::path::Path::new("checkpoints"),
        &cfg.ckpt,
    ))?;
    params.check(eng.manifest.model("main")?)?;

    // pre-compile every admissible strategy's executables once (any
    // request may switch strategy per-request, and a compile inside the
    // serving round would stall the whole interleaved pool). The
    // configured strategy's executables stay fail-fast at startup; other
    // strategies' names absent from the manifest are skipped, their
    // requests will fail per-request instead.
    let mut execs = decode::strategy_exec_names(cfg.strategy, &cfg.variant);
    for s in Strategy::ALL {
        if s == cfg.strategy {
            continue;
        }
        for name in decode::strategy_exec_names(s, &cfg.variant) {
            if !execs.contains(&name) && eng.manifest.exec(&name).is_ok() {
                execs.push(name);
            }
        }
    }
    let exec_refs: Vec<&str> = execs.iter().map(|s| s.as_str()).collect();
    eng.warmup(&exec_refs)?;
    eprintln!("[serve] engine ready ({} executables warm)", exec_refs.len());

    let max_live = cfg.max_concurrent_sessions.max(1);
    let mut batcher: Batcher<Job> = Batcher::new(cfg.max_queue);
    let mut pool: SessionPool<ActiveJob> = SessionPool::new();
    let mut disconnected = false;

    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // ---- drain the channel into the priority queue
        loop {
            match jobs.try_recv() {
                Ok(job) => {
                    let pri = job.req.priority;
                    // priority-aware backpressure: on overflow the lowest
                    // ranked job (newcomer or queued) is answered and
                    // dropped
                    match batcher.push_evicting(job, pri) {
                        Admission::Admitted(None) => {}
                        Admission::Admitted(Some(evicted)) => {
                            stats.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = evicted.payload.reply.send(
                                protocol::err_response(
                                    &evicted.payload.req.id,
                                    "queue full (displaced by higher \
                                     priority)",
                                ),
                            );
                        }
                        Admission::Rejected(job) => {
                            stats.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = job.reply.send(protocol::err_response(
                                &job.req.id,
                                "queue full",
                            ));
                        }
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }

        // ---- admit queued jobs: every strategy is a resumable policy
        //      session, so everything joins the interleaving pool
        while pool.len() < max_live {
            let Some(queued) = batcher.pop() else { break };
            let queue_ms = queued.enqueued.elapsed().as_secs_f64() * 1e3;
            let job = queued.payload;
            let admitted = request_cfg(&cfg, &job.req)
                .and_then(|dcfg| admit_session(&eng, &tk, &dcfg, &job.req));
            match admitted {
                Ok(session) => {
                    pool.admit(
                        job.req.id.clone(),
                        ActiveJob { reply: job.reply, queue_ms },
                        session,
                    );
                }
                Err(e) => reply_err(&stats, &job, &e),
            }
        }

        // ---- publish gauges + per-session progress (the pool is the
        //      single source of truth for its own counters)
        stats.queue_depth.store(batcher.len() as u64, Ordering::Relaxed);
        stats
            .active_sessions
            .store(pool.len() as u64, Ordering::Relaxed);
        stats.steps_total.store(pool.steps_total, Ordering::Relaxed);
        stats
            .admitted_total
            .store(pool.admitted_total, Ordering::Relaxed);
        if let Ok(mut s) = stats.sessions.lock() {
            *s = pool.progress();
        }

        if pool.is_empty() {
            // only block when there is truly nothing to do; with jobs
            // still queued, loop straight back into admission
            if batcher.is_empty() {
                if disconnected {
                    return Ok(());
                }
                match jobs.recv_timeout(std::time::Duration::from_millis(50))
                {
                    Ok(job) => {
                        let pri = job.req.priority;
                        batcher.push(job, pri);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Ok(());
                    }
                }
            }
            continue;
        }

        // ---- one interleaved round: each live session advances one step
        let finished = pool.step_round(&eng, &params.data);
        for f in finished {
            let line = match f.result {
                Ok(r) => {
                    let resp = GenResponse {
                        id: f.id.clone(),
                        text: tk.decode(&r.tokens),
                        tpf: r.tpf(),
                        forwards: r.forwards,
                        gen_tokens: r.tokens.len(),
                        tokens: r.tokens,
                        queue_ms: f.tag.queue_ms,
                        // engine time of this session's own steps (its
                        // share of batched forwards included)
                        decode_ms: f.busy_secs * 1e3,
                    };
                    record_served(&stats, &resp);
                    protocol::ok_response(&resp)
                }
                Err(e) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    protocol::err_response(&f.id, &format!("{e:#}"))
                }
            };
            let _ = f.tag.reply.send(line);
        }
    }
    Ok(())
}

fn reply_err(stats: &ServerStats, job: &Job, e: &anyhow::Error) {
    stats.errors.fetch_add(1, Ordering::Relaxed);
    let _ = job
        .reply
        .send(protocol::err_response(&job.req.id, &format!("{e:#}")));
}

fn record_served(stats: &ServerStats, r: &GenResponse) {
    stats.served.fetch_add(1, Ordering::Relaxed);
    stats
        .queue_ms_total
        .fetch_add(r.queue_ms as u64, Ordering::Relaxed);
    stats
        .decode_ms_total
        .fetch_add(r.decode_ms as u64, Ordering::Relaxed);
}

/// Build a resumable session for one admitted request (any strategy;
/// `Spec` needs a draft checkpoint the server does not load yet, so it
/// fails here with a per-request error).
fn admit_session(eng: &Engine, tk: &Tokenizer, dcfg: &DecodeCfg,
                 req: &GenRequest) -> Result<DecodeSession> {
    let (prompt, gen_len) = prepare_request(eng, tk, req)?;
    DecodeSession::new(eng, dcfg.clone(), &prompt, gen_len)
}

/// Blocking client helper (examples + integration tests).
pub fn client_request(addr: &str, line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Ok(resp.trim().to_string())
}
