//! Serving coordinator: a threaded JSON-line TCP server in front of a
//! single-stream decode engine.
//!
//! Topology (the offline registry has no tokio; std threads + channels):
//!
//!   acceptor thread --- per-connection reader threads
//!        |  (mpsc)                |  parse JSON-line requests
//!        v                        v
//!   router/batcher  <-- bounded priority queue, backpressure
//!        |
//!        v
//!   engine worker (owns PJRT Engine + checkpoint; decodes batch=1,
//!                  matching the paper's serving setup)
//!        |
//!        v  per-request reply channel
//!   connection writer
//!
//! The engine worker pre-compiles the executables its strategy needs, so
//! first-request latency is decode, not XLA compilation.

pub mod batcher;
pub mod protocol;
pub mod scheduler;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::decode::{self, DecodeCfg, Strategy};
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;
use crate::train::TrainCfg;

use batcher::Batcher;
use protocol::{GenRequest, GenResponse, Request};

#[derive(Debug, Clone)]
pub struct ServerCfg {
    pub host: String,
    pub port: u16,
    pub ckpt: String,
    pub strategy: Strategy,
    pub variant: String,
    pub max_queue: usize,
    /// full decode configuration; per-request `strategy` switches presets,
    /// otherwise this config is used verbatim
    pub decode: Option<crate::decode::DecodeCfg>,
}

struct Job {
    req: GenRequest,
    reply: mpsc::Sender<String>,
}

#[derive(Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub queue_ms_total: AtomicU64,
    pub decode_ms_total: AtomicU64,
}

/// Run the server until a shutdown request arrives.
pub fn serve(cfg: ServerCfg) -> Result<()> {
    let addr = format!("{}:{}", cfg.host, cfg.port);
    let listener =
        TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
    eprintln!("[serve] listening on {addr} (ckpt={}, strategy={})",
              cfg.ckpt, cfg.strategy.name());

    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let stats = Arc::new(ServerStats::default());
    let shutdown = Arc::new(AtomicBool::new(false));

    // ---- engine worker (owns the non-Sync PJRT engine)
    let worker_cfg = cfg.clone();
    let worker_stats = stats.clone();
    let worker_shutdown = shutdown.clone();
    let worker = std::thread::spawn(move || {
        if let Err(e) =
            engine_worker(worker_cfg, job_rx, worker_stats, worker_shutdown)
        {
            eprintln!("[serve] engine worker failed: {e:#}");
        }
    });

    // ---- accept loop
    listener.set_nonblocking(true)?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = job_tx.clone();
                let st = stats.clone();
                let sd = shutdown.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, tx, st, sd) {
                        eprintln!("[serve] connection error: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    drop(job_tx);
    let _ = worker.join();
    eprintln!("[serve] shut down cleanly");
    Ok(())
}

fn handle_conn(stream: TcpStream, jobs: mpsc::Sender<Job>,
               stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>)
               -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_request(&line) {
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", protocol::err_response("", "shutting down"))?;
                break;
            }
            Ok(Request::Stats) => {
                let s = format!(
                    r#"{{"ok":true,"served":{},"errors":{},"queue_ms":{},"decode_ms":{}}}"#,
                    stats.served.load(Ordering::Relaxed),
                    stats.errors.load(Ordering::Relaxed),
                    stats.queue_ms_total.load(Ordering::Relaxed),
                    stats.decode_ms_total.load(Ordering::Relaxed),
                );
                writeln!(writer, "{s}")?;
            }
            Ok(Request::Generate(req)) => {
                let (reply_tx, reply_rx) = mpsc::channel();
                jobs.send(Job { req, reply: reply_tx })
                    .map_err(|_| anyhow!("engine worker gone"))?;
                let response = reply_rx
                    .recv()
                    .unwrap_or_else(|_| protocol::err_response("", "worker died"));
                writeln!(writer, "{response}")?;
            }
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                writeln!(writer, "{}", protocol::err_response("", &format!("{e}")))?;
            }
        }
    }
    let _ = peer;
    Ok(())
}

fn engine_worker(cfg: ServerCfg, jobs: mpsc::Receiver<Job>,
                 stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>)
                 -> Result<()> {
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    let tk = Tokenizer::new(c.vocab)?;
    let params = ParamStore::load(TrainCfg::ckpt_path(
        std::path::Path::new("checkpoints"),
        &cfg.ckpt,
    ))?;
    params.check(eng.manifest.model("main")?)?;

    // pre-compile the strategy's executables
    let (prefill, dec) = decode::exec_names(&cfg.variant);
    eng.warmup(&[prefill.as_str(), dec.as_str()])?;
    eprintln!("[serve] engine ready");

    let mut batcher: Batcher<Job> = Batcher::new(cfg.max_queue);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // drain the channel into the priority queue
        loop {
            match jobs.try_recv() {
                Ok(job) => {
                    let pri = job.req.priority;
                    if !batcher.push(job, pri) {
                        // reject newest on overflow
                        if let Some(j) = batcher.pop() {
                            let _ = j.payload.reply.send(
                                protocol::err_response(
                                    &j.payload.req.id,
                                    "queue full",
                                ),
                            );
                        }
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    if batcher.is_empty() {
                        return Ok(());
                    }
                    break;
                }
            }
        }
        let Some(queued) = batcher.pop() else {
            // block for the next job to avoid spinning
            match jobs.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(job) => {
                    let pri = job.req.priority;
                    batcher.push(job, pri);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
            continue;
        };

        let queue_ms = queued.enqueued.elapsed().as_secs_f64() * 1e3;
        let job = queued.payload;
        let response = serve_one(&eng, &cfg, &tk, &params, &job.req, queue_ms);
        let line = match response {
            Ok(r) => {
                stats.served.fetch_add(1, Ordering::Relaxed);
                stats
                    .queue_ms_total
                    .fetch_add(r.queue_ms as u64, Ordering::Relaxed);
                stats
                    .decode_ms_total
                    .fetch_add(r.decode_ms as u64, Ordering::Relaxed);
                protocol::ok_response(&r)
            }
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                protocol::err_response(&job.req.id, &format!("{e:#}"))
            }
        };
        let _ = job.reply.send(line);
    }
    Ok(())
}

fn serve_one(eng: &Engine, cfg: &ServerCfg, tk: &Tokenizer,
             params: &ParamStore, req: &GenRequest, queue_ms: f64)
             -> Result<GenResponse> {
    let c = eng.manifest.constants.clone();
    let prompt = tk.encode(&req.prompt)?;
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    let mut dcfg = match (&req.strategy, &cfg.decode) {
        (Some(s), _) => DecodeCfg::preset(
            Strategy::parse(s).ok_or_else(|| anyhow!("bad strategy"))?),
        (None, Some(d)) => d.clone(),
        (None, None) => DecodeCfg::preset(cfg.strategy),
    };
    dcfg.variant = cfg.variant.clone();
    let gen_len = req
        .gen_len
        .unwrap_or(96)
        .min(c.gen_max)
        .next_multiple_of(c.block)
        .min(c.s_max.saturating_sub(prompt.len()) / c.block * c.block);
    if gen_len == 0 {
        return Err(anyhow!("prompt too long"));
    }

    let t0 = Instant::now();
    let r = decode::generate(eng, &dcfg, &params.data, None, &prompt,
                             gen_len)?;
    Ok(GenResponse {
        id: req.id.clone(),
        text: tk.decode(&r.tokens),
        tpf: r.tpf(),
        forwards: r.forwards,
        gen_tokens: r.tokens.len(),
        tokens: r.tokens,
        queue_ms,
        decode_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Blocking client helper (examples + integration tests).
pub fn client_request(addr: &str, line: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Ok(resp.trim().to_string())
}
