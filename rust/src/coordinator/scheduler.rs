//! Round-robin session scheduler: runs several in-flight decode sessions
//! (any strategy — every strategy is a resumable `DecodePolicy`) on one
//! engine, one round each per cycle. This is the continuous-serving
//! analog at the paper's batch=1 compute granularity — it bounds
//! head-of-line blocking (a long request no longer delays a short one by
//! its full decode time, only by one round ~ one forward).
//!
//! `SessionPool` is the reusable core: the coordinator's engine worker
//! admits jobs into it between rounds (up to `max_concurrent_sessions`),
//! and `benches/interleave.rs` / the scheduler-determinism tests drive it
//! directly over the `SimBackend`. Fairness invariant: `step_round` steps
//! every live session exactly once in admission order, so between two
//! consecutive steps of any session, every other live session steps
//! exactly once (per-session step gap <= pool size).
//!
//! ## EDF scheduling and preemption-by-pausing
//!
//! With a `round_width` smaller than the live-session count, each round
//! steps only the `round_width` most urgent runnable sessions — earliest
//! deadline first (`admit_deadline`), deadline-free sessions after every
//! deadlined one, and sessions already past their deadline last (they
//! have nothing left to win; urgent work that can still make its budget
//! runs instead). Sessions are fully resumable, so preemption is simply
//! *not scheduling a round*: a paused session keeps its KV pages and
//! resumes bit-identically (its trajectory is schedule-independent —
//! pinned in tests/scheduler_determinism.rs). Ties rotate by
//! least-recently-stepped, so width-limited pools without deadlines
//! degrade to fair round-robin, and the default width (unlimited)
//! preserves the classic step-everyone behavior exactly.
//!
//! Deadlines are absolute milliseconds on a caller-driven clock
//! (`set_now_ms`): the serving coordinator feeds wall time since worker
//! start, tests and benches drive a deterministic virtual clock.
//!
//! ## Batched rounds
//!
//! One cycle runs in three phases: every runnable session *plans* its
//! round (`DecodeSession::plan_round`), the planned forwards are
//! *executed* — with same-shape forwards (same executable, same
//! sequence/window length) coalesced into one `Backend::prefill_batch` /
//! `decode_window_batch` call of B > 1 — and each output is *applied*
//! back to its session in admission order. Plans are pure descriptions
//! of forwards, so coalescing cannot change any session's trajectory:
//! per-session outputs are bit-identical to the B=1 path (asserted in
//! tests/scheduler_determinism.rs). Each `WindowItem` of a coalesced
//! round carries that session's `KvView`, so a batched round hands the
//! backend B per-session *page tables* (read paged-natively, see
//! `decode::backend`), never B dense cache copies. If a batched call
//! fails, the group falls back to per-session forwards so one bad
//! request cannot poison its round-mates (window-group isolation is
//! pinned in tests/scheduler_determinism.rs).

use std::time::Instant;

use anyhow::Result;

use crate::decode::{Backend, DecodeCfg, DecodeSession, GenResult,
                    PrefillItem, RoundBudget, RoundOut, RoundPlan,
                    SessionProgress, WindowItem};
use crate::model::kv_pool::{is_pool_exhausted, SharedKvPool};

/// One admitted request.
pub struct InterleavedRequest {
    pub id: String,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    /// Per-request decode config (strategy, thresholds). `None` uses the
    /// pool-level default, so one pool can mix strategies freely.
    pub cfg: Option<DecodeCfg>,
}

/// A session retired from the pool: either a finished decode or the error
/// that killed it. Per-session failures never poison the rest of the pool.
pub struct Finished<T> {
    pub id: String,
    pub tag: T,
    pub result: Result<GenResult>,
    /// Engine time this session's own steps took (its share of batched
    /// forwards; excludes rounds spent on other interleaved sessions).
    pub busy_secs: f64,
    /// True when the session retired after its deadline (on the pool's
    /// `set_now_ms` clock); always false for deadline-free sessions.
    pub deadline_missed: bool,
}

struct Entry<T> {
    id: String,
    tag: T,
    session: DecodeSession,
    seq: u64,
    busy_secs: f64,
    /// Absolute deadline (ms on the pool clock); `None` = no SLO.
    deadline_at_ms: Option<u64>,
    /// Pool round this session last stepped in (EDF tie rotation).
    last_step: u64,
}

/// What one session's round planned, held between the plan and apply
/// phases of a cycle.
enum Slot {
    /// Not runnable this round (blocked) — skipped.
    Idle,
    /// Plan said finished: retire with the session's result.
    Done,
    /// Bookkeeping round: apply with `RoundOut::None`.
    Book,
    Full { exec: String, tokens: Vec<i32>, valid: Vec<f32> },
    Window { exec: String, tokens: Vec<i32>, pos: Vec<i32>, valid: Vec<f32> },
    /// Plan failed: retire with the error.
    Failed(anyhow::Error),
}

/// Group `idx` under `key`, preserving first-seen (admission) order.
fn add_group<K: PartialEq>(groups: &mut Vec<(K, Vec<usize>)>, key: K,
                           idx: usize) {
    match groups.iter_mut().find(|(k, _)| *k == key) {
        Some((_, members)) => members.push(idx),
        None => groups.push((key, vec![idx])),
    }
}

/// Pool of live decode sessions, stepped round-robin in admission order.
/// `T` is caller metadata carried alongside each session (reply channels,
/// timing) and handed back on retirement.
pub struct SessionPool<T> {
    entries: Vec<Entry<T>>,
    next_seq: u64,
    /// Total session rounds issued by this pool.
    pub steps_total: u64,
    /// Total sessions ever admitted.
    pub admitted_total: u64,
    /// Runnable sessions left unscheduled by EDF width pressure (counter).
    pub preempted_total: u64,
    /// Sessions retired past their deadline (counter).
    pub deadline_miss_total: u64,
    /// Sessions stepped per round under EDF pressure (`usize::MAX` =
    /// step every runnable session, the classic behavior).
    round_width: usize,
    /// Current time (ms) on the caller's clock, for overdue checks.
    now_ms: u64,
    /// `step_round` invocations (EDF tie rotation epoch).
    rounds_issued: u64,
    record_trace: bool,
    trace: Vec<u64>,
    /// Shared paged KV pool the admitted sessions draw pages from, when
    /// paged serving is enabled (admission budget checks + occupancy
    /// stats; session retirement releases pages via `PagedKv::drop`).
    kv: Option<SharedKvPool>,
    /// Preemption spill threshold: a session paused this many consecutive
    /// rounds releases its paged KV to the pool's reclaimable set and
    /// re-prefills on resume (prefix adoption makes that cheap). `0` =
    /// disabled.
    spill_after_rounds: usize,
}

impl<T> SessionPool<T> {
    pub fn new() -> SessionPool<T> {
        SessionPool {
            entries: Vec::new(),
            next_seq: 0,
            steps_total: 0,
            admitted_total: 0,
            preempted_total: 0,
            deadline_miss_total: 0,
            round_width: usize::MAX,
            now_ms: 0,
            rounds_issued: 0,
            record_trace: false,
            trace: Vec::new(),
            kv: None,
            spill_after_rounds: 0,
        }
    }

    /// Bound how many sessions step per round (EDF selection among the
    /// runnable ones); `0` or `usize::MAX` = step every runnable session.
    pub fn with_round_width(mut self, width: usize) -> SessionPool<T> {
        self.set_round_width(width);
        self
    }

    /// See `with_round_width`.
    pub fn set_round_width(&mut self, width: usize) {
        self.round_width = if width == 0 { usize::MAX } else { width };
    }

    /// Advance the pool clock (absolute ms; same clock `admit_deadline`
    /// deadlines are on). Drives overdue demotion and miss accounting.
    pub fn set_now_ms(&mut self, now_ms: u64) {
        self.now_ms = now_ms;
    }

    /// Record the admission-sequence number of every step (for fairness
    /// assertions in tests). Off by default.
    pub fn with_trace(mut self) -> SessionPool<T> {
        self.record_trace = true;
        self
    }

    /// Attach the shared paged KV pool this scheduler's sessions draw
    /// pages from.
    pub fn with_kv_pool(mut self, kv: SharedKvPool) -> SessionPool<T> {
        self.kv = Some(kv);
        self
    }

    /// Spill a session's paged KV after it has been paused this many
    /// consecutive rounds (`0` = never, the default). Spilled sessions
    /// restore automatically before their next planned round, staying
    /// paused while the pool is exhausted instead of failing.
    pub fn set_spill_after_rounds(&mut self, rounds: usize) {
        self.spill_after_rounds = rounds;
    }

    /// The attached paged KV pool, if paged serving is enabled.
    pub fn kv_pool(&self) -> Option<&SharedKvPool> {
        self.kv.as_ref()
    }

    /// Install per-session adaptive round budgets: `f` sees each live
    /// session's config and running result (for the commit-quality
    /// feedback signal) and returns the budget to apply — `None` keeps
    /// that session on the static path. The serving coordinator calls
    /// this with `AdaptiveController::budget_for` before every
    /// `step_round`; sessions admitted later default to no budget until
    /// the next call.
    pub fn set_budgets<F>(&mut self, mut f: F)
    where
        F: FnMut(&DecodeCfg, &GenResult) -> Option<RoundBudget>,
    {
        for e in self.entries.iter_mut() {
            let b = f(&e.session.cfg, &e.session.res);
            e.session.set_round_budget(b);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.id.clone()).collect()
    }

    /// Per-session progress snapshots, in admission order.
    /// Drain every live session, returning each entry's `(id, tag)`.
    /// Worker-death path: the caller turns these into error replies so
    /// in-flight connections retire instead of hanging. Dropping the
    /// sessions releases their paged KV back to the pool.
    pub fn drain_sessions(&mut self) -> Vec<(String, T)> {
        self.entries.drain(..).map(|e| (e.id, e.tag)).collect()
    }

    pub fn progress(&self) -> Vec<(String, SessionProgress)> {
        self.entries
            .iter()
            .map(|e| (e.id.clone(), e.session.progress()))
            .collect()
    }

    /// Admission-sequence step trace recorded so far (see `with_trace`).
    pub fn trace(&self) -> &[u64] {
        &self.trace
    }

    /// Admit a live session with caller metadata. Returns its admission
    /// sequence number (stable id for the fairness trace).
    pub fn admit(&mut self, id: String, tag: T, session: DecodeSession)
                 -> u64 {
        self.admit_deadline(id, tag, session, None)
    }

    /// `admit` with an absolute deadline (ms on the `set_now_ms` clock):
    /// the session competes EDF for round slots and is demoted behind
    /// still-meetable work once overdue.
    pub fn admit_deadline(&mut self, id: String, tag: T,
                          session: DecodeSession,
                          deadline_at_ms: Option<u64>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.admitted_total += 1;
        self.entries.push(Entry {
            id,
            tag,
            session,
            seq,
            busy_secs: 0.0,
            deadline_at_ms,
            last_step: 0,
        });
        seq
    }

    /// Pick which runnable sessions step this round. `None` = no width
    /// pressure (every runnable session steps — the classic fast path);
    /// `Some(sel)` = EDF selection, `sel[i]` true for stepped entries.
    ///
    /// Urgency order: sessions that can still meet a deadline first
    /// (earliest deadline), then deadline-free sessions, then overdue
    /// sessions last — a session past its deadline budget yields its
    /// round slot to work that can still win. Ties rotate by
    /// least-recently-stepped, then admission order.
    fn select_runnable(&self) -> Option<Vec<bool>> {
        let mut runnable: Vec<usize> = (0..self.entries.len())
            .filter(|&i| self.entries[i].session.is_runnable())
            .collect();
        if runnable.len() <= self.round_width {
            return None;
        }
        runnable.sort_by_key(|&i| {
            let e = &self.entries[i];
            let overdue =
                e.deadline_at_ms.map_or(false, |d| d < self.now_ms);
            (overdue, e.deadline_at_ms.unwrap_or(u64::MAX), e.last_step,
             e.seq)
        });
        let mut sel = vec![false; self.entries.len()];
        for &i in runnable.iter().take(self.round_width) {
            sel[i] = true;
        }
        Some(sel)
    }

    /// Step every runnable session exactly once, in admission order,
    /// coalescing same-shape forwards into batched backend calls (see
    /// module docs). Finished (or failed) sessions are retired and
    /// returned in admission order.
    // index loops: the plan phase borrows trace/steps_total alongside
    // entries, which rules out iter_mut()
    #[allow(clippy::needless_range_loop)]
    pub fn step_round(&mut self, backend: &dyn Backend, params: &[f32])
                      -> Vec<Finished<T>> {
        let n = self.entries.len();
        self.rounds_issued += 1;
        let selected = self.select_runnable();

        // ---- phase 1: plan (admission order; this is the fairness trace)
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        for i in 0..n {
            if !self.entries[i].session.is_runnable() {
                // blocked (future async backends): skip this round; a
                // *finished* session is retired by the round that
                // finished it, so this never strands a completed decode
                slots.push(Slot::Idle);
                continue;
            }
            if let Some(sel) = &selected {
                if !sel[i] {
                    // preemption-by-pausing: runnable but out-prioritized
                    // this round — the session just doesn't get a step
                    self.entries[i].session.note_paused();
                    self.preempted_total += 1;
                    if self.spill_after_rounds > 0
                        && self.entries[i].session.paused_streak()
                            >= self.spill_after_rounds
                    {
                        // long pause: free the memory too, not just the
                        // round slot (no-op once spilled / for dense)
                        self.entries[i].session.spill_kv();
                    }
                    slots.push(Slot::Idle);
                    continue;
                }
            }
            if self.entries[i].session.kv_spilled() {
                // resuming a spilled session: re-admit + rebuild before
                // planning; under pool exhaustion it stays paused rather
                // than failing (retry next round)
                match self.entries[i].session.ensure_kv(backend, params) {
                    Ok(true) => {}
                    Ok(false) => {
                        self.entries[i].session.note_paused();
                        self.preempted_total += 1;
                        slots.push(Slot::Idle);
                        continue;
                    }
                    Err(e) => {
                        slots.push(Slot::Failed(e));
                        continue;
                    }
                }
            }
            self.entries[i].last_step = self.rounds_issued;
            if self.record_trace {
                self.trace.push(self.entries[i].seq);
            }
            self.steps_total += 1;
            let t0 = Instant::now();
            let plan = self.entries[i].session.plan_round(backend, params);
            self.entries[i].busy_secs += t0.elapsed().as_secs_f64();
            slots.push(match plan {
                Ok(RoundPlan::Finished) => Slot::Done,
                Ok(RoundPlan::Bookkeeping) => Slot::Book,
                Ok(RoundPlan::Full { exec, tokens, valid }) => {
                    Slot::Full { exec, tokens, valid }
                }
                Ok(RoundPlan::Window { exec, tokens, pos, valid }) => {
                    Slot::Window { exec, tokens, pos, valid }
                }
                Err(e) => Slot::Failed(e),
            });
        }

        // ---- phase 2: execute, coalescing same-shape forwards
        // (group keys borrow the plan's exec name — no per-round clones)
        let mut outs: Vec<Option<Result<RoundOut>>> =
            (0..n).map(|_| None).collect();
        let mut full_groups: Vec<((&str, usize), Vec<usize>)> = Vec::new();
        let mut win_groups: Vec<((&str, usize), Vec<usize>)> = Vec::new();
        for (i, s) in slots.iter().enumerate() {
            match s {
                Slot::Full { exec, tokens, .. } => {
                    add_group(&mut full_groups,
                              (exec.as_str(), tokens.len()), i);
                }
                Slot::Window { exec, tokens, .. } => {
                    add_group(&mut win_groups,
                              (exec.as_str(), tokens.len()), i);
                }
                _ => {}
            }
        }
        for (_, members) in &full_groups {
            self.run_full_group(backend, params, &slots, members, &mut outs);
        }
        for (_, members) in &win_groups {
            self.run_window_group(backend, params, &slots, members,
                                  &mut outs);
        }

        // ---- phase 3: apply outputs + retire, in admission order
        let mut retire: Vec<(usize, Option<anyhow::Error>)> = Vec::new();
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Slot::Idle => {}
                Slot::Done => retire.push((i, None)),
                Slot::Failed(e) => retire.push((i, Some(e))),
                Slot::Book => {
                    let t0 = Instant::now();
                    let r = self.entries[i].session.apply_round(
                        RoundOut::None);
                    self.entries[i].busy_secs += t0.elapsed().as_secs_f64();
                    match r {
                        Ok(true) => retire.push((i, None)),
                        Ok(false) => {}
                        Err(e) => retire.push((i, Some(e))),
                    }
                }
                Slot::Full { .. } | Slot::Window { .. } => {
                    match outs[i].take().expect("planned round has output") {
                        Ok(out) => {
                            let t0 = Instant::now();
                            let r = self.entries[i].session.apply_round(out);
                            self.entries[i].busy_secs +=
                                t0.elapsed().as_secs_f64();
                            match r {
                                Ok(true) => retire.push((i, None)),
                                Ok(false) => {}
                                Err(e) => retire.push((i, Some(e))),
                            }
                        }
                        Err(e) => retire.push((i, Some(e))),
                    }
                }
            }
        }

        let mut finished = Vec::with_capacity(retire.len());
        let mut removed = 0usize;
        for (idx, err) in retire {
            let e = self.entries.remove(idx - removed);
            removed += 1;
            let deadline_missed =
                e.deadline_at_ms.map_or(false, |d| self.now_ms > d);
            if deadline_missed {
                self.deadline_miss_total += 1;
            }
            finished.push(Finished {
                id: e.id,
                tag: e.tag,
                result: match err {
                    Some(err) => Err(err),
                    None => Ok(e.session.finish()),
                },
                busy_secs: e.busy_secs,
                deadline_missed,
            });
        }
        finished
    }

    /// Execute one group of same-shape full forwards (B=1 inline, B>1 via
    /// `prefill_batch`; on batch failure, fall back to per-session calls).
    ///
    /// NOTE: deliberately a structural twin of `run_window_group` (the
    /// window variant threads each session's cache through the items, so
    /// a shared closure-generic helper would cost more in borrow
    /// gymnastics than it saves) — keep the batch/fallback/crediting
    /// logic of the two in sync when editing either.
    fn run_full_group(&mut self, backend: &dyn Backend, params: &[f32],
                      slots: &[Slot], members: &[usize],
                      outs: &mut [Option<Result<RoundOut>>]) {
        if members.len() >= 2 {
            let (batched, share) = {
                let items: Vec<PrefillItem<'_>> = members
                    .iter()
                    .map(|&i| {
                        let Slot::Full { exec, tokens, valid } = &slots[i]
                        else {
                            unreachable!("full group holds full plans")
                        };
                        PrefillItem { exec, tokens, valid }
                    })
                    .collect();
                let t0 = Instant::now();
                let r = backend.prefill_batch(params, &items);
                (r, t0.elapsed().as_secs_f64() / members.len() as f64)
            };
            if let Ok(many) = batched {
                if many.len() == members.len() {
                    for (&i, out) in members.iter().zip(many) {
                        self.entries[i].session.credit_forward(share);
                        self.entries[i].busy_secs += share;
                        outs[i] = Some(Ok(RoundOut::Full(out)));
                    }
                    return;
                }
            }
            // batched call failed (or returned the wrong arity): isolate
            // failures by re-issuing per-session forwards below
        }
        for &i in members {
            let Slot::Full { exec, tokens, valid } = &slots[i] else {
                unreachable!("full group holds full plans")
            };
            let t0 = Instant::now();
            let r = backend.prefill(exec, params, tokens, valid);
            let dt = t0.elapsed().as_secs_f64();
            self.entries[i].session.credit_forward(dt);
            self.entries[i].busy_secs += dt;
            outs[i] = Some(r.map(RoundOut::Full));
        }
    }

    /// Execute one group of same-shape windowed forwards, each against
    /// its own session's cache (B=1 inline, B>1 via `decode_window_batch`;
    /// on batch failure, fall back to per-session calls). Structural twin
    /// of `run_full_group` — see the note there.
    fn run_window_group(&mut self, backend: &dyn Backend, params: &[f32],
                        slots: &[Slot], members: &[usize],
                        outs: &mut [Option<Result<RoundOut>>]) {
        if members.len() >= 2 {
            let (batched, share) = {
                let items: Vec<WindowItem<'_>> = members
                    .iter()
                    .map(|&i| {
                        let Slot::Window { exec, tokens, pos, valid } =
                            &slots[i]
                        else {
                            unreachable!("window group holds window plans")
                        };
                        WindowItem {
                            exec,
                            tokens,
                            pos,
                            valid,
                            cache: self.entries[i].session.cache.as_ref(),
                        }
                    })
                    .collect();
                let t0 = Instant::now();
                let r = backend.decode_window_batch(params, &items);
                (r, t0.elapsed().as_secs_f64() / members.len() as f64)
            };
            if let Ok(many) = batched {
                if many.len() == members.len() {
                    for (&i, out) in members.iter().zip(many) {
                        self.entries[i].session.credit_forward(share);
                        self.entries[i].busy_secs += share;
                        outs[i] = Some(Ok(RoundOut::Window(out)));
                    }
                    return;
                }
            }
        }
        for &i in members {
            let Slot::Window { exec, tokens, pos, valid } = &slots[i] else {
                unreachable!("window group holds window plans")
            };
            let t0 = Instant::now();
            let r = backend.decode_window(exec, params, tokens, pos, valid,
                                          self.entries[i].session.cache
                                              .as_ref());
            let dt = t0.elapsed().as_secs_f64();
            self.entries[i].session.credit_forward(dt);
            self.entries[i].busy_secs += dt;
            outs[i] = Some(r.map(RoundOut::Window));
        }
    }
}

impl<T> Default for SessionPool<T> {
    fn default() -> Self {
        SessionPool::new()
    }
}

/// Fair round-robin over all sessions until every request completes.
/// Accepts any strategy mix (per-request `cfg` overrides the pool
/// default); `draft_params` is only needed when the mix contains
/// `Strategy::Spec`. Returns results in the input order.
pub fn run_interleaved(backend: &dyn Backend, cfg: &DecodeCfg,
                       params: &[f32], draft_params: Option<&[f32]>,
                       requests: Vec<InterleavedRequest>)
                       -> Result<Vec<(String, GenResult)>> {
    run_interleaved_inner(backend, cfg, params, draft_params, requests,
                          None)
}

/// `run_interleaved` over the shared paged KV pool: sessions hold
/// page-table views, same-prefix requests share prefilled pages, and
/// per-request results stay bit-identical to the dense-cache run on the
/// deterministic `SimBackend`.
pub fn run_interleaved_pooled(backend: &dyn Backend, cfg: &DecodeCfg,
                              params: &[f32], draft_params: Option<&[f32]>,
                              requests: Vec<InterleavedRequest>,
                              kv: &SharedKvPool)
                              -> Result<Vec<(String, GenResult)>> {
    run_interleaved_inner(backend, cfg, params, draft_params, requests,
                          Some(kv))
}

fn run_interleaved_inner(backend: &dyn Backend, cfg: &DecodeCfg,
                         params: &[f32], draft_params: Option<&[f32]>,
                         requests: Vec<InterleavedRequest>,
                         kv: Option<&SharedKvPool>)
                         -> Result<Vec<(String, GenResult)>> {
    let mut pool: SessionPool<usize> = match kv {
        Some(kv) => SessionPool::new().with_kv_pool(kv.clone()),
        None => SessionPool::new(),
    };
    for (i, r) in requests.into_iter().enumerate() {
        let dcfg = r.cfg.unwrap_or_else(|| cfg.clone());
        let session = match kv {
            Some(kv) => DecodeSession::with_pool(backend, dcfg, &r.prompt,
                                                 r.gen_len, draft_params,
                                                 kv)?,
            None => DecodeSession::with_draft(backend, dcfg, &r.prompt,
                                              r.gen_len, draft_params)?,
        };
        pool.admit(r.id, i, session);
    }
    let mut done: Vec<(usize, String, GenResult)> = Vec::new();
    while !pool.is_empty() {
        for f in pool.step_round(backend, params) {
            done.push((f.tag, f.id, f.result?));
        }
    }
    done.sort_by_key(|(idx, _, _)| *idx);
    Ok(done.into_iter().map(|(_, id, r)| (id, r)).collect())
}

/// Drive `n` jobs through a bounded-width interleaved pool: sessions are
/// admitted from `make(index)` as slots free up (at most `width` live at
/// once), every round coalesces same-shape forwards into batched backend
/// calls, and results come back in job order. This is the batch-workload
/// twin of the serving engine worker — evaluation
/// (`eval::evaluate`) and pooled teacher-trajectory extraction
/// (`trajectory::extract_all`) both run on it, so they get round
/// coalescing and (when `make` binds sessions to a `SharedKvPool`)
/// prefix sharing for free.
///
/// A `make` failure with a pool-exhausted error pauses admission for the
/// cycle while live sessions drain pages; any other failure (or an
/// exhausted pool with nothing live to drain) aborts the run.
pub fn run_pool_bounded<F>(backend: &dyn Backend, params: &[f32], n: usize,
                           width: usize, mut make: F)
                           -> Result<Vec<GenResult>>
where
    F: FnMut(usize) -> Result<DecodeSession>,
{
    let width = width.max(1);
    let mut out: Vec<Option<GenResult>> = (0..n).map(|_| None).collect();
    let mut pool: SessionPool<usize> = SessionPool::new();
    let mut next = 0usize;
    while next < n || !pool.is_empty() {
        while pool.len() < width && next < n {
            match make(next) {
                Ok(session) => {
                    pool.admit(format!("job{next}"), next, session);
                    next += 1;
                }
                Err(e) if is_pool_exhausted(&e) && !pool.is_empty() => {
                    break; // retry once live sessions release pages
                }
                Err(e) => return Err(e),
            }
        }
        for f in pool.step_round(backend, params) {
            out[f.tag] = Some(f.result?);
        }
    }
    Ok(out
        .into_iter()
        .map(|r| r.expect("bounded pool finishes every job"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::{SimBackend, Strategy};
    use crate::model::ParamStore;
    use crate::runtime::Engine;

    #[test]
    fn bounded_pool_matches_sequential_and_respects_width() {
        let sim = SimBackend::new(9);
        let mut cfg = DecodeCfg::preset(Strategy::D3llm);
        cfg.early_stop = false;
        let params = vec![0.5f32; 8];
        let prompts: Vec<Vec<i32>> = (0..5)
            .map(|k| (0..12).map(|i| 5 + (i + 3 * k) % 70).collect())
            .collect();

        let mut refs = Vec::new();
        for p in &prompts {
            refs.push(
                crate::decode::generate(&sim, &cfg, &params, None, p, 64)
                    .unwrap(),
            );
        }
        let pooled = run_pool_bounded(&sim, &params, prompts.len(), 2, |i| {
            DecodeSession::new(&sim, cfg.clone(), &prompts[i], 64)
        })
        .unwrap();
        assert_eq!(pooled.len(), refs.len());
        for (i, (r, s)) in pooled.iter().zip(&refs).enumerate() {
            assert_eq!(r.tokens, s.tokens, "job {i} diverged");
            assert_eq!(r.forwards, s.forwards, "job {i} forwards diverged");
        }
        // width 2 over 5 jobs must still coalesce same-shape rounds
        assert!(sim.window_batch_calls() > 0 && sim.max_window_batch() >= 2,
                "bounded pool should batch same-shape rounds");
    }

    #[test]
    fn interleaved_matches_sequential() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ missing");
            return;
        }
        let eng = Engine::load("artifacts").unwrap();
        let params =
            ParamStore::init(eng.manifest.model("main").unwrap(), 3).data;
        let mut cfg = DecodeCfg::preset(Strategy::D3llm);
        cfg.early_stop = false;

        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..16).map(|i| 5 + (i + k * 7) % 80).collect())
            .collect();

        // sequential reference
        let mut seq_results = Vec::new();
        for p in &prompts {
            seq_results.push(
                crate::decode::generate(&eng, &cfg, &params, None, p, 64)
                    .unwrap(),
            );
        }
        // interleaved
        let reqs = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| InterleavedRequest {
                id: format!("r{i}"),
                prompt: p.clone(),
                gen_len: 64,
                cfg: None,
            })
            .collect();
        let inter = run_interleaved(&eng, &cfg, &params, None, reqs).unwrap();

        assert_eq!(inter.len(), 3);
        for ((id, r), seq) in inter.iter().zip(&seq_results) {
            assert!(id.starts_with('r'));
            // identical decoding decisions: same tokens, same forwards
            assert_eq!(r.tokens, seq.tokens, "{id}");
            assert_eq!(r.forwards, seq.forwards, "{id}");
        }
    }
}
