//! Round-robin session scheduler: runs several in-flight multi-block
//! decode sessions on one engine, one round each per cycle. This is the
//! continuous-serving analog at the paper's batch=1 compute granularity —
//! it bounds head-of-line blocking (a long request no longer delays a
//! short one by its full decode time, only by one round ~ one forward).
//!
//! `SessionPool` is the reusable core: the coordinator's engine worker
//! admits jobs into it between rounds (up to `max_concurrent_sessions`),
//! and `benches/interleave.rs` / the scheduler-determinism tests drive it
//! directly over the `SimBackend`. Fairness invariant: `step_round` steps
//! every live session exactly once in admission order, so between two
//! consecutive steps of any session, every other live session steps
//! exactly once (per-session step gap <= pool size).

use std::time::Instant;

use anyhow::Result;

use crate::decode::{Backend, DecodeCfg, DecodeSession, GenResult,
                    SessionProgress};

/// One admitted request.
pub struct InterleavedRequest {
    pub id: String,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// A session retired from the pool: either a finished decode or the error
/// that killed it. Per-session failures never poison the rest of the pool.
pub struct Finished<T> {
    pub id: String,
    pub tag: T,
    pub result: Result<GenResult>,
    /// Engine time this session's own steps took (excludes rounds spent
    /// on other interleaved sessions).
    pub busy_secs: f64,
}

struct Entry<T> {
    id: String,
    tag: T,
    session: DecodeSession,
    seq: u64,
    busy_secs: f64,
}

/// Pool of live decode sessions, stepped round-robin in admission order.
/// `T` is caller metadata carried alongside each session (reply channels,
/// timing) and handed back on retirement.
pub struct SessionPool<T> {
    entries: Vec<Entry<T>>,
    next_seq: u64,
    /// Total `session.step()` calls issued by this pool.
    pub steps_total: u64,
    /// Total sessions ever admitted.
    pub admitted_total: u64,
    record_trace: bool,
    trace: Vec<u64>,
}

impl<T> SessionPool<T> {
    pub fn new() -> SessionPool<T> {
        SessionPool {
            entries: Vec::new(),
            next_seq: 0,
            steps_total: 0,
            admitted_total: 0,
            record_trace: false,
            trace: Vec::new(),
        }
    }

    /// Record the admission-sequence number of every step (for fairness
    /// assertions in tests). Off by default.
    pub fn with_trace(mut self) -> SessionPool<T> {
        self.record_trace = true;
        self
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.id.clone()).collect()
    }

    /// Per-session progress snapshots, in admission order.
    pub fn progress(&self) -> Vec<(String, SessionProgress)> {
        self.entries
            .iter()
            .map(|e| (e.id.clone(), e.session.progress()))
            .collect()
    }

    /// Admission-sequence step trace recorded so far (see `with_trace`).
    pub fn trace(&self) -> &[u64] {
        &self.trace
    }

    /// Admit a live session with caller metadata. Returns its admission
    /// sequence number (stable id for the fairness trace).
    pub fn admit(&mut self, id: String, tag: T, session: DecodeSession)
                 -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.admitted_total += 1;
        self.entries.push(Entry { id, tag, session, seq, busy_secs: 0.0 });
        seq
    }

    /// Step every runnable session exactly once, in admission order.
    /// Finished (or failed) sessions are retired and returned.
    pub fn step_round(&mut self, backend: &dyn Backend, params: &[f32])
                      -> Vec<Finished<T>> {
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.entries.len() {
            if !self.entries[i].session.is_runnable() {
                // blocked (future async backends): skip this round; a
                // *finished* session is retired by the step that finished
                // it, so this never strands a completed decode
                i += 1;
                continue;
            }
            if self.record_trace {
                self.trace.push(self.entries[i].seq);
            }
            self.steps_total += 1;
            let t0 = Instant::now();
            let stepped = self.entries[i].session.step(backend, params);
            self.entries[i].busy_secs += t0.elapsed().as_secs_f64();
            match stepped {
                Ok(true) => {
                    let e = self.entries.remove(i);
                    finished.push(Finished {
                        id: e.id,
                        tag: e.tag,
                        result: Ok(e.session.finish()),
                        busy_secs: e.busy_secs,
                    });
                }
                Ok(false) => i += 1,
                Err(err) => {
                    let e = self.entries.remove(i);
                    finished.push(Finished {
                        id: e.id,
                        tag: e.tag,
                        result: Err(err),
                        busy_secs: e.busy_secs,
                    });
                }
            }
        }
        finished
    }
}

impl<T> Default for SessionPool<T> {
    fn default() -> Self {
        SessionPool::new()
    }
}

/// Fair round-robin over all sessions until every request completes.
/// Returns results in the input order.
pub fn run_interleaved(backend: &dyn Backend, cfg: &DecodeCfg,
                       params: &[f32], requests: Vec<InterleavedRequest>)
                       -> Result<Vec<(String, GenResult)>> {
    let mut pool: SessionPool<usize> = SessionPool::new();
    for (i, r) in requests.into_iter().enumerate() {
        let session =
            DecodeSession::new(backend, cfg.clone(), &r.prompt, r.gen_len)?;
        pool.admit(r.id, i, session);
    }
    let mut done: Vec<(usize, String, GenResult)> = Vec::new();
    while !pool.is_empty() {
        for f in pool.step_round(backend, params) {
            done.push((f.tag, f.id, f.result?));
        }
    }
    done.sort_by_key(|(idx, _, _)| *idx);
    Ok(done.into_iter().map(|(_, id, r)| (id, r)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::Strategy;
    use crate::model::ParamStore;
    use crate::runtime::Engine;

    #[test]
    fn interleaved_matches_sequential() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ missing");
            return;
        }
        let eng = Engine::load("artifacts").unwrap();
        let params =
            ParamStore::init(eng.manifest.model("main").unwrap(), 3).data;
        let mut cfg = DecodeCfg::preset(Strategy::D3llm);
        cfg.early_stop = false;

        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..16).map(|i| 5 + (i + k * 7) % 80).collect())
            .collect();

        // sequential reference
        let mut seq_results = Vec::new();
        for p in &prompts {
            seq_results.push(
                crate::decode::generate(&eng, &cfg, &params, None, p, 64)
                    .unwrap(),
            );
        }
        // interleaved
        let reqs = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| InterleavedRequest {
                id: format!("r{i}"),
                prompt: p.clone(),
                gen_len: 64,
            })
            .collect();
        let inter = run_interleaved(&eng, &cfg, &params, reqs).unwrap();

        assert_eq!(inter.len(), 3);
        for ((id, r), seq) in inter.iter().zip(&seq_results) {
            assert!(id.starts_with('r'));
            // identical decoding decisions: same tokens, same forwards
            assert_eq!(r.tokens, seq.tokens, "{id}");
            assert_eq!(r.forwards, seq.forwards, "{id}");
        }
    }
}
