//! Round-robin request interleaver: runs several in-flight multi-block
//! decode sessions on one engine, one round each per cycle. This is the
//! continuous-serving analog at the paper's batch=1 compute granularity —
//! it bounds head-of-line blocking (a long request no longer delays a
//! short one by its full decode time, only by one round ~ one forward).

use anyhow::Result;

use crate::decode::{DecodeCfg, DecodeSession, GenResult};
use crate::runtime::Engine;

/// One admitted request.
pub struct InterleavedRequest {
    pub id: String,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// Fair round-robin over all sessions until every request completes.
/// Returns results in the input order.
pub fn run_interleaved(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                       requests: Vec<InterleavedRequest>)
                       -> Result<Vec<(String, GenResult)>> {
    let mut live: Vec<(usize, String, DecodeSession)> = requests
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            DecodeSession::new(eng, cfg.clone(), &r.prompt, r.gen_len)
                .map(|s| (i, r.id, s))
        })
        .collect::<Result<_>>()?;
    let mut done: Vec<(usize, String, GenResult)> = Vec::new();

    while !live.is_empty() {
        let mut still = Vec::with_capacity(live.len());
        for (idx, id, mut session) in live {
            let finished = session.step(eng, params)?;
            if finished {
                done.push((idx, id, session.finish()));
            } else {
                still.push((idx, id, session));
            }
        }
        live = still;
    }
    done.sort_by_key(|(idx, _, _)| *idx);
    Ok(done.into_iter().map(|(_, id, r)| (id, r)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::Strategy;
    use crate::model::ParamStore;

    #[test]
    fn interleaved_matches_sequential() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ missing");
            return;
        }
        let eng = Engine::load("artifacts").unwrap();
        let params =
            ParamStore::init(eng.manifest.model("main").unwrap(), 3).data;
        let mut cfg = DecodeCfg::preset(Strategy::D3llm);
        cfg.early_stop = false;

        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..16).map(|i| 5 + (i + k * 7) % 80).collect())
            .collect();

        // sequential reference
        let mut seq_results = Vec::new();
        for p in &prompts {
            seq_results.push(
                crate::decode::generate(&eng, &cfg, &params, None, p, 64)
                    .unwrap(),
            );
        }
        // interleaved
        let reqs = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| InterleavedRequest {
                id: format!("r{i}"),
                prompt: p.clone(),
                gen_len: 64,
            })
            .collect();
        let inter = run_interleaved(&eng, &cfg, &params, reqs).unwrap();

        assert_eq!(inter.len(), 3);
        for ((id, r), seq) in inter.iter().zip(&seq_results) {
            assert!(id.starts_with('r'));
            // identical decoding decisions: same tokens, same forwards
            assert_eq!(r.tokens, seq.tokens, "{id}");
            assert_eq!(r.forwards, seq.forwards, "{id}");
        }
    }
}
