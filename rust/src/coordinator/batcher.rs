//! Request batcher / scheduler.
//!
//! The decode engine is single-stream (batch = 1, matching the paper's
//! serving setup), so the batcher's job is admission control and ordering:
//! a bounded priority queue with FIFO tie-breaking and queue-time
//! accounting. Higher `priority` values are served first.

use std::collections::BinaryHeap;
use std::time::Instant;

/// A queued unit of work.
pub struct QueuedJob<T> {
    pub payload: T,
    pub priority: i64,
    pub enqueued: Instant,
    seq: u64,
}

impl<T> PartialEq for QueuedJob<T> {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl<T> Eq for QueuedJob<T> {}
impl<T> PartialOrd for QueuedJob<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for QueuedJob<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher priority first; then earlier seq (FIFO)
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Outcome of a priority-aware admission attempt (`push_evicting`).
pub enum Admission<T> {
    /// Admitted; if the queue was full, the displaced lowest-priority
    /// job is returned so the caller can answer it.
    Admitted(Option<QueuedJob<T>>),
    /// Queue full of equal-or-higher-priority work; payload handed back.
    Rejected(T),
}

pub struct Batcher<T> {
    heap: BinaryHeap<QueuedJob<T>>,
    next_seq: u64,
    max_queue: usize,
    pub enqueued_total: u64,
    pub rejected_total: u64,
}

impl<T> Batcher<T> {
    pub fn new(max_queue: usize) -> Self {
        Batcher {
            heap: BinaryHeap::new(),
            next_seq: 0,
            max_queue,
            enqueued_total: 0,
            rejected_total: 0,
        }
    }

    /// Admit a job; returns false (backpressure) when the queue is full.
    pub fn push(&mut self, payload: T, priority: i64) -> bool {
        if self.heap.len() >= self.max_queue {
            self.rejected_total += 1;
            return false;
        }
        self.heap.push(QueuedJob {
            payload,
            priority,
            enqueued: Instant::now(),
            seq: self.next_seq,
        });
        self.next_seq += 1;
        self.enqueued_total += 1;
        true
    }

    pub fn pop(&mut self) -> Option<QueuedJob<T>> {
        self.heap.pop()
    }

    /// Borrow the job `pop` would return next, without disturbing its
    /// queue position or enqueue timestamp (admission checks that may
    /// decide to leave it queued).
    pub fn peek(&self) -> Option<&QueuedJob<T>> {
        self.heap.peek()
    }

    /// At capacity: the next `push` would be rejected.
    pub fn is_full(&self) -> bool {
        self.heap.len() >= self.max_queue
    }

    /// Priority-aware admission: like `push`, but when the queue is full
    /// a newcomer that outranks the lowest-priority queued job displaces
    /// it (newest-first among equals) instead of being turned away.
    /// Exactly one job loses in either case, and it is handed back so the
    /// caller can answer it.
    pub fn push_evicting(&mut self, payload: T, priority: i64)
                         -> Admission<T> {
        if self.heap.len() < self.max_queue {
            self.push(payload, priority);
            return Admission::Admitted(None);
        }
        // victim candidate: lowest priority, newest among equals; found
        // by a borrow-only scan so the rejection path (the common case
        // under sustained overload) never deconstructs the heap
        let victim = self
            .heap
            .iter()
            .map(|j| (j.priority, std::cmp::Reverse(j.seq)))
            .min();
        let Some((v_pri, v_seq)) = victim else {
            // zero-capacity queue: nothing to displace
            self.rejected_total += 1;
            return Admission::Rejected(payload);
        };
        if v_pri >= priority {
            // everything queued outranks (or ties) the newcomer
            self.rejected_total += 1;
            return Admission::Rejected(payload);
        }
        let mut v = std::mem::take(&mut self.heap).into_vec();
        let pos = v
            .iter()
            .position(|j| j.seq == v_seq.0)
            .expect("victim vanished");
        let evicted = v.swap_remove(pos);
        self.heap = BinaryHeap::from(v);
        self.rejected_total += 1;
        self.heap.push(QueuedJob {
            payload,
            priority,
            enqueued: Instant::now(),
            seq: self.next_seq,
        });
        self.next_seq += 1;
        self.enqueued_total += 1;
        Admission::Admitted(Some(evicted))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_priority() {
        let mut b = Batcher::new(10);
        b.push("a", 0);
        b.push("b", 0);
        b.push("c", 0);
        assert_eq!(b.pop().unwrap().payload, "a");
        assert_eq!(b.pop().unwrap().payload, "b");
        assert_eq!(b.pop().unwrap().payload, "c");
    }

    #[test]
    fn priority_wins() {
        let mut b = Batcher::new(10);
        b.push("low", 0);
        b.push("high", 5);
        b.push("mid", 2);
        assert_eq!(b.pop().unwrap().payload, "high");
        assert_eq!(b.pop().unwrap().payload, "mid");
        assert_eq!(b.pop().unwrap().payload, "low");
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(2);
        assert!(b.push(1, 0));
        assert!(b.push(2, 0));
        assert!(!b.push(3, 0));
        assert_eq!(b.rejected_total, 1);
        b.pop();
        assert!(b.push(3, 0));
    }

    #[test]
    fn eviction_prefers_low_priority_newest() {
        let mut b = Batcher::new(3);
        b.push("old-low", 0);
        b.push("high", 5);
        b.push("new-low", 0);
        // newcomer outranks the lows: newest low is displaced
        match b.push_evicting("mid", 2) {
            Admission::Admitted(Some(evicted)) => {
                assert_eq!(evicted.payload, "new-low");
            }
            _ => panic!("expected eviction"),
        }
        assert_eq!(b.len(), 3);
        // newcomer that ties the lowest is rejected (FIFO respected)
        match b.push_evicting("tie-low", 0) {
            Admission::Rejected(p) => assert_eq!(p, "tie-low"),
            _ => panic!("tie must not evict"),
        }
        assert_eq!(b.rejected_total, 2);
        // drain order: priority desc, FIFO within priority
        assert_eq!(b.pop().unwrap().payload, "high");
        assert_eq!(b.pop().unwrap().payload, "mid");
        assert_eq!(b.pop().unwrap().payload, "old-low");
    }

    #[test]
    fn push_evicting_on_spare_capacity_is_plain_push() {
        let mut b = Batcher::new(2);
        assert!(matches!(b.push_evicting(1, 0), Admission::Admitted(None)));
        assert!(b.push(2, 1));
        assert!(b.is_full());
        assert_eq!(b.enqueued_total, 2);
    }

    #[test]
    fn peek_matches_pop_and_preserves_order() {
        let mut b = Batcher::new(4);
        b.push("lo", 0);
        b.push("hi", 3);
        assert_eq!(b.peek().unwrap().payload, "hi");
        // peeking does not consume or reorder
        assert_eq!(b.len(), 2);
        assert_eq!(b.pop().unwrap().payload, "hi");
        assert_eq!(b.peek().unwrap().payload, "lo");
    }

    #[test]
    fn queue_time_is_tracked() {
        let mut b = Batcher::new(4);
        b.push((), 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let j = b.pop().unwrap();
        assert!(j.enqueued.elapsed().as_secs_f64() >= 0.005);
    }
}
