//! Request batcher / admission controller.
//!
//! The decode engine is single-stream (batch = 1, matching the paper's
//! serving setup), so the batcher's job is admission control and ordering:
//! a bounded priority queue with deadline-aware (EDF) ordering inside each
//! priority class, FIFO tie-breaking, queue-time accounting, and early
//! load shedding. Higher `priority` values are served first; within a
//! priority, jobs with earlier deadlines are served first and deadline-free
//! jobs last.
//!
//! ## Deadlines and shedding
//!
//! Deadlines are absolute milliseconds on a caller-supplied monotonic
//! clock (`now_ms`): the serving coordinator uses wall time since worker
//! start, deterministic tests and benches drive a virtual clock. The
//! batcher learns the observed per-round drain time via
//! [`Batcher::observe_round_ms`] (EWMA) and sheds a job *at admission*
//! when `queue depth x observed round time` already exceeds the job's
//! deadline budget — answering with a `retry_after_ms` hint instead of
//! letting the queue collapse under sustained overload.
//!
//! ## Accounting invariant
//!
//! Every job that entered the queue leaves it exactly once, by `pop` or
//! by displacement:
//!
//! ```text
//! enqueued_total == popped_total + evicted_total + len()
//! ```
//!
//! Turned-away work (`rejected_total` for plain full-queue rejects,
//! `shed_total` for deadline/overload sheds) never enters the queue and
//! never counts toward `enqueued_total`.

use std::cmp::Reverse;
use std::time::Instant;

/// Default smoothing factor for the observed round-time EWMA
/// (`observe_round_ms`): each observation contributes a quarter of the
/// new estimate. Overridable via [`Batcher::with_ewma_alpha`].
pub const DEFAULT_EWMA_ALPHA: f64 = 0.25;

/// A queued unit of work.
pub struct QueuedJob<T> {
    pub payload: T,
    pub priority: i64,
    pub enqueued: Instant,
    /// Absolute deadline on the caller's clock (ms); `None` = no SLO.
    pub deadline_at_ms: Option<u64>,
    seq: u64,
}

impl<T> QueuedJob<T> {
    /// Milliseconds this job has spent queued so far (wall clock) —
    /// available to the caller even for displaced victims, so wasted
    /// queue time is never lost.
    pub fn queue_ms(&self) -> f64 {
        self.enqueued.elapsed().as_secs_f64() * 1e3
    }

    /// Urgency key: greater = served sooner. Priority first, then EDF
    /// (earlier deadline first, deadline-free last), then FIFO.
    fn urgency(&self) -> (i64, Reverse<u64>, Reverse<u64>) {
        (
            self.priority,
            Reverse(self.deadline_at_ms.unwrap_or(u64::MAX)),
            Reverse(self.seq),
        )
    }
}

impl<T> PartialEq for QueuedJob<T> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<T> Eq for QueuedJob<T> {}
impl<T> PartialOrd for QueuedJob<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for QueuedJob<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.urgency().cmp(&other.urgency())
    }
}

/// Outcome of a deadline/priority-aware admission attempt (`admit`).
pub enum Admission<T> {
    /// Admitted; if the queue was full, the displaced least-urgent job is
    /// returned (with its enqueue timestamp intact) so the caller can
    /// answer it and account its wasted queue time.
    Admitted(Option<QueuedJob<T>>),
    /// Queue full of equal-or-more-urgent work, or the job's deadline
    /// budget is already unmeetable: payload handed back with a hint for
    /// when capacity is expected (queue depth x observed round time).
    Shed { payload: T, retry_after_ms: u64 },
}

pub struct Batcher<T> {
    heap: std::collections::BinaryHeap<QueuedJob<T>>,
    next_seq: u64,
    max_queue: usize,
    /// EWMA of the observed serving-round time (ms); 0 until observed.
    round_ms: f64,
    /// Smoothing factor of the round-time EWMA, in (0, 1].
    ewma_alpha: f64,
    /// Jobs that entered the queue.
    pub enqueued_total: u64,
    /// Jobs handed out by `pop` (admitted to serving).
    pub popped_total: u64,
    /// Admitted jobs displaced by a more urgent newcomer.
    pub evicted_total: u64,
    /// Jobs turned away by plain full-queue backpressure (`push`).
    pub rejected_total: u64,
    /// Jobs turned away early with a retry-after hint (`admit`).
    pub shed_total: u64,
}

impl<T> Batcher<T> {
    pub fn new(max_queue: usize) -> Self {
        Batcher::with_ewma_alpha(max_queue, DEFAULT_EWMA_ALPHA)
    }

    /// `new` with an explicit round-time EWMA smoothing factor. Values
    /// outside (0, 1] are clamped: alpha 1 tracks the last observation
    /// exactly, small alphas smooth harder.
    pub fn with_ewma_alpha(max_queue: usize, ewma_alpha: f64) -> Self {
        let ewma_alpha = if ewma_alpha.is_finite() {
            ewma_alpha.clamp(f64::EPSILON, 1.0)
        } else {
            DEFAULT_EWMA_ALPHA
        };
        Batcher {
            heap: std::collections::BinaryHeap::new(),
            next_seq: 0,
            max_queue,
            round_ms: 0.0,
            ewma_alpha,
            enqueued_total: 0,
            popped_total: 0,
            evicted_total: 0,
            rejected_total: 0,
            shed_total: 0,
        }
    }

    /// Feed one observed serving-round duration (ms) into the drain-time
    /// estimate (EWMA, alpha `DEFAULT_EWMA_ALPHA` unless overridden).
    pub fn observe_round_ms(&mut self, ms: f64) {
        if !(ms.is_finite() && ms >= 0.0) {
            return;
        }
        self.round_ms = if self.round_ms == 0.0 {
            ms
        } else {
            (1.0 - self.ewma_alpha) * self.round_ms + self.ewma_alpha * ms
        };
    }

    /// Estimated queue wait (ms): queue depth x observed round time.
    /// Zero until the first round has been observed.
    pub fn estimated_wait_ms(&self) -> f64 {
        self.heap.len() as f64 * self.round_ms
    }

    /// Round-time EWMA (ms) on its own, independent of queue depth — the
    /// adaptive controller's latency pressure term reads this. Zero until
    /// the first round has been observed.
    pub fn round_ms(&self) -> f64 {
        self.round_ms
    }

    fn push_job(&mut self, payload: T, priority: i64,
                deadline_at_ms: Option<u64>) {
        self.heap.push(QueuedJob {
            payload,
            priority,
            enqueued: Instant::now(),
            deadline_at_ms,
            seq: self.next_seq,
        });
        self.next_seq += 1;
        self.enqueued_total += 1;
    }

    /// Plain admission (no deadline, no displacement): returns false
    /// (backpressure) when the queue is full.
    pub fn push(&mut self, payload: T, priority: i64) -> bool {
        if self.heap.len() >= self.max_queue {
            self.rejected_total += 1;
            return false;
        }
        self.push_job(payload, priority, None);
        true
    }

    pub fn pop(&mut self) -> Option<QueuedJob<T>> {
        let j = self.heap.pop();
        if j.is_some() {
            self.popped_total += 1;
        }
        j
    }

    /// Borrow the job `pop` would return next, without disturbing its
    /// queue position or enqueue timestamp (admission checks that may
    /// decide to leave it queued).
    pub fn peek(&self) -> Option<&QueuedJob<T>> {
        self.heap.peek()
    }

    /// At capacity: the next `push` would be rejected.
    pub fn is_full(&self) -> bool {
        self.heap.len() >= self.max_queue
    }

    /// Retry-after hint for a shed job: the time the current queue needs
    /// to drain at the observed round time, floored at one round (or 1 ms
    /// before any round has been observed).
    fn retry_after_ms(&self) -> u64 {
        (self.estimated_wait_ms().max(self.round_ms).max(1.0)).ceil() as u64
    }

    /// Deadline/priority-aware admission. `now_ms` is the caller's clock
    /// (same clock `deadline_at_ms` is on).
    ///
    /// 1. Early shed: once round time has been observed, a job whose
    ///    deadline budget is smaller than the estimated queue wait is
    ///    turned away immediately with a retry-after hint — it would
    ///    only miss its deadline in the queue and starve others.
    /// 2. Spare capacity: enqueue.
    /// 3. Full queue: the least-urgent queued job (lowest priority, then
    ///    latest/absent deadline, then newest) is displaced if the
    ///    newcomer outranks it, otherwise the newcomer is shed. Exactly
    ///    one job loses in either case.
    pub fn admit(&mut self, payload: T, priority: i64,
                 deadline_at_ms: Option<u64>, now_ms: u64) -> Admission<T> {
        if let Some(d) = deadline_at_ms {
            let budget_ms = d.saturating_sub(now_ms) as f64;
            if self.round_ms > 0.0 && self.estimated_wait_ms() > budget_ms {
                self.shed_total += 1;
                let retry_after_ms = self.retry_after_ms();
                return Admission::Shed { payload, retry_after_ms };
            }
        }
        if self.heap.len() < self.max_queue {
            self.push_job(payload, priority, deadline_at_ms);
            return Admission::Admitted(None);
        }
        // victim candidate: least urgent; found by a borrow-only scan so
        // the shed path (the common case under sustained overload) never
        // deconstructs the heap
        let victim = self
            .heap
            .iter()
            .map(|j| (j.priority, j.deadline_at_ms.unwrap_or(u64::MAX), j.seq))
            .min_by_key(|&(pri, dl, seq)| (pri, Reverse(dl), Reverse(seq)));
        let Some((v_pri, v_dl, v_seq)) = victim else {
            // zero-capacity queue: nothing to displace
            self.shed_total += 1;
            let retry_after_ms = self.retry_after_ms();
            return Admission::Shed { payload, retry_after_ms };
        };
        let new_dl = deadline_at_ms.unwrap_or(u64::MAX);
        // the newcomer must strictly outrank the victim (ties keep FIFO)
        if (v_pri, Reverse(v_dl)) >= (priority, Reverse(new_dl)) {
            self.shed_total += 1;
            let retry_after_ms = self.retry_after_ms();
            return Admission::Shed { payload, retry_after_ms };
        }
        let evicted = if self.heap.peek().map(|j| j.seq) == Some(v_seq) {
            // least-urgent job is the heap top (e.g. capacity-1 queues):
            // pop directly instead of rebuilding the heap
            match self.heap.pop() {
                Some(j) => j,
                // peek just said the top exists; if the heap somehow
                // raced empty, shed the newcomer instead of dying
                None => {
                    self.shed_total += 1;
                    let retry_after_ms = self.retry_after_ms();
                    return Admission::Shed { payload, retry_after_ms };
                }
            }
        } else {
            let mut v = std::mem::take(&mut self.heap).into_vec();
            match v.iter().position(|j| j.seq == v_seq) {
                Some(pos) => {
                    let evicted = v.swap_remove(pos);
                    self.heap = std::collections::BinaryHeap::from(v);
                    evicted
                }
                // the victim scan found v_seq in this same heap moments
                // ago; if it vanished, restore the heap untouched and
                // shed the newcomer — never panic mid-admission
                None => {
                    self.heap = std::collections::BinaryHeap::from(v);
                    self.shed_total += 1;
                    let retry_after_ms = self.retry_after_ms();
                    return Admission::Shed { payload, retry_after_ms };
                }
            }
        };
        self.evicted_total += 1;
        self.push_job(payload, priority, deadline_at_ms);
        Admission::Admitted(Some(evicted))
    }

    /// Priority-aware admission without a deadline (legacy entry point;
    /// see `admit`).
    pub fn push_evicting(&mut self, payload: T, priority: i64)
                         -> Admission<T> {
        self.admit(payload, priority, None, 0)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `enqueued_total == popped + evicted + still-queued`, always.
    fn assert_invariant<T>(b: &Batcher<T>) {
        assert_eq!(
            b.enqueued_total,
            b.popped_total + b.evicted_total + b.len() as u64,
            "admission accounting drifted"
        );
    }

    #[test]
    fn fifo_within_priority() {
        let mut b = Batcher::new(10);
        b.push("a", 0);
        b.push("b", 0);
        b.push("c", 0);
        assert_eq!(b.pop().unwrap().payload, "a");
        assert_eq!(b.pop().unwrap().payload, "b");
        assert_eq!(b.pop().unwrap().payload, "c");
        assert_invariant(&b);
    }

    #[test]
    fn priority_wins() {
        let mut b = Batcher::new(10);
        b.push("low", 0);
        b.push("high", 5);
        b.push("mid", 2);
        assert_eq!(b.pop().unwrap().payload, "high");
        assert_eq!(b.pop().unwrap().payload, "mid");
        assert_eq!(b.pop().unwrap().payload, "low");
    }

    #[test]
    fn edf_within_priority_deadline_free_last() {
        let mut b = Batcher::new(10);
        b.admit("no-slo", 0, None, 0);
        b.admit("late", 0, Some(900), 0);
        b.admit("soon", 0, Some(200), 0);
        b.admit("urgent-low-pri", -1, Some(10), 0);
        assert_eq!(b.pop().unwrap().payload, "soon");
        assert_eq!(b.pop().unwrap().payload, "late");
        assert_eq!(b.pop().unwrap().payload, "no-slo");
        // priority still dominates the deadline
        assert_eq!(b.pop().unwrap().payload, "urgent-low-pri");
        assert_invariant(&b);
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(2);
        assert!(b.push(1, 0));
        assert!(b.push(2, 0));
        assert!(!b.push(3, 0));
        assert_eq!(b.rejected_total, 1);
        assert_invariant(&b);
        b.pop();
        assert!(b.push(3, 0));
        assert_invariant(&b);
    }

    #[test]
    fn eviction_prefers_low_priority_newest() {
        let mut b = Batcher::new(3);
        b.push("old-low", 0);
        b.push("high", 5);
        b.push("new-low", 0);
        // newcomer outranks the lows: newest low is displaced
        match b.push_evicting("mid", 2) {
            Admission::Admitted(Some(evicted)) => {
                assert_eq!(evicted.payload, "new-low");
                // wasted queue time of the victim is still readable
                assert!(evicted.queue_ms() >= 0.0);
            }
            _ => panic!("expected eviction"),
        }
        assert_eq!(b.len(), 3);
        // an admitted-by-displacement job is NOT a rejection: the newcomer
        // entered the queue and the victim left it as an eviction
        assert_eq!(b.evicted_total, 1);
        assert_eq!(b.shed_total, 0);
        assert_invariant(&b);
        // newcomer that ties the lowest is shed (FIFO respected)
        match b.push_evicting("tie-low", 0) {
            Admission::Shed { payload, .. } => assert_eq!(payload, "tie-low"),
            _ => panic!("tie must not evict"),
        }
        assert_eq!(b.shed_total, 1);
        assert_invariant(&b);
        // drain order: priority desc, FIFO within priority
        assert_eq!(b.pop().unwrap().payload, "high");
        assert_eq!(b.pop().unwrap().payload, "mid");
        assert_eq!(b.pop().unwrap().payload, "old-low");
        assert_invariant(&b);
    }

    #[test]
    fn eviction_pops_directly_when_victim_is_heap_top() {
        // capacity-1 queue: the only queued job is both heap top and
        // victim; the fast path must still hand it back intact
        let mut b = Batcher::new(1);
        b.push("low", 0);
        match b.push_evicting("high", 9) {
            Admission::Admitted(Some(evicted)) => {
                assert_eq!(evicted.payload, "low");
            }
            _ => panic!("expected eviction"),
        }
        assert_eq!(b.pop().unwrap().payload, "high");
        assert_invariant(&b);
    }

    #[test]
    fn deadline_eviction_displaces_most_slack_first() {
        let mut b = Batcher::new(2);
        b.admit("slack", 0, Some(5_000), 0);
        b.admit("tight", 0, Some(100), 0);
        // same priority, tighter deadline: displaces the slack job
        match b.admit("tighter", 0, Some(50), 0) {
            Admission::Admitted(Some(evicted)) => {
                assert_eq!(evicted.payload, "slack");
            }
            _ => panic!("expected eviction of the most-slack job"),
        }
        assert_eq!(b.pop().unwrap().payload, "tighter");
        assert_eq!(b.pop().unwrap().payload, "tight");
        assert_invariant(&b);
    }

    #[test]
    fn unmeetable_deadline_is_shed_with_retry_after() {
        let mut b = Batcher::new(100);
        b.observe_round_ms(10.0);
        for i in 0..20 {
            b.admit(i, 0, None, 0);
        }
        // estimated wait = 20 x 10 ms; a 50 ms budget cannot be met
        match b.admit(99, 0, Some(1_050), 1_000) {
            Admission::Shed { payload, retry_after_ms } => {
                assert_eq!(payload, 99);
                assert!(retry_after_ms >= 200,
                        "retry hint should cover the queue drain");
            }
            _ => panic!("expected early shed"),
        }
        assert_eq!(b.shed_total, 1);
        // a job with enough budget still admits
        assert!(matches!(b.admit(7, 0, Some(2_000), 1_000),
                         Admission::Admitted(None)));
        // deadline-free jobs are never early-shed
        assert!(matches!(b.admit(8, 0, None, 1_000),
                         Admission::Admitted(None)));
        assert_invariant(&b);
    }

    #[test]
    fn no_early_shed_before_round_time_observed() {
        let mut b = Batcher::new(10);
        for i in 0..5 {
            b.admit(i, 0, None, 0);
        }
        // round time unknown: even a 0-budget job is admitted (EDF will
        // order it first)
        assert!(matches!(b.admit(9, 0, Some(0), 0),
                         Admission::Admitted(None)));
        assert_invariant(&b);
    }

    #[test]
    fn push_evicting_on_spare_capacity_is_plain_push() {
        let mut b = Batcher::new(2);
        assert!(matches!(b.push_evicting(1, 0), Admission::Admitted(None)));
        assert!(b.push(2, 1));
        assert!(b.is_full());
        assert_eq!(b.enqueued_total, 2);
        assert_invariant(&b);
    }

    #[test]
    fn peek_matches_pop_and_preserves_order() {
        let mut b = Batcher::new(4);
        b.push("lo", 0);
        b.push("hi", 3);
        assert_eq!(b.peek().unwrap().payload, "hi");
        // peeking does not consume or reorder
        assert_eq!(b.len(), 2);
        assert_eq!(b.pop().unwrap().payload, "hi");
        assert_eq!(b.peek().unwrap().payload, "lo");
    }

    #[test]
    fn queue_time_is_tracked() {
        let mut b = Batcher::new(4);
        b.push((), 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let j = b.pop().unwrap();
        assert!(j.queue_ms() >= 5.0);
        assert!(j.enqueued.elapsed().as_secs_f64() >= 0.005);
    }

    #[test]
    fn round_time_ewma_converges() {
        let mut b: Batcher<()> = Batcher::new(4);
        assert_eq!(b.estimated_wait_ms(), 0.0);
        b.observe_round_ms(8.0);
        for _ in 0..64 {
            b.observe_round_ms(4.0);
        }
        b.push((), 0);
        b.push((), 0);
        // 2 queued x ~4 ms rounds
        let est = b.estimated_wait_ms();
        assert!(est > 7.0 && est < 9.0, "est {est}");
    }

    #[test]
    fn ewma_alpha_is_configurable() {
        // alpha 1.0: the estimate tracks the last observation exactly
        let mut fast: Batcher<()> = Batcher::with_ewma_alpha(4, 1.0);
        fast.observe_round_ms(8.0);
        fast.observe_round_ms(2.0);
        fast.push((), 0);
        assert_eq!(fast.estimated_wait_ms(), 2.0);

        // the default constructor matches an explicit DEFAULT_EWMA_ALPHA
        let mut a: Batcher<()> = Batcher::new(4);
        let mut b: Batcher<()> =
            Batcher::with_ewma_alpha(4, DEFAULT_EWMA_ALPHA);
        for ms in [8.0, 4.0, 6.0, 2.0] {
            a.observe_round_ms(ms);
            b.observe_round_ms(ms);
        }
        a.push((), 0);
        b.push((), 0);
        assert_eq!(a.estimated_wait_ms(), b.estimated_wait_ms());

        // out-of-range alphas are clamped into (0, 1] instead of
        // producing a frozen or oscillating estimator
        let mut c: Batcher<()> = Batcher::with_ewma_alpha(4, 7.5);
        c.observe_round_ms(8.0);
        c.observe_round_ms(2.0);
        c.push((), 0);
        assert_eq!(c.estimated_wait_ms(), 2.0);
    }

    #[test]
    fn accounting_invariant_under_churn() {
        let mut b = Batcher::new(4);
        let mut served = 0u64;
        for i in 0..64i64 {
            let dl = if i % 3 == 0 { Some(100 + i as u64) } else { None };
            b.admit(i, i % 5, dl, 0);
            if i % 2 == 0 && b.pop().is_some() {
                served += 1;
            }
            assert_invariant(&b);
        }
        while b.pop().is_some() {
            served += 1;
        }
        assert_invariant(&b);
        assert_eq!(b.enqueued_total, served + b.evicted_total);
    }
}
