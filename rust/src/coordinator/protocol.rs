//! JSON-line serving protocol.
//!
//! Request (one JSON object per line):
//!   {"id": "r1", "prompt": "Q EVAL 3 + 4", "gen_len": 96,
//!    "priority": 0, "strategy": "d3llm"}        // strategy optional
//!   {"cmd": "stats"} | {"cmd": "shutdown"}
//!
//! Response:
//!   {"id": "r1", "ok": true, "text": "...", "tokens": [..],
//!    "tpf": 5.1, "forwards": 12, "gen_tokens": 61,
//!    "queue_ms": 0.3, "decode_ms": 210.0}
//!   {"id": "r1", "ok": false, "error": "..."}

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub enum Request {
    Generate(GenRequest),
    Stats,
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: String,
    pub prompt: String,
    pub gen_len: Option<usize>,
    pub priority: i64,
    pub strategy: Option<String>,
}

#[derive(Debug, Clone, Default)]
pub struct GenResponse {
    pub id: String,
    pub text: String,
    pub tokens: Vec<i32>,
    pub tpf: f64,
    pub forwards: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    pub decode_ms: f64,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("{e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow!("unknown cmd `{other}`")),
        };
    }
    let id = j
        .get("id")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `id`"))?
        .to_string();
    let prompt = j
        .get("prompt")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `prompt`"))?
        .to_string();
    Ok(Request::Generate(GenRequest {
        id,
        prompt,
        gen_len: j.get("gen_len").and_then(|v| v.as_usize()),
        priority: j.get("priority").and_then(|v| v.as_i64()).unwrap_or(0),
        strategy: j
            .get("strategy")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
    }))
}

pub fn ok_response(r: &GenResponse) -> String {
    Json::obj(vec![
        ("id", Json::str(r.id.clone())),
        ("ok", Json::Bool(true)),
        ("text", Json::str(r.text.clone())),
        ("tokens",
         Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64)))),
        ("tpf", Json::num(r.tpf)),
        ("forwards", Json::num(r.forwards as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("queue_ms", Json::num(r.queue_ms)),
        ("decode_ms", Json::num(r.decode_ms)),
    ])
    .to_string()
}

pub fn err_response(id: &str, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let r = parse_request(
            r#"{"id":"a","prompt":"Q EVAL 1 + 2","gen_len":96,"priority":2}"#,
        )
        .unwrap();
        match r {
            Request::Generate(g) => {
                assert_eq!(g.id, "a");
                assert_eq!(g.gen_len, Some(96));
                assert_eq!(g.priority, 2);
                assert!(g.strategy.is_none());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_cmds() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#).unwrap(),
                         Request::Stats));
        assert!(matches!(parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
                         Request::Shutdown));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt":"x"}"#).is_err()); // no id
        assert!(parse_request(r#"{"cmd":"nope"}"#).is_err());
    }

    #[test]
    fn responses_roundtrip() {
        let resp = GenResponse {
            id: "r".into(),
            text: "ANS 7".into(),
            tokens: vec![1, 2],
            tpf: 3.5,
            forwards: 4,
            gen_tokens: 14,
            queue_ms: 0.4,
            decode_ms: 9.0,
        };
        let line = ok_response(&resp);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("tpf").unwrap().as_f64(), Some(3.5));
        let e = err_response("x", "boom");
        let j = json::parse(&e).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }
}
