//! JSON-line serving protocol.
//!
//! Request (one JSON object per line):
//!   {"id": "r1", "prompt": "Q EVAL 3 + 4", "gen_len": 96,
//!    "priority": 0, "strategy": "d3llm",        // strategy optional
//!    "slo": "interactive", "deadline_ms": 250}  // SLO fields optional
//!   {"cmd": "stats"} | {"cmd": "shutdown"}
//!
//! `slo` names the request's service class (`interactive` / `standard` /
//! `batch`); `deadline_ms` overrides the class's default latency budget.
//! Without either, a request serves as `standard` with no deadline (the
//! pre-SLO behavior: never shed, never preempted).
//!
//! Response:
//!   {"id": "r1", "ok": true, "text": "...", "tokens": [..],
//!    "tpf": 5.1, "forwards": 12, "gen_tokens": 61,
//!    "queue_ms": 0.3, "decode_ms": 210.0,
//!    "slo": "standard", "deadline_missed": false}
//!   {"id": "r1", "ok": false, "error": "..."}
//!   {"id": "r1", "ok": false, "error": "shed: queue overloaded",
//!    "retry_after_ms": 120}                     // shed under overload

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

/// Service-level objective class of a request. Classes only set the
/// *default* deadline budget and label the per-class serving counters;
/// scheduling itself is driven by `priority` and the resolved deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    /// Tight latency budget (user-facing chat turns).
    Interactive,
    /// Default class: relaxed budget.
    Standard,
    /// Throughput work: no deadline, first to be shed or preempted.
    Batch,
}

impl SloClass {
    pub const ALL: [SloClass; 3] =
        [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// Stable index for per-class counter arrays.
    pub fn idx(&self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    /// Default latency budget when the request names a class but no
    /// explicit `deadline_ms`. `None` = no deadline (never shed on SLO).
    pub fn default_deadline_ms(&self) -> Option<u64> {
        match self {
            SloClass::Interactive => Some(500),
            SloClass::Standard => Some(2_000),
            SloClass::Batch => None,
        }
    }
}

#[derive(Debug, Clone)]
pub enum Request {
    Generate(GenRequest),
    Stats,
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: String,
    pub prompt: String,
    pub gen_len: Option<usize>,
    pub priority: i64,
    pub strategy: Option<String>,
    /// SLO class (accounting + default deadline). `Standard` when absent.
    pub slo: SloClass,
    /// Effective latency budget in ms from enqueue, resolved at parse
    /// time: an explicit `deadline_ms` wins; a request that only named a
    /// class gets the class default; a request with neither has no
    /// deadline (legacy behavior: never shed on SLO, never preempted).
    pub deadline_ms: Option<u64>,
}

#[derive(Debug, Clone, Default)]
pub struct GenResponse {
    pub id: String,
    pub text: String,
    pub tokens: Vec<i32>,
    pub tpf: f64,
    pub forwards: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    pub decode_ms: f64,
    /// SLO class name the request was served under.
    pub slo: String,
    /// True when the request finished past its deadline budget.
    pub deadline_missed: bool,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("{e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow!("unknown cmd `{other}`")),
        };
    }
    parse_generate(&j).map(Request::Generate)
}

fn parse_generate(j: &Json) -> Result<GenRequest> {
    let id = j
        .get("id")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `id`"))?
        .to_string();
    let prompt = j
        .get("prompt")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `prompt`"))?
        .to_string();
    let slo_raw = j.get("slo").and_then(|v| v.as_str());
    let slo = match slo_raw {
        Some(s) => {
            SloClass::parse(s).ok_or_else(|| anyhow!("unknown slo `{s}`"))?
        }
        None => SloClass::Standard,
    };
    // resolve the effective deadline here: explicit budget wins, the
    // class default applies only when the line named a class, and a line
    // with neither keeps the legacy no-deadline behavior
    let deadline_ms = j
        .get("deadline_ms")
        .and_then(|v| v.as_f64())
        .filter(|d| *d >= 0.0)
        .map(|d| d as u64)
        .or_else(|| {
            if slo_raw.is_some() { slo.default_deadline_ms() } else { None }
        });
    Ok(GenRequest {
        id,
        prompt,
        gen_len: j.get("gen_len").and_then(|v| v.as_usize()),
        priority: j.get("priority").and_then(|v| v.as_i64()).unwrap_or(0),
        strategy: j
            .get("strategy")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
        slo,
        deadline_ms,
    })
}

pub fn ok_response(r: &GenResponse) -> String {
    Json::obj(vec![
        ("id", Json::str(r.id.clone())),
        ("ok", Json::Bool(true)),
        ("text", Json::str(r.text.clone())),
        ("tokens",
         Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64)))),
        ("tpf", Json::num(r.tpf)),
        ("forwards", Json::num(r.forwards as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("queue_ms", Json::num(r.queue_ms)),
        ("decode_ms", Json::num(r.decode_ms)),
        ("slo", Json::str(r.slo.clone())),
        ("deadline_missed", Json::Bool(r.deadline_missed)),
    ])
    .to_string()
}

/// Load-shed reply: the request was turned away before decoding (queue
/// overload or unmeetable deadline) with a hint for when to retry.
pub fn shed_response(id: &str, reason: &str, retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(format!("shed: {reason}"))),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
    .to_string()
}

/// Serialize the server stats snapshot, including the interleaving
/// gauges (queue depth, live sessions), the SLO serving counters
/// (per-class served/shed/deadline-miss + latency totals) and per-session
/// progress.
pub fn stats_response(s: &super::ServerStats) -> String {
    let mut fields = vec![("ok", Json::Bool(true))];
    fields.extend(stats_fields(s, None));
    Json::obj(fields).to_string()
}

/// Bounds-checked counter read for the fixed-size stats arrays (per-SLO
/// class, width histogram). The index is in range by construction
/// (`SloClass::idx()` / histogram bucket loops), but the stats path must
/// stay panic-free, so an out-of-range slot reads as zero.
fn counter_at(arr: &[std::sync::atomic::AtomicU64], i: usize) -> u64 {
    arr.get(i)
        .map_or(0, |a| a.load(std::sync::atomic::Ordering::Relaxed))
}

/// The shared field set of one `ServerStats` snapshot — used verbatim by
/// the single-stats response and per-replica objects of the fleet
/// response, and (name-for-name) by the fleet aggregates, so the wire
/// names stay pinned in exactly one place. `replica` tags each session
/// entry with its home replica when serving a fleet.
fn stats_fields(s: &super::ServerStats, replica: Option<usize>)
                -> Vec<(&'static str, Json)> {
    use std::sync::atomic::Ordering::Relaxed;
    let sessions: Vec<Json> = s
        .sessions
        .lock()
        .map(|v| {
            v.iter()
                .map(|(id, p)| {
                    let mut f = vec![("id", Json::str(id.clone()))];
                    if let Some(r) = replica {
                        f.push(("replica", Json::num(r as f64)));
                    }
                    f.extend(vec![
                        ("unmasked", Json::num(p.unmasked as f64)),
                        ("gen_len", Json::num(p.gen_len as f64)),
                        ("steps", Json::num(p.steps as f64)),
                        ("rounds", Json::num(p.rounds as f64)),
                        ("forwards", Json::num(p.forwards as f64)),
                        ("paused_rounds",
                         Json::num(p.paused_rounds as f64)),
                    ]);
                    Json::obj(f)
                })
                .collect()
        })
        .unwrap_or_default();
    let slo: Vec<Json> = SloClass::ALL
        .iter()
        .map(|c| {
            let i = c.idx();
            Json::obj(vec![
                ("class", Json::str(c.name())),
                ("served",
                 Json::num(counter_at(&s.served_by_class, i) as f64)),
                ("shed",
                 Json::num(counter_at(&s.shed_by_class, i) as f64)),
                ("deadline_miss",
                 Json::num(counter_at(&s.deadline_miss_by_class, i) as f64)),
                ("queue_ms",
                 Json::num(counter_at(&s.queue_ms_by_class, i) as f64)),
                ("decode_ms",
                 Json::num(counter_at(&s.decode_ms_by_class, i) as f64)),
            ])
        })
        .collect();
    vec![
        ("served", Json::num(s.served.load(Relaxed) as f64)),
        ("errors", Json::num(s.errors.load(Relaxed) as f64)),
        ("queue_ms", Json::num(s.queue_ms_total.load(Relaxed) as f64)),
        ("decode_ms", Json::num(s.decode_ms_total.load(Relaxed) as f64)),
        ("queue_depth", Json::num(s.queue_depth.load(Relaxed) as f64)),
        ("active_sessions",
         Json::num(s.active_sessions.load(Relaxed) as f64)),
        ("steps", Json::num(s.steps_total.load(Relaxed) as f64)),
        ("admitted", Json::num(s.admitted_total.load(Relaxed) as f64)),
        ("max_concurrent_sessions",
         Json::num(s.max_concurrent.load(Relaxed) as f64)),
        // SLO / admission counters
        ("shed", Json::num(s.shed_total.load(Relaxed) as f64)),
        ("evicted", Json::num(s.evicted_total.load(Relaxed) as f64)),
        ("deadline_misses",
         Json::num(s.deadline_miss_total.load(Relaxed) as f64)),
        ("preempted_rounds",
         Json::num(s.preempted_rounds.load(Relaxed) as f64)),
        ("slo", Json::Arr(slo)),
        // paged KV pool gauges (all zero when serving dense caches)
        ("kv_pages_total",
         Json::num(s.kv_pages_total.load(Relaxed) as f64)),
        ("kv_pages_in_use",
         Json::num(s.kv_pages_in_use.load(Relaxed) as f64)),
        ("kv_pages_reclaimable",
         Json::num(s.kv_pages_reclaimable.load(Relaxed) as f64)),
        ("kv_prefix_hits",
         Json::num(s.kv_prefix_hits.load(Relaxed) as f64)),
        ("kv_prefill_skips",
         Json::num(s.kv_prefill_skips.load(Relaxed) as f64)),
        ("kv_pages_refreshed",
         Json::num(s.kv_pages_refreshed.load(Relaxed) as f64)),
        ("kv_refresh_skips",
         Json::num(s.kv_refresh_skips.load(Relaxed) as f64)),
        ("kv_cow_copies",
         Json::num(s.kv_cow_copies.load(Relaxed) as f64)),
        ("kv_pages_spilled",
         Json::num(s.kv_pages_spilled.load(Relaxed) as f64)),
        ("kv_pages_reprefilled",
         Json::num(s.kv_pages_reprefilled.load(Relaxed) as f64)),
        // adaptive parallelism controller (all zero in `off` mode)
        ("adaptive_threshold_milli",
         Json::num(s.adaptive_threshold_milli.load(Relaxed) as f64)),
        ("adaptive_up", Json::num(s.adaptive_up.load(Relaxed) as f64)),
        ("adaptive_down", Json::num(s.adaptive_down.load(Relaxed) as f64)),
        ("adaptive_width_hist",
         Json::arr(s.adaptive_width_hist
             .iter()
             .map(|v| Json::num(v.load(Relaxed) as f64)))),
        ("sessions", Json::Arr(sessions)),
    ]
}

/// Serialize the whole fleet's stats: the pinned top-level field names of
/// `stats_response` carry fleet *sums* (so single-worker clients read the
/// same names unchanged — with one replica the sums degenerate to its
/// snapshot), `max_concurrent_sessions` echoes the per-replica config,
/// session entries gain a `replica` tag, and new `workers` / `replicas` /
/// routing fields expose the per-replica breakdown and the router's
/// affinity accounting.
pub fn fleet_stats_response(replicas: &[std::sync::Arc<super::ServerStats>],
                            core: &super::router::RouterCore) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let sum = |f: &dyn Fn(&super::ServerStats) -> u64| -> f64 {
        replicas.iter().map(|s| f(s)).sum::<u64>() as f64
    };
    let slo: Vec<Json> = SloClass::ALL
        .iter()
        .map(|c| {
            let i = c.idx();
            Json::obj(vec![
                ("class", Json::str(c.name())),
                ("served",
                 Json::num(sum(&|s| counter_at(&s.served_by_class, i)))),
                ("shed",
                 Json::num(sum(&|s| counter_at(&s.shed_by_class, i)))),
                ("deadline_miss",
                 Json::num(sum(
                     &|s| counter_at(&s.deadline_miss_by_class, i)))),
                ("queue_ms",
                 Json::num(sum(&|s| counter_at(&s.queue_ms_by_class, i)))),
                ("decode_ms",
                 Json::num(sum(&|s| counter_at(&s.decode_ms_by_class, i)))),
            ])
        })
        .collect();
    let sessions: Vec<Json> = replicas
        .iter()
        .enumerate()
        .flat_map(|(r, s)| {
            s.sessions
                .lock()
                .map(|v| {
                    v.iter()
                        .map(|(id, p)| {
                            Json::obj(vec![
                                ("id", Json::str(id.clone())),
                                ("replica", Json::num(r as f64)),
                                ("unmasked", Json::num(p.unmasked as f64)),
                                ("gen_len", Json::num(p.gen_len as f64)),
                                ("steps", Json::num(p.steps as f64)),
                                ("rounds", Json::num(p.rounds as f64)),
                                ("forwards", Json::num(p.forwards as f64)),
                                ("paused_rounds",
                                 Json::num(p.paused_rounds as f64)),
                            ])
                        })
                        .collect::<Vec<Json>>()
                })
                .unwrap_or_default()
        })
        .collect();
    let per_replica: Vec<Json> = replicas
        .iter()
        .enumerate()
        .map(|(r, s)| {
            let mut f = vec![
                ("replica", Json::num(r as f64)),
                ("alive", Json::Bool(core.alive(r))),
            ];
            f.extend(stats_fields(s, Some(r)));
            Json::obj(f)
        })
        .collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("served", Json::num(sum(&|s| s.served.load(Relaxed)))),
        // acceptor-side protocol errors never reach a replica, so the
        // fleet total adds them on top of the per-replica sums
        ("errors",
         Json::num(sum(&|s| s.errors.load(Relaxed))
                   + core.conn_errors.load(Relaxed) as f64)),
        ("queue_ms", Json::num(sum(&|s| s.queue_ms_total.load(Relaxed)))),
        ("decode_ms", Json::num(sum(&|s| s.decode_ms_total.load(Relaxed)))),
        ("queue_depth", Json::num(sum(&|s| s.queue_depth.load(Relaxed)))),
        ("active_sessions",
         Json::num(sum(&|s| s.active_sessions.load(Relaxed)))),
        ("steps", Json::num(sum(&|s| s.steps_total.load(Relaxed)))),
        ("admitted", Json::num(sum(&|s| s.admitted_total.load(Relaxed)))),
        // config echo, not a sum: the per-replica interleaving width
        ("max_concurrent_sessions",
         Json::num(replicas.first()
                       .map(|s| s.max_concurrent.load(Relaxed))
                       .unwrap_or(0) as f64)),
        ("shed", Json::num(sum(&|s| s.shed_total.load(Relaxed)))),
        ("evicted", Json::num(sum(&|s| s.evicted_total.load(Relaxed)))),
        ("deadline_misses",
         Json::num(sum(&|s| s.deadline_miss_total.load(Relaxed)))),
        ("preempted_rounds",
         Json::num(sum(&|s| s.preempted_rounds.load(Relaxed)))),
        ("slo", Json::Arr(slo)),
        ("kv_pages_total",
         Json::num(sum(&|s| s.kv_pages_total.load(Relaxed)))),
        ("kv_pages_in_use",
         Json::num(sum(&|s| s.kv_pages_in_use.load(Relaxed)))),
        ("kv_pages_reclaimable",
         Json::num(sum(&|s| s.kv_pages_reclaimable.load(Relaxed)))),
        ("kv_prefix_hits",
         Json::num(sum(&|s| s.kv_prefix_hits.load(Relaxed)))),
        ("kv_prefill_skips",
         Json::num(sum(&|s| s.kv_prefill_skips.load(Relaxed)))),
        ("kv_pages_refreshed",
         Json::num(sum(&|s| s.kv_pages_refreshed.load(Relaxed)))),
        ("kv_refresh_skips",
         Json::num(sum(&|s| s.kv_refresh_skips.load(Relaxed)))),
        ("kv_cow_copies",
         Json::num(sum(&|s| s.kv_cow_copies.load(Relaxed)))),
        ("kv_pages_spilled",
         Json::num(sum(&|s| s.kv_pages_spilled.load(Relaxed)))),
        ("kv_pages_reprefilled",
         Json::num(sum(&|s| s.kv_pages_reprefilled.load(Relaxed)))),
        // adaptive controller: counters/histogram sum fleet-wide; the
        // threshold gauge reports the fleet max (the most aggressive
        // replica) — per-replica values live in `replicas`
        ("adaptive_threshold_milli",
         Json::num(replicas
             .iter()
             .map(|s| s.adaptive_threshold_milli.load(Relaxed))
             .max()
             .unwrap_or(0) as f64)),
        ("adaptive_up", Json::num(sum(&|s| s.adaptive_up.load(Relaxed)))),
        ("adaptive_down",
         Json::num(sum(&|s| s.adaptive_down.load(Relaxed)))),
        ("adaptive_width_hist",
         Json::arr((0..crate::decode::WIDTH_HIST_BUCKETS).map(|i| {
             Json::num(sum(&|s: &super::ServerStats| {
                 counter_at(&s.adaptive_width_hist, i)
             }))
         }))),
        ("sessions", Json::Arr(sessions)),
        // ---- fleet topology + routing
        ("workers", Json::num(replicas.len() as f64)),
        ("replicas_alive", Json::num(core.alive_count() as f64)),
        ("affinity_hits",
         Json::num(core.affinity_hits.load(Relaxed) as f64)),
        ("affinity_spills",
         Json::num(core.affinity_spills.load(Relaxed) as f64)),
        ("cold_placements",
         Json::num(core.cold_placements.load(Relaxed) as f64)),
        ("jobs_rerouted",
         Json::num(core.jobs_rerouted.load(Relaxed) as f64)),
        ("replica_deaths",
         Json::num(core.replica_deaths.load(Relaxed) as f64)),
        ("replicas", Json::Arr(per_replica)),
    ])
    .to_string()
}

pub fn err_response(id: &str, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let r = parse_request(
            r#"{"id":"a","prompt":"Q EVAL 1 + 2","gen_len":96,"priority":2}"#,
        )
        .unwrap();
        match r {
            Request::Generate(g) => {
                assert_eq!(g.id, "a");
                assert_eq!(g.gen_len, Some(96));
                assert_eq!(g.priority, 2);
                assert!(g.strategy.is_none());
                assert_eq!(g.slo, SloClass::Standard);
                assert!(g.deadline_ms.is_none());
            }
            _ => panic!(),
        }
    }

    fn gen_req(line: &str) -> GenRequest {
        match parse_request(line).unwrap() {
            Request::Generate(g) => g,
            _ => panic!("expected generate"),
        }
    }

    #[test]
    fn parse_slo_fields() {
        // explicit deadline wins over the class default
        let g = gen_req(
            r#"{"id":"a","prompt":"x","slo":"interactive","deadline_ms":250}"#,
        );
        assert_eq!(g.slo, SloClass::Interactive);
        assert_eq!(g.deadline_ms, Some(250));

        // class default applies when only the class is named
        let g = gen_req(r#"{"id":"a","prompt":"x","slo":"interactive"}"#);
        assert_eq!(g.deadline_ms, Some(500));

        // batch: no default deadline
        let g = gen_req(r#"{"id":"a","prompt":"x","slo":"batch"}"#);
        assert_eq!(g.slo, SloClass::Batch);
        assert_eq!(g.deadline_ms, None);

        // no SLO fields: legacy behavior, no deadline at all
        let g = gen_req(r#"{"id":"a","prompt":"x"}"#);
        assert_eq!(g.slo, SloClass::Standard);
        assert_eq!(g.deadline_ms, None);

        // an explicit deadline without a class still applies
        let g = gen_req(r#"{"id":"a","prompt":"x","deadline_ms":80}"#);
        assert_eq!(g.deadline_ms, Some(80));

        // unknown class is a parse error
        assert!(
            parse_request(r#"{"id":"a","prompt":"x","slo":"warp"}"#).is_err()
        );
    }

    #[test]
    fn parse_cmds() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#).unwrap(),
                         Request::Stats));
        assert!(matches!(parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
                         Request::Shutdown));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt":"x"}"#).is_err()); // no id
        assert!(parse_request(r#"{"cmd":"nope"}"#).is_err());
    }

    #[test]
    fn responses_roundtrip() {
        let resp = GenResponse {
            id: "r".into(),
            text: "ANS 7".into(),
            tokens: vec![1, 2],
            tpf: 3.5,
            forwards: 4,
            gen_tokens: 14,
            queue_ms: 0.4,
            decode_ms: 9.0,
            slo: "interactive".into(),
            deadline_missed: true,
        };
        let line = ok_response(&resp);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("tpf").unwrap().as_f64(), Some(3.5));
        assert_eq!(j.get("slo").unwrap().as_str(), Some("interactive"));
        assert_eq!(j.get("deadline_missed").unwrap().as_bool(), Some(true));
        let e = err_response("x", "boom");
        let j = json::parse(&e).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn shed_response_carries_retry_after() {
        let line = shed_response("r9", "queue overloaded", 120);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_str(), Some("r9"));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("retry_after_ms").unwrap().as_usize(), Some(120));
        assert!(j.get("error").unwrap().as_str().unwrap()
                 .starts_with("shed:"));
    }

    #[test]
    fn stats_response_exposes_interleaving_gauges() {
        use std::sync::atomic::Ordering;
        let s = crate::coordinator::ServerStats::default();
        s.served.store(5, Ordering::Relaxed);
        s.queue_depth.store(3, Ordering::Relaxed);
        s.active_sessions.store(2, Ordering::Relaxed);
        s.max_concurrent.store(8, Ordering::Relaxed);
        s.sessions.lock().unwrap().push((
            "r1".to_string(),
            crate::decode::SessionProgress {
                unmasked: 40,
                gen_len: 96,
                steps: 11,
                rounds: 10,
                forwards: 9,
                ..Default::default()
            },
        ));
        s.kv_pages_total.store(24, Ordering::Relaxed);
        s.kv_pages_in_use.store(9, Ordering::Relaxed);
        s.kv_prefix_hits.store(4, Ordering::Relaxed);
        s.kv_prefill_skips.store(2, Ordering::Relaxed);
        let j = json::parse(&stats_response(&s)).unwrap();
        assert_eq!(j.get("served").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("queue_depth").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("active_sessions").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("kv_pages_total").unwrap().as_usize(), Some(24));
        assert_eq!(j.get("kv_pages_in_use").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("kv_prefix_hits").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("kv_prefill_skips").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("kv_cow_copies").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("max_concurrent_sessions").unwrap().as_usize(),
                   Some(8));
        let sess = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sess.len(), 1);
        assert_eq!(sess[0].get("id").unwrap().as_str(), Some("r1"));
        assert_eq!(sess[0].get("unmasked").unwrap().as_usize(), Some(40));
    }

    #[test]
    fn fleet_stats_sums_replicas_and_reports_routing() {
        use std::sync::atomic::Ordering;
        use std::sync::Arc;
        let a = Arc::new(crate::coordinator::ServerStats::default());
        let b = Arc::new(crate::coordinator::ServerStats::default());
        a.served.store(3, Ordering::Relaxed);
        b.served.store(4, Ordering::Relaxed);
        a.errors.store(1, Ordering::Relaxed);
        a.max_concurrent.store(4, Ordering::Relaxed);
        b.max_concurrent.store(4, Ordering::Relaxed);
        a.kv_pages_spilled.store(5, Ordering::Relaxed);
        b.sessions.lock().unwrap().push((
            "r7".to_string(),
            crate::decode::SessionProgress::default(),
        ));
        let core = crate::coordinator::router::RouterCore::new(2, 8);
        core.affinity_hits.store(9, Ordering::Relaxed);
        core.conn_errors.store(2, Ordering::Relaxed);
        core.mark_dead(1);
        let line = fleet_stats_response(&[a, b], &core);
        let j = json::parse(&line).unwrap();
        // pinned names carry fleet sums
        assert_eq!(j.get("served").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("errors").unwrap().as_usize(), Some(3));
        // config echo, not a sum
        assert_eq!(j.get("max_concurrent_sessions").unwrap().as_usize(),
                   Some(4));
        assert_eq!(j.get("kv_pages_spilled").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("workers").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("replicas_alive").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("affinity_hits").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("replica_deaths").unwrap().as_usize(), Some(1));
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("replica").unwrap().as_usize(), Some(0));
        assert_eq!(reps[0].get("alive").unwrap().as_bool(), Some(true));
        assert_eq!(reps[1].get("alive").unwrap().as_bool(), Some(false));
        assert_eq!(reps[1].get("served").unwrap().as_usize(), Some(4));
        // session entries are tagged with their home replica
        let sess = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sess.len(), 1);
        assert_eq!(sess[0].get("id").unwrap().as_str(), Some("r7"));
        assert_eq!(sess[0].get("replica").unwrap().as_usize(), Some(1));
        // the slo array stays a 3-class summary
        assert_eq!(j.get("slo").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn stats_response_exposes_adaptive_gauges() {
        use std::sync::atomic::Ordering;
        let s = crate::coordinator::ServerStats::default();
        s.adaptive_threshold_milli.store(980, Ordering::Relaxed);
        s.adaptive_up.store(4, Ordering::Relaxed);
        s.adaptive_down.store(2, Ordering::Relaxed);
        s.adaptive_width_hist[3].store(7, Ordering::Relaxed);
        let j = json::parse(&stats_response(&s)).unwrap();
        assert_eq!(j.get("adaptive_threshold_milli").unwrap().as_usize(),
                   Some(980));
        assert_eq!(j.get("adaptive_up").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("adaptive_down").unwrap().as_usize(), Some(2));
        let hist = j.get("adaptive_width_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), crate::decode::WIDTH_HIST_BUCKETS);
        assert_eq!(hist[3].as_usize(), Some(7));
        assert_eq!(hist[0].as_usize(), Some(0));
    }

    #[test]
    fn fleet_stats_aggregate_adaptive_gauges() {
        use std::sync::atomic::Ordering;
        use std::sync::Arc;
        let a = Arc::new(crate::coordinator::ServerStats::default());
        let b = Arc::new(crate::coordinator::ServerStats::default());
        a.adaptive_threshold_milli.store(450, Ordering::Relaxed);
        b.adaptive_threshold_milli.store(1_300, Ordering::Relaxed);
        a.adaptive_up.store(2, Ordering::Relaxed);
        b.adaptive_up.store(3, Ordering::Relaxed);
        a.adaptive_width_hist[1].store(4, Ordering::Relaxed);
        b.adaptive_width_hist[1].store(6, Ordering::Relaxed);
        let core = crate::coordinator::router::RouterCore::new(2, 8);
        let j = json::parse(&fleet_stats_response(&[a, b], &core)).unwrap();
        // counters/histogram sum, the threshold gauge is the fleet max
        assert_eq!(j.get("adaptive_threshold_milli").unwrap().as_usize(),
                   Some(1_300));
        assert_eq!(j.get("adaptive_up").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("adaptive_down").unwrap().as_usize(), Some(0));
        let hist = j.get("adaptive_width_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist[1].as_usize(), Some(10));
    }

    #[test]
    fn stats_response_exposes_slo_counters() {
        use std::sync::atomic::Ordering;
        let s = crate::coordinator::ServerStats::default();
        let i = SloClass::Interactive.idx();
        s.served_by_class[i].store(7, Ordering::Relaxed);
        s.shed_by_class[SloClass::Batch.idx()].store(3, Ordering::Relaxed);
        s.deadline_miss_by_class[i].store(1, Ordering::Relaxed);
        s.shed_total.store(3, Ordering::Relaxed);
        s.evicted_total.store(2, Ordering::Relaxed);
        s.deadline_miss_total.store(1, Ordering::Relaxed);
        s.preempted_rounds.store(11, Ordering::Relaxed);
        let j = json::parse(&stats_response(&s)).unwrap();
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("evicted").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("deadline_misses").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("preempted_rounds").unwrap().as_usize(), Some(11));
        let slo = j.get("slo").unwrap().as_arr().unwrap();
        assert_eq!(slo.len(), 3);
        assert_eq!(slo[0].get("class").unwrap().as_str(),
                   Some("interactive"));
        assert_eq!(slo[0].get("served").unwrap().as_usize(), Some(7));
        assert_eq!(slo[0].get("deadline_miss").unwrap().as_usize(), Some(1));
        assert_eq!(slo[2].get("class").unwrap().as_str(), Some("batch"));
        assert_eq!(slo[2].get("shed").unwrap().as_usize(), Some(3));
    }
}
