//! JSON-line serving protocol.
//!
//! Request (one JSON object per line):
//!   {"id": "r1", "prompt": "Q EVAL 3 + 4", "gen_len": 96,
//!    "priority": 0, "strategy": "d3llm"}        // strategy optional
//!   {"cmd": "stats"} | {"cmd": "shutdown"}
//!
//! Response:
//!   {"id": "r1", "ok": true, "text": "...", "tokens": [..],
//!    "tpf": 5.1, "forwards": 12, "gen_tokens": 61,
//!    "queue_ms": 0.3, "decode_ms": 210.0}
//!   {"id": "r1", "ok": false, "error": "..."}

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub enum Request {
    Generate(GenRequest),
    Stats,
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: String,
    pub prompt: String,
    pub gen_len: Option<usize>,
    pub priority: i64,
    pub strategy: Option<String>,
}

#[derive(Debug, Clone, Default)]
pub struct GenResponse {
    pub id: String,
    pub text: String,
    pub tokens: Vec<i32>,
    pub tpf: f64,
    pub forwards: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    pub decode_ms: f64,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("{e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow!("unknown cmd `{other}`")),
        };
    }
    let id = j
        .get("id")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `id`"))?
        .to_string();
    let prompt = j
        .get("prompt")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing `prompt`"))?
        .to_string();
    Ok(Request::Generate(GenRequest {
        id,
        prompt,
        gen_len: j.get("gen_len").and_then(|v| v.as_usize()),
        priority: j.get("priority").and_then(|v| v.as_i64()).unwrap_or(0),
        strategy: j
            .get("strategy")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
    }))
}

pub fn ok_response(r: &GenResponse) -> String {
    Json::obj(vec![
        ("id", Json::str(r.id.clone())),
        ("ok", Json::Bool(true)),
        ("text", Json::str(r.text.clone())),
        ("tokens",
         Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64)))),
        ("tpf", Json::num(r.tpf)),
        ("forwards", Json::num(r.forwards as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("queue_ms", Json::num(r.queue_ms)),
        ("decode_ms", Json::num(r.decode_ms)),
    ])
    .to_string()
}

/// Serialize the server stats snapshot, including the interleaving
/// gauges (queue depth, live sessions) and per-session progress.
pub fn stats_response(s: &super::ServerStats) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let sessions: Vec<Json> = s
        .sessions
        .lock()
        .map(|v| {
            v.iter()
                .map(|(id, p)| {
                    Json::obj(vec![
                        ("id", Json::str(id.clone())),
                        ("unmasked", Json::num(p.unmasked as f64)),
                        ("gen_len", Json::num(p.gen_len as f64)),
                        ("steps", Json::num(p.steps as f64)),
                        ("rounds", Json::num(p.rounds as f64)),
                        ("forwards", Json::num(p.forwards as f64)),
                    ])
                })
                .collect()
        })
        .unwrap_or_default();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("served", Json::num(s.served.load(Relaxed) as f64)),
        ("errors", Json::num(s.errors.load(Relaxed) as f64)),
        ("queue_ms", Json::num(s.queue_ms_total.load(Relaxed) as f64)),
        ("decode_ms", Json::num(s.decode_ms_total.load(Relaxed) as f64)),
        ("queue_depth", Json::num(s.queue_depth.load(Relaxed) as f64)),
        ("active_sessions",
         Json::num(s.active_sessions.load(Relaxed) as f64)),
        ("steps", Json::num(s.steps_total.load(Relaxed) as f64)),
        ("admitted", Json::num(s.admitted_total.load(Relaxed) as f64)),
        ("max_concurrent_sessions",
         Json::num(s.max_concurrent.load(Relaxed) as f64)),
        // paged KV pool gauges (all zero when serving dense caches)
        ("kv_pages_total",
         Json::num(s.kv_pages_total.load(Relaxed) as f64)),
        ("kv_pages_in_use",
         Json::num(s.kv_pages_in_use.load(Relaxed) as f64)),
        ("kv_pages_reclaimable",
         Json::num(s.kv_pages_reclaimable.load(Relaxed) as f64)),
        ("kv_prefix_hits",
         Json::num(s.kv_prefix_hits.load(Relaxed) as f64)),
        ("kv_prefill_skips",
         Json::num(s.kv_prefill_skips.load(Relaxed) as f64)),
        ("kv_pages_refreshed",
         Json::num(s.kv_pages_refreshed.load(Relaxed) as f64)),
        ("kv_refresh_skips",
         Json::num(s.kv_refresh_skips.load(Relaxed) as f64)),
        ("kv_cow_copies",
         Json::num(s.kv_cow_copies.load(Relaxed) as f64)),
        ("sessions", Json::Arr(sessions)),
    ])
    .to_string()
}

pub fn err_response(id: &str, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let r = parse_request(
            r#"{"id":"a","prompt":"Q EVAL 1 + 2","gen_len":96,"priority":2}"#,
        )
        .unwrap();
        match r {
            Request::Generate(g) => {
                assert_eq!(g.id, "a");
                assert_eq!(g.gen_len, Some(96));
                assert_eq!(g.priority, 2);
                assert!(g.strategy.is_none());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_cmds() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#).unwrap(),
                         Request::Stats));
        assert!(matches!(parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
                         Request::Shutdown));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt":"x"}"#).is_err()); // no id
        assert!(parse_request(r#"{"cmd":"nope"}"#).is_err());
    }

    #[test]
    fn responses_roundtrip() {
        let resp = GenResponse {
            id: "r".into(),
            text: "ANS 7".into(),
            tokens: vec![1, 2],
            tpf: 3.5,
            forwards: 4,
            gen_tokens: 14,
            queue_ms: 0.4,
            decode_ms: 9.0,
        };
        let line = ok_response(&resp);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("tpf").unwrap().as_f64(), Some(3.5));
        let e = err_response("x", "boom");
        let j = json::parse(&e).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn stats_response_exposes_interleaving_gauges() {
        use std::sync::atomic::Ordering;
        let s = crate::coordinator::ServerStats::default();
        s.served.store(5, Ordering::Relaxed);
        s.queue_depth.store(3, Ordering::Relaxed);
        s.active_sessions.store(2, Ordering::Relaxed);
        s.max_concurrent.store(8, Ordering::Relaxed);
        s.sessions.lock().unwrap().push((
            "r1".to_string(),
            crate::decode::SessionProgress {
                unmasked: 40,
                gen_len: 96,
                steps: 11,
                rounds: 10,
                forwards: 9,
                ..Default::default()
            },
        ));
        s.kv_pages_total.store(24, Ordering::Relaxed);
        s.kv_pages_in_use.store(9, Ordering::Relaxed);
        s.kv_prefix_hits.store(4, Ordering::Relaxed);
        s.kv_prefill_skips.store(2, Ordering::Relaxed);
        let j = json::parse(&stats_response(&s)).unwrap();
        assert_eq!(j.get("served").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("queue_depth").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("active_sessions").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("kv_pages_total").unwrap().as_usize(), Some(24));
        assert_eq!(j.get("kv_pages_in_use").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("kv_prefix_hits").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("kv_prefill_skips").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("kv_cow_copies").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("max_concurrent_sessions").unwrap().as_usize(),
                   Some(8));
        let sess = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sess.len(), 1);
        assert_eq!(sess[0].get("id").unwrap().as_str(), Some("r1"));
        assert_eq!(sess[0].get("unmasked").unwrap().as_usize(), Some(40));
    }
}
