//! Typed host-side wrappers around the AOT executables: each wrapper
//! assembles the manifest-ordered argument list, runs the graph, and
//! unpacks outputs into plain Rust vectors.
//!
//! Since manifest format_version 2 the artifact set also ships
//! paged-native and batched lowerings (`decode_paged_{variant}`,
//! `prefill_batch`, `decode_paged_batch`, `train_diff_fused`,
//! `trajectory_paged`). Every wrapper here probes the manifest and uses
//! them when present, falling back to the per-item / staged v1 path
//! otherwise — old artifact dirs keep working bit-identically.

use anyhow::{bail, Result};
use xla::Literal;

use crate::model::kv_cache::KvView;
use crate::runtime::engine::{
    scalar_f32_out, to_vec_f32, to_vec_i32, ArgData, Engine, TypedArgs,
};
use crate::runtime::manifest::ExecSpec;

/// Output of `prefill` / `ar_prefill`: full-sequence caches + head stats.
pub struct PrefillOut {
    pub kcache: Vec<f32>,
    pub vcache: Vec<f32>,
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
}

/// Output of `decode` / `ar_verify`: window head stats + window KV rows.
pub struct DecodeOut {
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
    pub k_win: Vec<f32>,
    pub v_win: Vec<f32>,
}

/// Output of a fused train step.
pub struct TrainOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub loss: f32,
}

/// Output of the chunked fused train step (`train_diff_fused`): K
/// optimizer steps in one device call, one loss per inner step.
pub struct TrainFusedOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub loss: Vec<f32>,
}

/// Output of the pseudo-trajectory extractor.
pub struct TrajectoryOut {
    pub rank: Vec<i32>,
    pub final_tokens: Vec<i32>,
}

/// Full-sequence bidirectional forward (`prefill_{variant}`) — prompt
/// prefill, KV-refresh, and the vanilla no-cache decode forward.
pub fn prefill(eng: &Engine, exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let s = spec.inputs[1].shape[0];
    if tokens.len() != s || valid.len() != s {
        bail!("prefill: tokens/valid must be length {s}");
    }
    let out = if eng.buffered() {
        eng.run_buffered(exec, params, &[
            ArgData::I32(tokens, &spec.inputs[1].shape),
            ArgData::F32(valid, &spec.inputs[2].shape),
        ])?
    } else {
        let args = TypedArgs::new()
            .f32(params, &spec.inputs[0].shape)?
            .i32(tokens, &[s])?
            .f32(valid, &[s])?;
        eng.run(exec, args)?
    };
    Ok(PrefillOut {
        kcache: to_vec_f32(&out[0], &spec.outputs[0])?,
        vcache: to_vec_f32(&out[1], &spec.outputs[1])?,
        argmax: to_vec_i32(&out[2], &spec.outputs[2])?,
        conf: to_vec_f32(&out[3], &spec.outputs[3])?,
        entropy: to_vec_f32(&out[4], &spec.outputs[4])?,
    })
}

// ------------------------------------------------------------ page tables

/// Packed page-table argument image for a paged executable: the host-side
/// form of [`crate::runtime::manifest::PagedAbi`]. Entries hold live
/// pages in arbitrary order; `page_index[j] >= 0` marks entry `j` live
/// and `page_valid[j]` counts its attendable rows, packed to the front of
/// the entry. The executable masks row `r` of entry `j` attendable iff
/// `page_index[j] >= 0 && r < page_valid[j]`.
pub struct PageTableArgs {
    /// `[L, max_pages, page_rows, d_kv]` packed key rows.
    pub k_pages: Vec<f32>,
    /// `[L, max_pages, page_rows, d_kv]` packed value rows.
    pub v_pages: Vec<f32>,
    /// `[max_pages]` slot index of each live entry, `-1` = dead.
    pub page_index: Vec<i32>,
    /// `[max_pages]` packed valid-row count per entry.
    pub page_valid: Vec<i32>,
    /// Total rows packed (== the view's `valid_count`).
    pub rows_packed: usize,
}

/// Build the packed page-table arguments for `cache` against a
/// `page_rows x max_pages` ABI.
///
/// Valid rows are **compacted to the front of each entry**: pool pages
/// can hold scattered valid rows (decode strategies commit individual
/// unmasked positions mid-block), while the lowered kernel expects
/// prefix-valid entries. Compaction is exact — positional information is
/// baked into the cached K/V vectors when they are produced, and
/// attention is permutation-invariant over its key rows, so only *which*
/// rows are attendable matters, never where they sit in the entry.
///
/// Paged views are read in place through [`KvView::for_each_page`]
/// (bytes copied scale with *valid rows*, not capacity — the dense
/// `[L, S_max, d_kv]` gather and the [`crate::model::kv_cache::KvStaging`]
/// scratch are both off this path); dense caches are sliced into
/// `page_rows`-row chunks with identity slot mapping, so one paged
/// executable serves both storage backends.
pub fn pack_page_table(cache: &dyn KvView, page_rows: usize,
                       max_pages: usize) -> Result<PageTableArgs> {
    let (l, d) = (cache.layers(), cache.d_kv());
    let (pr, mp) = (page_rows, max_pages);
    let mut t = PageTableArgs {
        k_pages: vec![0.0; l * mp * pr * d],
        v_pages: vec![0.0; l * mp * pr * d],
        page_index: vec![-1; mp],
        page_valid: vec![0; mp],
        rows_packed: 0,
    };
    if let Some(view_pr) = cache.page_rows() {
        if view_pr != pr {
            bail!("page table: view page_rows {view_pr} != executable \
                   page_rows {pr}");
        }
        let mut next = 0usize;
        let mut overflow = false;
        cache.for_each_page(&mut |pg| {
            if next >= mp {
                overflow = true;
                return;
            }
            let j = next;
            next += 1;
            t.page_index[j] = pg.slot as i32;
            let mut packed = 0usize;
            for r_idx in 0..pg.rows.min(pr) {
                if pg.valid[r_idx] > 0.0 {
                    for layer in 0..l {
                        let src = (layer * pr + r_idx) * d;
                        let dst = ((layer * mp + j) * pr + packed) * d;
                        t.k_pages[dst..dst + d]
                            .copy_from_slice(&pg.k[src..src + d]);
                        t.v_pages[dst..dst + d]
                            .copy_from_slice(&pg.v[src..src + d]);
                    }
                    packed += 1;
                }
            }
            t.page_valid[j] = packed as i32;
            t.rows_packed += packed;
        });
        if overflow {
            bail!("page table: view holds more than {mp} live pages");
        }
    } else {
        // dense storage: identity slot mapping, same per-slice compaction
        let (ck, cv, cvalid) =
            (cache.k_dense(), cache.v_dense(), cache.valid_dense());
        let (ck, cv, cvalid) = (ck.as_ref(), cv.as_ref(), cvalid.as_ref());
        let s = cache.capacity();
        for j in 0..mp {
            let base = j * pr;
            if base >= s {
                break;
            }
            let rows = pr.min(s - base);
            let mut packed = 0usize;
            for r_idx in 0..rows {
                if cvalid[base + r_idx] > 0.0 {
                    for layer in 0..l {
                        let src = (layer * s + base + r_idx) * d;
                        let dst = ((layer * mp + j) * pr + packed) * d;
                        t.k_pages[dst..dst + d]
                            .copy_from_slice(&ck[src..src + d]);
                        t.v_pages[dst..dst + d]
                            .copy_from_slice(&cv[src..src + d]);
                    }
                    packed += 1;
                }
            }
            if packed > 0 {
                t.page_index[j] = j as i32;
                t.page_valid[j] = packed as i32;
                t.rows_packed += packed;
            }
        }
    }
    debug_assert_eq!(t.rows_packed, cache.valid_count());
    Ok(t)
}

/// Resolve the paged lowering that can serve a `decode_{variant}` call
/// against `cache`, or `None` when the staged/dense fallback must run:
/// v1 manifests (no paged executable), a window-length or cache-geometry
/// mismatch, or a paged view whose page size differs from the lowered
/// ABI. Every gate failing is a *fallback*, not an error — the pinned
/// behavior for old artifact dirs.
fn paged_decode_spec(eng: &Engine, exec: &str, cache: &dyn KvView,
                     w: usize) -> Option<ExecSpec> {
    let variant = exec.strip_prefix("decode_")?;
    if variant.starts_with("paged") {
        return None;
    }
    let spec = eng.manifest.executables.get(&format!("decode_paged_{variant}"))?;
    let abi = spec.paged?;
    if abi.page_rows * abi.max_pages != cache.capacity() {
        return None;
    }
    if let Some(view_pr) = cache.page_rows() {
        if view_pr != abi.page_rows {
            return None;
        }
    }
    if spec.inputs.len() != 8 || spec.inputs[1].shape != [w] {
        return None;
    }
    let want = [cache.layers(), abi.max_pages, abi.page_rows, cache.d_kv()];
    if spec.inputs[4].shape != want {
        return None;
    }
    Some(spec.clone())
}

/// Windowed forward against the KV cache (`decode_{variant}`, `ar_step`,
/// `ar_verify`, `draft_ar_step`): the serving hot path. Accepts any
/// [`KvView`].
///
/// When the artifact set ships a paged lowering
/// (`decode_paged_{variant}`, manifest format_version >= 2) whose ABI
/// matches the cache geometry, the forward consumes the page table
/// directly ([`pack_page_table`]): pages are read in place via
/// `for_each_page`, bytes copied scale with valid rows, and the
/// [`crate::model::kv_cache::KvStaging`] dense-gather scratch is never
/// touched. Otherwise — v1 artifacts, ABI mismatch, or the AR/draft
/// executables which have no paged lowering — the pinned fallback runs:
/// a paged view is staged into the engine's reusable scratch
/// (`Engine::kv_stage`, copying only pages that changed since the
/// scratch last held them) and a dense cache hands its buffers over
/// borrow-only, exactly the pre-v2 behavior.
pub fn decode_window(eng: &Engine, exec: &str, params: &[f32],
                     win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
                     cache: &dyn KvView) -> Result<DecodeOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let w = spec.inputs[1].shape[0];
    if win_tokens.len() != w || win_pos.len() != w || win_valid.len() != w {
        bail!("decode: window inputs must be length {w}");
    }
    if let Some(pspec) = paged_decode_spec(eng, exec, cache, w) {
        return decode_window_paged(eng, &pspec, params, win_tokens,
                                   win_pos, win_valid, cache);
    }
    // Every cache argument is validated against the manifest shape on
    // BOTH call paths (buffered and literal); a view whose capacity
    // diverges from the lowered S_max fails here with one clear error
    // instead of a path-dependent shape mismatch downstream.
    let s_exec: usize =
        spec.inputs[6].shape.iter().product::<usize>().max(1);
    if cache.capacity() != s_exec {
        bail!("decode `{exec}`: cache capacity {} != executable S_max \
               {s_exec} (manifest valid-mask shape {:?})",
              cache.capacity(), spec.inputs[6].shape);
    }
    let out = if cache.page_rows().is_some() {
        // staged fallback: bring the reusable scratch to this view's
        // dense image, copying only the pages that changed since the
        // scratch last held them (allocation-free steady state)
        let mut stage = eng.kv_stage();
        stage.stage(cache)?;
        run_decode(eng, exec, &spec, params, win_tokens, win_pos,
                   win_valid, &stage.k, &stage.v, &stage.valid)?
    } else {
        let (ck, cv, cvalid) =
            (cache.k_dense(), cache.v_dense(), cache.valid_dense());
        run_decode(eng, exec, &spec, params, win_tokens, win_pos,
                   win_valid, ck.as_ref(), cv.as_ref(), cvalid.as_ref())?
    };
    Ok(DecodeOut {
        argmax: to_vec_i32(&out[0], &spec.outputs[0])?,
        conf: to_vec_f32(&out[1], &spec.outputs[1])?,
        entropy: to_vec_f32(&out[2], &spec.outputs[2])?,
        k_win: to_vec_f32(&out[3], &spec.outputs[3])?,
        v_win: to_vec_f32(&out[4], &spec.outputs[4])?,
    })
}

/// Paged-native windowed forward: feed the packed page table straight to
/// a `decode_paged_{variant}` executable. No staging scratch, no dense
/// gather — the 0-staged-bytes hot path pinned in `benches/hotpath.rs`.
fn decode_window_paged(eng: &Engine, spec: &ExecSpec, params: &[f32],
                       win_tokens: &[i32], win_pos: &[i32],
                       win_valid: &[f32], cache: &dyn KvView)
                       -> Result<DecodeOut> {
    let abi = spec.paged.expect("paged_decode_spec checked");
    let t = pack_page_table(cache, abi.page_rows, abi.max_pages)?;
    let out = if eng.buffered() {
        eng.run_buffered(&spec.name, params, &[
            ArgData::I32(win_tokens, &spec.inputs[1].shape),
            ArgData::I32(win_pos, &spec.inputs[2].shape),
            ArgData::F32(win_valid, &spec.inputs[3].shape),
            ArgData::F32(&t.k_pages, &spec.inputs[4].shape),
            ArgData::F32(&t.v_pages, &spec.inputs[5].shape),
            ArgData::I32(&t.page_index, &spec.inputs[6].shape),
            ArgData::I32(&t.page_valid, &spec.inputs[7].shape),
        ])?
    } else {
        let args = TypedArgs::new()
            .f32(params, &spec.inputs[0].shape)?
            .i32(win_tokens, &spec.inputs[1].shape)?
            .i32(win_pos, &spec.inputs[2].shape)?
            .f32(win_valid, &spec.inputs[3].shape)?
            .f32(&t.k_pages, &spec.inputs[4].shape)?
            .f32(&t.v_pages, &spec.inputs[5].shape)?
            .i32(&t.page_index, &spec.inputs[6].shape)?
            .i32(&t.page_valid, &spec.inputs[7].shape)?;
        eng.run(&spec.name, args)?
    };
    Ok(DecodeOut {
        argmax: to_vec_i32(&out[0], &spec.outputs[0])?,
        conf: to_vec_f32(&out[1], &spec.outputs[1])?,
        entropy: to_vec_f32(&out[2], &spec.outputs[2])?,
        k_win: to_vec_f32(&out[3], &spec.outputs[3])?,
        v_win: to_vec_f32(&out[4], &spec.outputs[4])?,
    })
}

/// Shared tail of `decode_window`: issue the forward with the staged (or
/// borrowed) dense cache image. Both the buffered and the literal path
/// take every shape from the manifest spec.
#[allow(clippy::too_many_arguments)]
fn run_decode(eng: &Engine, exec: &str, spec: &ExecSpec, params: &[f32],
              win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
              ck: &[f32], cv: &[f32], cvalid: &[f32])
              -> Result<Vec<Literal>> {
    if eng.buffered() {
        eng.run_buffered(exec, params, &[
            ArgData::I32(win_tokens, &spec.inputs[1].shape),
            ArgData::I32(win_pos, &spec.inputs[2].shape),
            ArgData::F32(win_valid, &spec.inputs[3].shape),
            ArgData::F32(ck, &spec.inputs[4].shape),
            ArgData::F32(cv, &spec.inputs[5].shape),
            ArgData::F32(cvalid, &spec.inputs[6].shape),
        ])
    } else {
        let args = TypedArgs::new()
            .f32(params, &spec.inputs[0].shape)?
            .i32(win_tokens, &spec.inputs[1].shape)?
            .i32(win_pos, &spec.inputs[2].shape)?
            .f32(win_valid, &spec.inputs[3].shape)?
            .f32(ck, &spec.inputs[4].shape)?
            .f32(cv, &spec.inputs[5].shape)?
            .f32(cvalid, &spec.inputs[6].shape)?;
        eng.run(exec, args)
    }
}

// --------------------------------------------------------- batched calls

/// One sequence of a batched full forward (exec-name-agnostic form the
/// `exec` layer consumes; `decode::backend` adapts its item type).
pub struct PrefillBatchItem<'a> {
    pub tokens: &'a [i32],
    pub valid: &'a [f32],
}

/// One windowed forward of a batched paged decode call.
pub struct WindowBatchItem<'a> {
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub valid: &'a [f32],
    pub cache: &'a dyn KvView,
}

/// B same-shape full forwards through the `prefill_batch` executable.
/// Returns `Ok(None)` when the batched lowering cannot serve this group
/// (v1 manifest, different model family, or a sequence-length mismatch) —
/// the caller then loops over [`prefill`]. Groups larger than the
/// lowered batch are chunked; a partial last chunk pads its unused lanes
/// with lane 0's arguments and discards the padded outputs.
pub fn prefill_batch(eng: &Engine, exec: &str, params: &[f32],
                     items: &[PrefillBatchItem<'_>])
                     -> Result<Option<Vec<PrefillOut>>> {
    // only the bidirectional main-family prefills have a batched
    // lowering; ar_prefill (causal) and draft_* (different model) do not
    if !exec.starts_with("prefill_") {
        return Ok(None);
    }
    let Some(bspec) = eng.manifest.executables.get("prefill_batch") else {
        return Ok(None);
    };
    let bspec = bspec.clone();
    let Some(b) = bspec.batch else { return Ok(None) };
    if eng.manifest.exec(exec)?.model != bspec.model {
        return Ok(None);
    }
    if bspec.inputs[1].shape.len() != 2 || bspec.inputs[1].shape[0] != b {
        return Ok(None);
    }
    let s = bspec.inputs[1].shape[1];
    if items.iter().any(|it| it.tokens.len() != s || it.valid.len() != s) {
        return Ok(None);
    }
    let mut outs = Vec::with_capacity(items.len());
    for chunk in items.chunks(b) {
        let mut tok = Vec::with_capacity(b * s);
        let mut vld = Vec::with_capacity(b * s);
        for lane in 0..b {
            let it = chunk.get(lane).unwrap_or(&chunk[0]);
            tok.extend_from_slice(it.tokens);
            vld.extend_from_slice(it.valid);
        }
        let out = if eng.buffered() {
            eng.run_buffered(&bspec.name, params, &[
                ArgData::I32(&tok, &bspec.inputs[1].shape),
                ArgData::F32(&vld, &bspec.inputs[2].shape),
            ])?
        } else {
            let args = TypedArgs::new()
                .f32(params, &bspec.inputs[0].shape)?
                .i32(&tok, &bspec.inputs[1].shape)?
                .f32(&vld, &bspec.inputs[2].shape)?;
            eng.run(&bspec.name, args)?
        };
        let kc = to_vec_f32(&out[0], &bspec.outputs[0])?;
        let vc = to_vec_f32(&out[1], &bspec.outputs[1])?;
        let am = to_vec_i32(&out[2], &bspec.outputs[2])?;
        let cf = to_vec_f32(&out[3], &bspec.outputs[3])?;
        let en = to_vec_f32(&out[4], &bspec.outputs[4])?;
        let (nc, nw) = (kc.len() / b, am.len() / b);
        for lane in 0..chunk.len() {
            outs.push(PrefillOut {
                kcache: kc[lane * nc..(lane + 1) * nc].to_vec(),
                vcache: vc[lane * nc..(lane + 1) * nc].to_vec(),
                argmax: am[lane * nw..(lane + 1) * nw].to_vec(),
                conf: cf[lane * nw..(lane + 1) * nw].to_vec(),
                entropy: en[lane * nw..(lane + 1) * nw].to_vec(),
            });
        }
    }
    Ok(Some(outs))
}

/// B same-shape windowed forwards (each against its own cache view)
/// through the `decode_paged_batch` executable. Returns `Ok(None)` when
/// the batched paged lowering cannot serve this group — v1 manifests,
/// the AR/draft window executables, or any item whose cache geometry
/// disagrees with the lowered page-table ABI — and the caller loops over
/// [`decode_window`] (which may still take the B=1 paged lowering per
/// item).
pub fn decode_window_batch(eng: &Engine, exec: &str, params: &[f32],
                           items: &[WindowBatchItem<'_>])
                           -> Result<Option<Vec<DecodeOut>>> {
    let Some(variant) = exec.strip_prefix("decode_") else {
        return Ok(None);
    };
    if variant.starts_with("paged") {
        return Ok(None);
    }
    let Some(bspec) = eng.manifest.executables.get("decode_paged_batch")
    else {
        return Ok(None);
    };
    let bspec = bspec.clone();
    let (Some(b), Some(abi)) = (bspec.batch, bspec.paged) else {
        return Ok(None);
    };
    if eng.manifest.exec(exec)?.model != bspec.model {
        return Ok(None);
    }
    if bspec.inputs[1].shape.len() != 2 || bspec.inputs[1].shape[0] != b {
        return Ok(None);
    }
    let w = bspec.inputs[1].shape[1];
    let cap = abi.page_rows * abi.max_pages;
    for it in items {
        if it.tokens.len() != w || it.pos.len() != w || it.valid.len() != w {
            return Ok(None);
        }
        if it.cache.capacity() != cap
            || it.cache.page_rows().is_some_and(|pr| pr != abi.page_rows)
        {
            return Ok(None);
        }
        let want = [b, it.cache.layers(), abi.max_pages, abi.page_rows,
                    it.cache.d_kv()];
        if bspec.inputs[4].shape != want {
            return Ok(None);
        }
    }
    let mut outs = Vec::with_capacity(items.len());
    for chunk in items.chunks(b) {
        let tables = chunk
            .iter()
            .map(|it| pack_page_table(it.cache, abi.page_rows,
                                      abi.max_pages))
            .collect::<Result<Vec<_>>>()?;
        let per_kv = tables[0].k_pages.len();
        let mut tok = Vec::with_capacity(b * w);
        let mut pos = Vec::with_capacity(b * w);
        let mut vld = Vec::with_capacity(b * w);
        let mut kp = Vec::with_capacity(b * per_kv);
        let mut vp = Vec::with_capacity(b * per_kv);
        let mut pidx = Vec::with_capacity(b * abi.max_pages);
        let mut pval = Vec::with_capacity(b * abi.max_pages);
        for lane in 0..b {
            // pad unused lanes with lane 0 and discard their outputs
            let (it, t) = match chunk.get(lane) {
                Some(it) => (it, &tables[lane]),
                None => (&chunk[0], &tables[0]),
            };
            tok.extend_from_slice(it.tokens);
            pos.extend_from_slice(it.pos);
            vld.extend_from_slice(it.valid);
            kp.extend_from_slice(&t.k_pages);
            vp.extend_from_slice(&t.v_pages);
            pidx.extend_from_slice(&t.page_index);
            pval.extend_from_slice(&t.page_valid);
        }
        let out = if eng.buffered() {
            eng.run_buffered(&bspec.name, params, &[
                ArgData::I32(&tok, &bspec.inputs[1].shape),
                ArgData::I32(&pos, &bspec.inputs[2].shape),
                ArgData::F32(&vld, &bspec.inputs[3].shape),
                ArgData::F32(&kp, &bspec.inputs[4].shape),
                ArgData::F32(&vp, &bspec.inputs[5].shape),
                ArgData::I32(&pidx, &bspec.inputs[6].shape),
                ArgData::I32(&pval, &bspec.inputs[7].shape),
            ])?
        } else {
            let args = TypedArgs::new()
                .f32(params, &bspec.inputs[0].shape)?
                .i32(&tok, &bspec.inputs[1].shape)?
                .i32(&pos, &bspec.inputs[2].shape)?
                .f32(&vld, &bspec.inputs[3].shape)?
                .f32(&kp, &bspec.inputs[4].shape)?
                .f32(&vp, &bspec.inputs[5].shape)?
                .i32(&pidx, &bspec.inputs[6].shape)?
                .i32(&pval, &bspec.inputs[7].shape)?;
            eng.run(&bspec.name, args)?
        };
        let am = to_vec_i32(&out[0], &bspec.outputs[0])?;
        let cf = to_vec_f32(&out[1], &bspec.outputs[1])?;
        let en = to_vec_f32(&out[2], &bspec.outputs[2])?;
        let kw = to_vec_f32(&out[3], &bspec.outputs[3])?;
        let vw = to_vec_f32(&out[4], &bspec.outputs[4])?;
        let (nw, nkw) = (am.len() / b, kw.len() / b);
        for lane in 0..chunk.len() {
            outs.push(DecodeOut {
                argmax: am[lane * nw..(lane + 1) * nw].to_vec(),
                conf: cf[lane * nw..(lane + 1) * nw].to_vec(),
                entropy: en[lane * nw..(lane + 1) * nw].to_vec(),
                k_win: kw[lane * nkw..(lane + 1) * nkw].to_vec(),
                v_win: vw[lane * nkw..(lane + 1) * nkw].to_vec(),
            });
        }
    }
    Ok(Some(outs))
}

/// Fused fwd+bwd+AdamW step (`train_diff` / `train_ar` / `draft_train_ar`).
#[allow(clippy::too_many_arguments)]
pub fn train_step(eng: &Engine, exec: &str, params: &[f32], m: &[f32],
                  v: &[f32], step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let bs = &spec.inputs[4].shape; // [B, S]
    let args = TypedArgs::new()
        .f32(params, &spec.inputs[0].shape)?
        .f32(m, &spec.inputs[1].shape)?
        .f32(v, &spec.inputs[2].shape)?
        .scalar_i32(step)
        .i32(tokens, bs)?
        .i32(labels, bs)?
        .f32(loss_mask, bs)?
        .f32(attn_valid, bs)?
        .scalar_f32(lr)
        .scalar_f32(ent_weight);
    let out = eng.run(exec, args)?;
    Ok(TrainOut {
        params: to_vec_f32(&out[0], &spec.outputs[0])?,
        m: to_vec_f32(&out[1], &spec.outputs[1])?,
        v: to_vec_f32(&out[2], &spec.outputs[2])?,
        loss: scalar_f32_out(&out[3])?,
    })
}

/// Chunked fused train step (`train_diff_fused`): K sequential
/// fwd+bwd+AdamW steps scanned on device in one call, batches stacked as
/// `[K, B, s_train]`. The inner step counter advances `step0 .. step0+K`,
/// so K fused steps are arithmetically the K per-step calls they replace.
#[allow(clippy::too_many_arguments)]
pub fn train_step_fused(eng: &Engine, params: &[f32], m: &[f32], v: &[f32],
                        step0: i32, tokens: &[i32], labels: &[i32],
                        loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                        ent_weight: f32) -> Result<TrainFusedOut> {
    let spec = eng.manifest.exec("train_diff_fused")?.clone();
    let kbs = &spec.inputs[4].shape; // [K, B, S]
    let n: usize = kbs.iter().product();
    if tokens.len() != n || labels.len() != n || loss_mask.len() != n
        || attn_valid.len() != n
    {
        bail!("train_step_fused: batch inputs must be {kbs:?} = {n}");
    }
    let args = TypedArgs::new()
        .f32(params, &spec.inputs[0].shape)?
        .f32(m, &spec.inputs[1].shape)?
        .f32(v, &spec.inputs[2].shape)?
        .scalar_i32(step0)
        .i32(tokens, kbs)?
        .i32(labels, kbs)?
        .f32(loss_mask, kbs)?
        .f32(attn_valid, kbs)?
        .scalar_f32(lr)
        .scalar_f32(ent_weight);
    let out = eng.run("train_diff_fused", args)?;
    Ok(TrainFusedOut {
        params: to_vec_f32(&out[0], &spec.outputs[0])?,
        m: to_vec_f32(&out[1], &spec.outputs[1])?,
        v: to_vec_f32(&out[2], &spec.outputs[2])?,
        loss: to_vec_f32(&out[3], &spec.outputs[3])?,
    })
}

/// Pseudo-trajectory extraction (`trajectory`): batched on-device scan.
pub fn trajectory(eng: &Engine, params: &[f32], tokens: &[i32],
                  attn_valid: &[f32], gen_mask: &[f32])
                  -> Result<TrajectoryOut> {
    trajectory_named(eng, "trajectory", params, tokens, attn_valid, gen_mask)
}

/// Paged variant of the trajectory scan (`trajectory_paged`): identical
/// signature and outputs, lowered over the paged window forward. Opt-in —
/// callers probe `Engine::has_executable("trajectory_paged")` first.
pub fn trajectory_paged(eng: &Engine, params: &[f32], tokens: &[i32],
                        attn_valid: &[f32], gen_mask: &[f32])
                        -> Result<TrajectoryOut> {
    trajectory_named(eng, "trajectory_paged", params, tokens, attn_valid,
                     gen_mask)
}

fn trajectory_named(eng: &Engine, exec: &str, params: &[f32],
                    tokens: &[i32], attn_valid: &[f32], gen_mask: &[f32])
                    -> Result<TrajectoryOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let bs = &spec.inputs[1].shape; // [B, S]
    let args = TypedArgs::new()
        .f32(params, &spec.inputs[0].shape)?
        .i32(tokens, bs)?
        .f32(attn_valid, bs)?
        .f32(gen_mask, bs)?;
    let out = eng.run(exec, args)?;
    Ok(TrajectoryOut {
        rank: to_vec_i32(&out[0], &spec.outputs[0])?,
        final_tokens: to_vec_i32(&out[1], &spec.outputs[1])?,
    })
}
