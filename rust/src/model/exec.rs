//! Typed host-side wrappers around the AOT executables: each wrapper
//! assembles the manifest-ordered argument list, runs the graph, and
//! unpacks outputs into plain Rust vectors.

use anyhow::{bail, Result};
use xla::Literal;

use crate::model::kv_cache::KvView;
use crate::runtime::engine::{
    scalar_f32_out, to_vec_f32, to_vec_i32, ArgData, Engine, TypedArgs,
};
use crate::runtime::manifest::ExecSpec;

/// Output of `prefill` / `ar_prefill`: full-sequence caches + head stats.
pub struct PrefillOut {
    pub kcache: Vec<f32>,
    pub vcache: Vec<f32>,
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
}

/// Output of `decode` / `ar_verify`: window head stats + window KV rows.
pub struct DecodeOut {
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
    pub k_win: Vec<f32>,
    pub v_win: Vec<f32>,
}

/// Output of a fused train step.
pub struct TrainOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub loss: f32,
}

/// Output of the pseudo-trajectory extractor.
pub struct TrajectoryOut {
    pub rank: Vec<i32>,
    pub final_tokens: Vec<i32>,
}

/// Full-sequence bidirectional forward (`prefill_{variant}`) — prompt
/// prefill, KV-refresh, and the vanilla no-cache decode forward.
pub fn prefill(eng: &Engine, exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let s = spec.inputs[1].shape[0];
    if tokens.len() != s || valid.len() != s {
        bail!("prefill: tokens/valid must be length {s}");
    }
    let out = if eng.buffered() {
        eng.run_buffered(exec, params, &[
            ArgData::I32(tokens, &spec.inputs[1].shape),
            ArgData::F32(valid, &spec.inputs[2].shape),
        ])?
    } else {
        let args = TypedArgs::new()
            .f32(params, &spec.inputs[0].shape)?
            .i32(tokens, &[s])?
            .f32(valid, &[s])?;
        eng.run(exec, args)?
    };
    Ok(PrefillOut {
        kcache: to_vec_f32(&out[0], &spec.outputs[0])?,
        vcache: to_vec_f32(&out[1], &spec.outputs[1])?,
        argmax: to_vec_i32(&out[2], &spec.outputs[2])?,
        conf: to_vec_f32(&out[3], &spec.outputs[3])?,
        entropy: to_vec_f32(&out[4], &spec.outputs[4])?,
    })
}

/// Windowed forward against the KV cache (`decode_{variant}`, `ar_step`,
/// `ar_verify`, `draft_ar_step`): the serving hot path. Accepts any
/// [`KvView`]: the dense cache hands over its buffers borrow-only; a
/// paged view is read through its page table (`KvView::page_rows` /
/// `for_each_page`, allocation-free) into
/// the engine's reusable staging scratch, which copies only pages that
/// changed since the scratch last held them (`Engine::kv_stage`) — the
/// old per-call full-cache `k_dense()` gather is gone from this path.
/// The HLO exec interface is unchanged: the executable still consumes
/// dense `[L, S_max, d_kv]` buffers until a true paged-attention
/// executable lands in the AOT layer (python/compile).
pub fn decode_window(eng: &Engine, exec: &str, params: &[f32],
                     win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
                     cache: &dyn KvView) -> Result<DecodeOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let w = spec.inputs[1].shape[0];
    if win_tokens.len() != w || win_pos.len() != w || win_valid.len() != w {
        bail!("decode: window inputs must be length {w}");
    }
    // Every cache argument is validated against the manifest shape on
    // BOTH call paths (buffered and literal); a view whose capacity
    // diverges from the lowered S_max fails here with one clear error
    // instead of a path-dependent shape mismatch downstream.
    let s_exec: usize =
        spec.inputs[6].shape.iter().product::<usize>().max(1);
    if cache.capacity() != s_exec {
        bail!("decode `{exec}`: cache capacity {} != executable S_max \
               {s_exec} (manifest valid-mask shape {:?})",
              cache.capacity(), spec.inputs[6].shape);
    }
    let out = if cache.page_rows().is_some() {
        // paged-native read: stage only the pages that changed since the
        // scratch last held them (allocation-free steady state)
        let mut stage = eng.kv_stage();
        stage.stage(cache)?;
        run_decode(eng, exec, &spec, params, win_tokens, win_pos,
                   win_valid, &stage.k, &stage.v, &stage.valid)?
    } else {
        let (ck, cv, cvalid) =
            (cache.k_dense(), cache.v_dense(), cache.valid_dense());
        run_decode(eng, exec, &spec, params, win_tokens, win_pos,
                   win_valid, ck.as_ref(), cv.as_ref(), cvalid.as_ref())?
    };
    Ok(DecodeOut {
        argmax: to_vec_i32(&out[0], &spec.outputs[0])?,
        conf: to_vec_f32(&out[1], &spec.outputs[1])?,
        entropy: to_vec_f32(&out[2], &spec.outputs[2])?,
        k_win: to_vec_f32(&out[3], &spec.outputs[3])?,
        v_win: to_vec_f32(&out[4], &spec.outputs[4])?,
    })
}

/// Shared tail of `decode_window`: issue the forward with the staged (or
/// borrowed) dense cache image. Both the buffered and the literal path
/// take every shape from the manifest spec.
#[allow(clippy::too_many_arguments)]
fn run_decode(eng: &Engine, exec: &str, spec: &ExecSpec, params: &[f32],
              win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
              ck: &[f32], cv: &[f32], cvalid: &[f32])
              -> Result<Vec<Literal>> {
    if eng.buffered() {
        eng.run_buffered(exec, params, &[
            ArgData::I32(win_tokens, &spec.inputs[1].shape),
            ArgData::I32(win_pos, &spec.inputs[2].shape),
            ArgData::F32(win_valid, &spec.inputs[3].shape),
            ArgData::F32(ck, &spec.inputs[4].shape),
            ArgData::F32(cv, &spec.inputs[5].shape),
            ArgData::F32(cvalid, &spec.inputs[6].shape),
        ])
    } else {
        let args = TypedArgs::new()
            .f32(params, &spec.inputs[0].shape)?
            .i32(win_tokens, &spec.inputs[1].shape)?
            .i32(win_pos, &spec.inputs[2].shape)?
            .f32(win_valid, &spec.inputs[3].shape)?
            .f32(ck, &spec.inputs[4].shape)?
            .f32(cv, &spec.inputs[5].shape)?
            .f32(cvalid, &spec.inputs[6].shape)?;
        eng.run(exec, args)
    }
}

/// Fused fwd+bwd+AdamW step (`train_diff` / `train_ar` / `draft_train_ar`).
#[allow(clippy::too_many_arguments)]
pub fn train_step(eng: &Engine, exec: &str, params: &[f32], m: &[f32],
                  v: &[f32], step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut> {
    let spec = eng.manifest.exec(exec)?.clone();
    let bs = &spec.inputs[4].shape; // [B, S]
    let args = TypedArgs::new()
        .f32(params, &spec.inputs[0].shape)?
        .f32(m, &spec.inputs[1].shape)?
        .f32(v, &spec.inputs[2].shape)?
        .scalar_i32(step)
        .i32(tokens, bs)?
        .i32(labels, bs)?
        .f32(loss_mask, bs)?
        .f32(attn_valid, bs)?
        .scalar_f32(lr)
        .scalar_f32(ent_weight);
    let out = eng.run(exec, args)?;
    Ok(TrainOut {
        params: to_vec_f32(&out[0], &spec.outputs[0])?,
        m: to_vec_f32(&out[1], &spec.outputs[1])?,
        v: to_vec_f32(&out[2], &spec.outputs[2])?,
        loss: scalar_f32_out(&out[3])?,
    })
}

/// Pseudo-trajectory extraction (`trajectory`): batched on-device scan.
pub fn trajectory(eng: &Engine, params: &[f32], tokens: &[i32],
                  attn_valid: &[f32], gen_mask: &[f32])
                  -> Result<TrajectoryOut> {
    let spec = eng.manifest.exec("trajectory")?.clone();
    let bs = &spec.inputs[1].shape; // [B, S]
    let args = TypedArgs::new()
        .f32(params, &spec.inputs[0].shape)?
        .i32(tokens, bs)?
        .f32(attn_valid, bs)?
        .f32(gen_mask, bs)?;
    let out = eng.run("trajectory", args)?;
    Ok(TrajectoryOut {
        rank: to_vec_i32(&out[0], &spec.outputs[0])?,
        final_tokens: to_vec_i32(&out[1], &spec.outputs[1])?,
    })
}
