//! Paged KV-cache pool with prompt-prefix sharing and incremental
//! refresh.
//!
//! The dense per-session [`super::KvCache`] allocates `[L, S_max, d_kv]`
//! for every admitted session, so serving memory scales with
//! `max_concurrent_sessions x S_max` and same-prefix sessions redo
//! identical prefill forwards. This module replaces that with a shared
//! pool of fixed-size *pages* (aligned to the decode block size) under a
//! configurable byte budget; each session holds a [`PagedKv`] page-table
//! view implementing [`KvView`]:
//!
//!   * **Memory scales with live tokens.** Pages are allocated lazily as
//!     rows are installed/committed; a session reserves only the pages
//!     its `prompt + gen` span can touch, not `S_max`.
//!   * **Prefix sharing.** At admission the prompt is chain-hashed per
//!     page (the hash of page *i* covers tokens `0..end_i`, plus the
//!     prefill executable family and cache geometry). For *causal*
//!     prefill families (`ar_prefill`) a page hit is individually sound
//!     — causal rows depend only on the tokens the chain hash certifies
//!     — so partial prefixes share page by page. For *bidirectional*
//!     families (`prefill_{variant}`) a row depends on the whole visible
//!     prompt, so adoption is all-or-nothing: pages are adopted only
//!     when every prompt page hits (the full prompt matches). In either
//!     case a full-prefix hit also skips the prompt-prefill forward
//!     entirely — sound because every decode policy uses the prefill
//!     output only to install those very rows.
//!   * **Copy-on-write.** A write to a page referenced by more than one
//!     session — or to any prefix-registered page, whose pristine content
//!     must stay adoptable — copies it first. Sessions can never observe
//!     each other's decode commits, and a prompt page survives in the
//!     index even after its registrant decodes past it or retires.
//!   * **Incremental refresh.** Each view keeps per-page generation
//!     counters: `touch` advances when a page's row content changes
//!     (commits / invalidation), `install` records the generation of its
//!     last full-forward install. A KV-refresh `install_full` rewrites
//!     only pages whose install generation lags their touch generation or
//!     whose range still has invalid rows; fully-current pages (the
//!     prompt, long-completed blocks) are skipped instead of rewritten.
//!   * **Reclaimable pages.** When a session retires, its prefix-indexed
//!     pages are kept (ref count 0) so future same-prefix sessions still
//!     hit; they are evicted LRU-first whenever the allocator needs a
//!     physical page, so they never block admission.
//!   * **Paged-native reads.** Backends read a view through its page
//!     table (`KvView::page_args` / `for_each_page`), O(live-pages) per
//!     windowed forward: the sim fingerprints pages in place, the engine
//!     stages only pages whose (uid, stamp) changed since its reusable
//!     scratch last held them (`super::kv_cache::KvStaging`). The dense
//!     `k_dense()` gather remains only as the reference read path.
//!
//! On the deterministic `SimBackend`, a paged session's decode output is
//! bit-identical to the dense baseline for every strategy
//! (`tests/kv_pool.rs` pins this): KV row values are pure functions of
//! (layer, position, token), rows are only installed for finalized
//! tokens, and the row-validity set evolves identically. On a real
//! engine, prefix sharing and refresh skipping are approximations in
//! exactly the spirit of the paper's block-approximate cache (§3.2).
//!
//! Everything is single-threaded behind the engine worker (like the
//! `RefCell`-caching `Engine`), so the pool is shared via `Rc<RefCell>`.

use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use super::kv_cache::{KvPage, KvPageArgs, KvView};

/// Process-wide physical-page identity source: ids stay unique across
/// pools and across recycling, so a staging scratch keyed by (id, stamp)
/// can never confuse two pages — even pages of different pools staged
/// through one scratch.
static PAGE_UID: AtomicU64 = AtomicU64::new(1);

fn next_page_uid() -> u64 {
    PAGE_UID.fetch_add(1, Ordering::Relaxed)
}

/// Marker embedded in every budget-exhaustion error so callers can
/// distinguish "no page budget, retry later" from hard failures without
/// typed downcasts (the vendored `anyhow` has none).
pub const POOL_EXHAUSTED: &str = "kv pool exhausted";

/// True when `e` is a page-budget exhaustion error from this module.
pub fn is_pool_exhausted(e: &anyhow::Error) -> bool {
    e.chain().any(|m| m.contains(POOL_EXHAUSTED))
}

/// Pool geometry + budget. One pool serves one model geometry (the
/// serving coordinator builds it from the "main" `ModelSpec`).
#[derive(Debug, Clone)]
pub struct KvPoolCfg {
    pub layers: usize,
    pub d_kv: usize,
    /// Sequence capacity of every view (`s_max`).
    pub s_max: usize,
    /// Rows per page; align to the decode block size so block commits
    /// land on whole pages.
    pub page_rows: usize,
    /// Byte budget for page storage; `max_pages = budget / page_bytes`.
    pub budget_bytes: usize,
}

impl KvPoolCfg {
    /// Bytes of one page: k + v (`[L, R, d_kv]` f32 each) + valid (`[R]`).
    pub fn page_bytes(&self) -> usize {
        (2 * self.layers * self.page_rows * self.d_kv + self.page_rows) * 4
    }

    pub fn max_pages(&self) -> usize {
        self.budget_bytes / self.page_bytes()
    }

    /// Pages covering `rows` sequence rows.
    pub fn span_pages(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_rows)
    }

    /// Bytes one dense [`super::KvCache`] session costs — the baseline
    /// the capacity bench compares against.
    pub fn dense_session_bytes(&self) -> usize {
        (2 * self.layers * self.s_max * self.d_kv + self.s_max) * 4
    }
}

/// Pool-lifetime counters (monotonic; exported through the serving stats
/// protocol).
#[derive(Debug, Clone, Default)]
pub struct KvPoolStats {
    /// Prompt pages adopted from the prefix index at admission.
    pub prefix_hits: u64,
    /// Prompt pages probed but absent from the index.
    pub prefix_misses: u64,
    /// Prompt-prefill forwards skipped entirely (full-prefix hits).
    pub prefill_skips: u64,
    /// Pages copied on write (shared-page isolation).
    pub cow_copies: u64,
    /// Pages (re)written by `install_full` calls.
    pub pages_refreshed: u64,
    /// Pages skipped by `install_full` because their rows were current —
    /// the incremental-refresh win.
    pub refresh_skips: u64,
    /// Reclaimable (retired but still prefix-indexed) pages evicted to
    /// satisfy allocations.
    pub evictions: u64,
    /// Admissions rejected for lack of page budget.
    pub admit_rejects: u64,
    /// Prefix-index hits discarded because the indexed page's own chain
    /// hash no longer matched at install time (index superseded between
    /// the admission probe and adoption).
    pub stale_hash_skips: u64,
    /// Mid-decode page allocations that failed (budget exhausted beyond
    /// the admission reservation).
    pub alloc_fails: u64,
    /// Pages released back to the pool by preemption spill
    /// (`KvView::spill`): a long-paused session frees its memory, not
    /// just its round slot.
    pub pages_spilled: u64,
    /// Spilled pages that were *not* re-adopted from the prefix index at
    /// resume and had to be rebuilt — the re-prefill cost of a spill
    /// (prefix pages usually come back free).
    pub pages_reprefilled: u64,
}

/// Point-in-time occupancy snapshot.
#[derive(Debug, Clone, Default)]
pub struct KvPoolUsage {
    /// Budget ceiling in pages.
    pub max_pages: usize,
    /// Pages referenced by at least one live session.
    pub in_use: usize,
    /// Pages promised to admitted sessions but not yet allocated.
    pub reserved: usize,
    /// Retired-but-indexed pages kept for prefix hits (evictable).
    pub reclaimable: usize,
    /// Physical pages ever allocated (<= max_pages).
    pub allocated: usize,
}

struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
    valid: Vec<f32>,
    valid_rows: usize,
    refs: u32,
    /// Prefix-index key this page is registered under, if any.
    hash: Option<u64>,
    lru: u64,
    /// Process-unique physical identity; refreshed on recycling so a
    /// reader caching (uid, stamp) can never mistake a recycled page for
    /// the one it staged earlier.
    uid: u64,
    /// Content version: bumped on every k/v/valid mutation. Starts at 1
    /// (`0` is the KvPage "untracked" sentinel).
    stamp: u64,
}

impl Page {
    fn new(layers: usize, page_rows: usize, d_kv: usize) -> Page {
        let n = layers * page_rows * d_kv;
        Page {
            k: vec![0.0; n],
            v: vec![0.0; n],
            valid: vec![0.0; page_rows],
            valid_rows: 0,
            refs: 0,
            hash: None,
            lru: 0,
            uid: next_page_uid(),
            stamp: 1,
        }
    }

    fn clear(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.valid.fill(0.0);
        self.valid_rows = 0;
        self.refs = 0;
        self.hash = None;
        self.uid = next_page_uid();
        self.stamp = 1;
    }
}

struct PoolInner {
    cfg: KvPoolCfg,
    max_pages: usize,
    pages: Vec<Page>,
    /// Cleared pages ready for reuse.
    free: Vec<usize>,
    /// refs == 0 but still prefix-indexed: content kept, evictable.
    reclaim: Vec<usize>,
    /// Prefix chain-hash -> page holding those prompt rows.
    index: HashMap<u64, usize>,
    /// Pages referenced by >= 1 live view.
    in_use: usize,
    /// Admission reservations not yet drawn down.
    reserved: usize,
    lru_clock: u64,
    stats: KvPoolStats,
}

impl PoolInner {
    /// Logical headroom: reclaimable pages do not count against it (the
    /// allocator evicts them on demand), so admission "considers
    /// reclaimable pages" by construction.
    fn free_capacity(&self) -> usize {
        self.max_pages - self.in_use - self.reserved
    }

    fn touch_lru(&mut self, pid: usize) {
        self.lru_clock += 1;
        self.pages[pid].lru = self.lru_clock;
    }

    /// Acquire a cleared physical page: recycle, grow, or evict the
    /// least-recently-used reclaimable page. `None` only when the slab is
    /// at `max_pages` with nothing reclaimable — which the capacity
    /// accounting in `take_page`/`admit` rules out before calling.
    fn acquire_physical(&mut self) -> Option<usize> {
        if let Some(pid) = self.free.pop() {
            return Some(pid);
        }
        if self.pages.len() < self.max_pages {
            let p = Page::new(self.cfg.layers, self.cfg.page_rows,
                              self.cfg.d_kv);
            self.pages.push(p);
            return Some(self.pages.len() - 1);
        }
        self.evict_one_reclaim()
    }

    /// Evict the LRU reclaimable page (unregister + clear) and hand it
    /// back for reuse.
    fn evict_one_reclaim(&mut self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, &pid) in self.reclaim.iter().enumerate() {
            let lru = self.pages[pid].lru;
            if best.map(|(_, b)| lru < b).unwrap_or(true) {
                best = Some((i, lru));
            }
        }
        let (i, _) = best?;
        let pid = self.reclaim.swap_remove(i);
        if let Some(h) = self.pages[pid].hash {
            if self.index.get(&h) == Some(&pid) {
                self.index.remove(&h);
            }
        }
        self.pages[pid].clear();
        self.stats.evictions += 1;
        Some(pid)
    }

    /// Drop one view reference; at zero the page either becomes
    /// reclaimable (still prefix-indexed) or returns to the free list.
    fn release_page(&mut self, pid: usize) {
        self.pages[pid].refs -= 1;
        if self.pages[pid].refs > 0 {
            return;
        }
        self.in_use -= 1;
        let indexed = self.pages[pid]
            .hash
            .map(|h| self.index.get(&h) == Some(&pid))
            .unwrap_or(false);
        if indexed {
            self.lru_clock += 1;
            self.pages[pid].lru = self.lru_clock;
            self.reclaim.push(pid);
        } else {
            self.pages[pid].clear();
            self.free.push(pid);
        }
    }
}

// ------------------------------------------------------------- hashing

#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Seed covering everything that must match for two prefills to install
/// identical rows: the prefill executable family (an `ar_prefill` row is
/// causal, a `prefill_xla` row bidirectional) and the cache geometry.
fn prefix_seed(tag: &str, layers: usize, d_kv: usize, page_rows: usize)
               -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in tag.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for v in [layers as u64, d_kv as u64, page_rows as u64] {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Per-page chain hashes over `tokens[..prefix_rows]`: the hash of page
/// `i` covers all tokens up to that page's end, so a hit certifies the
/// *entire* prefix through page `i` matches — required for bidirectional
/// prefills, whose rows depend on the whole visible prompt. 64-bit
/// collisions are accepted (same trade as content-hash page dedup in
/// production paged-attention servers).
fn chain_hashes(seed: u64, tokens: &[i32], prefix_rows: usize,
                page_rows: usize) -> Vec<(usize, u64)> {
    let mut out = Vec::new();
    if prefix_rows == 0 {
        return out;
    }
    debug_assert!(tokens.len() >= prefix_rows);
    let mut h = seed;
    for slot in 0..prefix_rows.div_ceil(page_rows) {
        let lo = slot * page_rows;
        let hi = ((slot + 1) * page_rows).min(prefix_rows);
        for &t in &tokens[lo..hi] {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        // mix the covered-row count so a partial page cannot alias the
        // full page with the same leading tokens
        out.push((slot, mix64(h ^ (((hi - lo) as u64) << 40)
                              ^ slot as u64)));
    }
    out
}

/// Affinity routing key for a prompt under a given prefill family and
/// cache geometry: the chain hash of the *first* prefix page — the root
/// of the prefix chain. Two prompts share it iff their first page of
/// prompt tokens matches under the same executable family and geometry,
/// which is exactly when their pool pages are mutually adoptable — so a
/// fleet router that sends equal keys to the same replica lands
/// requests where their prompt pages already live. `None` when the
/// prompt does not fill a single page (no shareable pages exist, so
/// there is nothing to be affine to).
pub fn prefix_routing_key(tag: &str, layers: usize, d_kv: usize,
                          page_rows: usize, tokens: &[i32],
                          prefix_rows: usize) -> Option<u64> {
    let prefix_rows = prefix_rows.min(tokens.len());
    if prefix_rows < page_rows {
        return None;
    }
    let seed = prefix_seed(tag, layers, d_kv, page_rows);
    chain_hashes(seed, tokens, prefix_rows, page_rows)
        .first()
        .map(|&(_, h)| h)
}

/// Rendezvous (highest-random-weight) score of `replica` for `key`: a
/// router ranks the live replicas by this score and picks the maximum.
/// Removing a replica remaps only the keys it owned and adding one
/// steals only the keys it now wins — no global reshuffle of warm
/// prefix pages.
pub fn rendezvous_score(key: u64, replica: u64) -> u64 {
    mix64(key ^ mix64(replica ^ 0xD3A9_5F2E_C0FF_EE00))
}

/// Resolve a prefix-index hit, re-verifying that the indexed page still
/// carries the chain hash it is indexed under. The index and the page's
/// own `hash` field are kept consistent by construction, but adoption is
/// the one place where trusting a stale mapping would splice another
/// prompt's rows into a session — so the hit is re-verified at install
/// time instead of assumed (the admission probe and the actual adoption
/// happen in different rounds under peek-based admission, with
/// `evict_reclaimable` free to recycle pages in between).
fn verified_hit(inner: &PoolInner, h: u64) -> Option<usize> {
    let pid = *inner.index.get(&h)?;
    if inner.pages[pid].hash == Some(h) {
        Some(pid)
    } else {
        None
    }
}

/// Pages a session needs admitted: its whole span, minus pages adopted
/// from live sessions, plus one copy-on-write margin when the prompt
/// prefix ends mid-page — that partial page is (or becomes) registered
/// in the prefix index, so the session's first decode commit into it
/// always copies, leaving the pristine prefix page adoptable.
/// Reclaimable-page adoptions still count toward the requirement — they
/// move back to in-use. Non-causal (bidirectional) prefixes adopt
/// all-or-nothing, so their hits only reduce the requirement when every
/// prefix page is present.
fn required_pages(inner: &PoolInner, hashes: &[(usize, u64)],
                  prefix_rows: usize, span_rows: usize, causal: bool)
                  -> usize {
    let span_slots = inner.cfg.span_pages(span_rows);
    let mut live_hits = 0usize;
    let mut hits = 0usize;
    for &(_, h) in hashes {
        if let Some(pid) = verified_hit(inner, h) {
            hits += 1;
            if inner.pages[pid].refs > 0 {
                live_hits += 1;
            }
        }
    }
    if !causal && hits < hashes.len() {
        live_hits = 0; // partial bidirectional hit: nothing is adopted
    }
    let margin = usize::from(!hashes.is_empty()
        && prefix_rows % inner.cfg.page_rows != 0);
    // saturating: a caller probing an out-of-range geometry (prefix
    // beyond span) reads "free", and admit's range checks reject it
    span_slots.saturating_sub(live_hits) + margin
}

// ---------------------------------------------------------------- pool

/// Shared handle to one paged KV pool (single-threaded interior
/// mutability, like the engine's executable cache).
#[derive(Clone)]
pub struct SharedKvPool {
    inner: Rc<RefCell<PoolInner>>,
}

impl SharedKvPool {
    pub fn new(cfg: KvPoolCfg) -> SharedKvPool {
        let max_pages = cfg.max_pages();
        SharedKvPool {
            inner: Rc::new(RefCell::new(PoolInner {
                max_pages,
                pages: Vec::new(),
                free: Vec::new(),
                reclaim: Vec::new(),
                index: HashMap::new(),
                in_use: 0,
                reserved: 0,
                lru_clock: 0,
                stats: KvPoolStats::default(),
                cfg,
            })),
        }
    }

    pub fn cfg(&self) -> KvPoolCfg {
        self.inner.borrow().cfg.clone()
    }

    pub fn max_pages(&self) -> usize {
        self.inner.borrow().max_pages
    }

    /// Pages covering `rows` sequence rows (admission sizing helper).
    pub fn span_pages(&self, rows: usize) -> usize {
        self.inner.borrow().cfg.span_pages(rows)
    }

    pub fn usage(&self) -> KvPoolUsage {
        let p = self.inner.borrow();
        KvPoolUsage {
            max_pages: p.max_pages,
            in_use: p.in_use,
            reserved: p.reserved,
            reclaimable: p.reclaim.len(),
            allocated: p.pages.len(),
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        self.inner.borrow().stats.clone()
    }

    /// Worst-case pages one session of this geometry can ever hold
    /// (no-hit reservation). NOTE: as a hard-reject bound this
    /// over-charges prefix-heavy workloads — a request whose worst case
    /// exceeds `max_pages` may still be servable when its prompt pages
    /// are adopted from an indexed chain. Admission should bound against
    /// [`SharedKvPool::required_pages_for`], which accounts the expected
    /// shared-prefix adoption under the current index (re-evaluated per
    /// cycle, so an evicted chain degrades to this worst case instead of
    /// admitting on stale expectations).
    pub fn worst_case_pages(&self, prefix_rows: usize, span_rows: usize)
                            -> usize {
        let p = self.inner.borrow();
        p.cfg.span_pages(span_rows)
            + usize::from(prefix_rows > 0
                          && prefix_rows % p.cfg.page_rows != 0)
    }

    /// Pages this request would draw from the budget if admitted right
    /// now: the span reservation minus prefix pages expected to be
    /// adopted from live sessions under the current index (hash-verified,
    /// exactly the accounting `PagedKv::admit` applies). Between this
    /// probe and the actual admit the index can change — callers must
    /// treat an exhausted `admit` as "wait and re-probe", not as a hard
    /// failure (the serving coordinator leaves the request queued).
    pub fn required_pages_for(&self, prompt_tokens: &[i32],
                              prefix_tag: &str, prefix_rows: usize,
                              span_rows: usize, causal: bool) -> usize {
        let p = self.inner.borrow();
        let prefix_rows = prefix_rows.min(prompt_tokens.len());
        let seed = prefix_seed(prefix_tag, p.cfg.layers, p.cfg.d_kv,
                               p.cfg.page_rows);
        let hashes = chain_hashes(seed, &prompt_tokens[..prefix_rows],
                                  prefix_rows, p.cfg.page_rows);
        required_pages(&p, &hashes, prefix_rows, span_rows, causal)
    }

    /// Admission probe (no side effects): would a session with this
    /// prompt/geometry get its page reservation? Reclaimable pages never
    /// block admission — the allocator evicts them on demand. `causal`
    /// marks a causal prefill family (per-page adoption; bidirectional
    /// families adopt all-or-nothing).
    pub fn can_admit(&self, prompt_tokens: &[i32], prefix_tag: &str,
                     prefix_rows: usize, span_rows: usize, causal: bool)
                     -> bool {
        let p = self.inner.borrow();
        if prefix_rows > prompt_tokens.len() || prefix_rows > span_rows
            || span_rows > p.cfg.s_max
        {
            return false;
        }
        let seed = prefix_seed(prefix_tag, p.cfg.layers, p.cfg.d_kv,
                               p.cfg.page_rows);
        let hashes = chain_hashes(seed, &prompt_tokens[..prefix_rows],
                                  prefix_rows, p.cfg.page_rows);
        required_pages(&p, &hashes, prefix_rows, span_rows, causal)
            <= p.free_capacity()
    }

    /// Evict up to `n` reclaimable pages (LRU first), returning how many
    /// were evicted. Operator/test hook; normal allocation evicts lazily.
    pub fn evict_reclaimable(&self, n: usize) -> usize {
        let mut p = self.inner.borrow_mut();
        let mut done = 0;
        while done < n {
            match p.evict_one_reclaim() {
                Some(pid) => {
                    p.free.push(pid);
                    done += 1;
                }
                None => break,
            }
        }
        done
    }
}

// ---------------------------------------------------------------- view

/// Per-session page-table view into a [`SharedKvPool`]; implements
/// [`KvView`] so every decode policy runs unchanged over paged storage.
pub struct PagedKv {
    pool: SharedKvPool,
    layers: usize,
    s_max: usize,
    d_kv: usize,
    page_rows: usize,
    table: Vec<Option<usize>>,
    /// Maintained count of valid rows across the view.
    valid_rows: usize,
    /// Admission reservation not yet drawn down.
    reserved_left: usize,
    /// View-content generation; advanced whenever row content changes.
    seq_gen: u64,
    /// Generation at which each page slot's rows last changed.
    slot_touch: Vec<u64>,
    /// Generation of each page slot's last full-forward install.
    slot_install: Vec<u64>,
    /// Rows the prompt prefill will install (prefix-sharing domain).
    prefix_rows: usize,
    /// Prefix slots (+ chain hash) not yet registered in the pool index.
    pending: Vec<(usize, u64)>,
    /// Every prefix page was adopted at admission: the prompt-prefill
    /// forward can be skipped.
    prefill_cached: bool,
    /// Admission geometry retained so a preemption spill can re-admit
    /// the view later (`KvView::spill` / `KvView::readmit`).
    prefix_tag: String,
    span_rows: usize,
    causal: bool,
    /// Between `spill` and a successful `readmit`: the table is empty
    /// and `spill_restore` remembers which rows must be rebuilt.
    spilled: bool,
    spill_restore: Vec<(usize, usize)>,
    spill_pages_held: usize,
}

impl PagedKv {
    /// Admit a session view: probe the prefix index over
    /// `prompt_tokens[..prefix_rows]`, adopt hits (per page for causal
    /// prefill families, all-or-nothing for bidirectional ones — see the
    /// module docs), and reserve the pages the `span_rows`-row session
    /// may still need. Fails with a [`POOL_EXHAUSTED`] error when the
    /// budget cannot cover it.
    pub fn admit(pool: &SharedKvPool, prompt_tokens: &[i32],
                 prefix_tag: &str, prefix_rows: usize, span_rows: usize,
                 causal: bool) -> Result<PagedKv> {
        let mut p = pool.inner.borrow_mut();
        let cfg = p.cfg.clone();
        if prefix_rows > prompt_tokens.len() || prefix_rows > span_rows
            || span_rows > cfg.s_max
        {
            bail!("paged kv admit: prefix {prefix_rows} / span {span_rows} \
                   out of range (prompt {}, s_max {})",
                  prompt_tokens.len(), cfg.s_max);
        }
        let seed = prefix_seed(prefix_tag, cfg.layers, cfg.d_kv,
                               cfg.page_rows);
        let hashes = chain_hashes(seed, &prompt_tokens[..prefix_rows],
                                  prefix_rows, cfg.page_rows);
        let required =
            required_pages(&p, &hashes, prefix_rows, span_rows, causal);
        if required > p.free_capacity() {
            p.stats.admit_rejects += 1;
            bail!("{POOL_EXHAUSTED}: session needs {required} pages, \
                   {} free of {}", p.free_capacity(), p.max_pages);
        }
        p.reserved += required;

        let table_slots = cfg.s_max.div_ceil(cfg.page_rows);
        let mut view = PagedKv {
            pool: pool.clone(),
            layers: cfg.layers,
            s_max: cfg.s_max,
            d_kv: cfg.d_kv,
            page_rows: cfg.page_rows,
            table: vec![None; table_slots],
            valid_rows: 0,
            reserved_left: required,
            seq_gen: 1,
            slot_touch: vec![0; table_slots],
            slot_install: vec![0; table_slots],
            prefix_rows,
            pending: Vec::new(),
            prefill_cached: false,
            prefix_tag: prefix_tag.to_string(),
            span_rows,
            causal,
            spilled: false,
            spill_restore: Vec::new(),
            spill_pages_held: 0,
        };

        // adopt prefix hits (live pages share; reclaimable pages revive,
        // drawing from this session's reservation). Bidirectional
        // prefixes adopt only on a full-prompt match: their row content
        // depends on the whole visible prompt, so a partially matching
        // prefix would splice rows computed under someone else's suffix.
        // Every hit is re-verified against the page's own chain hash at
        // install time (`verified_hit`): a mapping superseded between the
        // admission probe and this adoption is treated as a miss, never
        // adopted.
        let adoptable = causal
            || hashes.iter().all(|(_, h)| verified_hit(&p, *h).is_some());
        let mut hits = 0usize;
        for &(slot, h) in &hashes {
            if p.index.contains_key(&h) && verified_hit(&p, h).is_none() {
                // superseded mapping: treat as a miss and self-heal the
                // index so the slot can be re-registered by this prefill
                p.index.remove(&h);
                p.stats.stale_hash_skips += 1;
            }
            let hit = verified_hit(&p, h).filter(|_| adoptable);
            let Some(pid) = hit else {
                view.pending.push((slot, h));
                continue;
            };
            if p.pages[pid].refs == 0 {
                p.reclaim.retain(|&x| x != pid);
                p.in_use += 1;
                p.reserved -= 1;
                view.reserved_left -= 1;
            }
            p.touch_lru(pid);
            p.pages[pid].refs += 1;
            view.valid_rows += p.pages[pid].valid_rows;
            view.table[slot] = Some(pid);
            hits += 1;
        }
        p.stats.prefix_hits += hits as u64;
        p.stats.prefix_misses += (hashes.len() - hits) as u64;
        view.prefill_cached = !hashes.is_empty() && hits == hashes.len();
        Ok(view)
    }

    /// Whether the whole prompt prefix was adopted at admission (the
    /// prompt-prefill forward is skippable).
    pub fn prefill_cached(&self) -> bool {
        self.prefill_cached
    }

    /// The pool this view draws from.
    pub fn pool(&self) -> &SharedKvPool {
        &self.pool
    }

    /// Pages currently referenced by this view.
    pub fn pages_held(&self) -> usize {
        self.table.iter().filter(|e| e.is_some()).count()
    }

    /// Draw one fresh page, preferring this session's admission
    /// reservation; beyond it, overflow into the pool's free capacity.
    fn take_page(&mut self) -> Result<usize> {
        let mut p = self.pool.inner.borrow_mut();
        if self.reserved_left > 0 {
            p.reserved -= 1;
            self.reserved_left -= 1;
        } else if p.free_capacity() == 0 {
            p.stats.alloc_fails += 1;
            bail!("{POOL_EXHAUSTED}: mid-decode page allocation \
                   (in_use {}, reserved {}, max {})",
                  p.in_use, p.reserved, p.max_pages);
        }
        let pid = p.acquire_physical().expect("capacity accounted");
        p.in_use += 1;
        p.pages[pid].refs = 1;
        p.touch_lru(pid);
        Ok(pid)
    }

    /// Make `slot` writable by this view: allocate on first touch; copy
    /// on write when the page is shared with another session *or*
    /// registered in the prefix index (the pristine prompt page must stay
    /// adoptable — the registrant's own decode commits copy too).
    fn ensure_writable(&mut self, slot: usize) -> Result<usize> {
        let Some(pid) = self.table[slot] else {
            let pid = self.take_page()?;
            self.table[slot] = Some(pid);
            return Ok(pid);
        };
        let needs_cow = {
            let mut p = self.pool.inner.borrow_mut();
            if p.pages[pid].refs > 1 {
                true
            } else {
                match p.pages[pid].hash {
                    Some(h) if p.index.get(&h) == Some(&pid) => true,
                    Some(_) => {
                        // stale hash (index superseded): plain private page
                        p.pages[pid].hash = None;
                        false
                    }
                    None => false,
                }
            }
        };
        if !needs_cow {
            return Ok(pid);
        }
        let new_pid = self.take_page()?;
        let mut p = self.pool.inner.borrow_mut();
        // clone-based copy keeps the borrow simple; pages are small
        // (one decode block of rows)
        let (k, v, valid, rows) = {
            let old = &p.pages[pid];
            (old.k.clone(), old.v.clone(), old.valid.clone(),
             old.valid_rows)
        };
        {
            let np = &mut p.pages[new_pid];
            np.k = k;
            np.v = v;
            np.valid = valid;
            np.valid_rows = rows;
            np.stamp += 1; // fresh uid + new content: readers must recopy
        }
        // drop our reference to the original: a registered page with no
        // remaining referents becomes reclaimable, still adoptable
        p.release_page(pid);
        p.stats.cow_copies += 1;
        self.table[slot] = Some(new_pid);
        Ok(new_pid)
    }

    /// Register still-pending prefix pages whose prompt rows are now
    /// fully installed, making them adoptable by future sessions.
    fn register_ready_prefix_pages(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let r = self.page_rows;
        let mut still = Vec::new();
        for &(slot, h) in &self.pending {
            let Some(pid) = self.table[slot] else {
                still.push((slot, h));
                continue;
            };
            let lo = slot * r;
            let hi = ((slot + 1) * r).min(self.prefix_rows);
            let mut p = self.pool.inner.borrow_mut();
            let ready =
                (lo..hi).all(|pos| p.pages[pid].valid[pos - lo] > 0.0);
            if !ready {
                still.push((slot, h));
                continue;
            }
            if p.pages[pid].refs == 1 && p.pages[pid].hash.is_none()
                && !p.index.contains_key(&h)
            {
                p.pages[pid].hash = Some(h);
                p.index.insert(h, pid);
            }
        }
        self.pending = still;
    }

    #[inline]
    fn slot_of(&self, pos: usize) -> usize {
        pos / self.page_rows
    }
}

impl KvView for PagedKv {
    fn layers(&self) -> usize {
        self.layers
    }

    fn capacity(&self) -> usize {
        self.s_max
    }

    fn d_kv(&self) -> usize {
        self.d_kv
    }

    fn valid_count(&self) -> usize {
        self.valid_rows
    }

    fn is_valid(&self, pos: usize) -> bool {
        match self.table[self.slot_of(pos)] {
            Some(pid) => {
                self.pool.inner.borrow().pages[pid].valid
                    [pos % self.page_rows] > 0.0
            }
            None => false,
        }
    }

    fn k_dense(&self) -> Cow<'_, [f32]> {
        let (l, s, d, r) = (self.layers, self.s_max, self.d_kv,
                            self.page_rows);
        let mut out = vec![0.0f32; l * s * d];
        let p = self.pool.inner.borrow();
        for (slot, entry) in self.table.iter().enumerate() {
            let Some(pid) = entry else { continue };
            let pg = &p.pages[*pid];
            let rows = r.min(s - slot * r);
            for layer in 0..l {
                let src = layer * r * d;
                let dst = (layer * s + slot * r) * d;
                out[dst..dst + rows * d]
                    .copy_from_slice(&pg.k[src..src + rows * d]);
            }
        }
        Cow::Owned(out)
    }

    fn v_dense(&self) -> Cow<'_, [f32]> {
        let (l, s, d, r) = (self.layers, self.s_max, self.d_kv,
                            self.page_rows);
        let mut out = vec![0.0f32; l * s * d];
        let p = self.pool.inner.borrow();
        for (slot, entry) in self.table.iter().enumerate() {
            let Some(pid) = entry else { continue };
            let pg = &p.pages[*pid];
            let rows = r.min(s - slot * r);
            for layer in 0..l {
                let src = layer * r * d;
                let dst = (layer * s + slot * r) * d;
                out[dst..dst + rows * d]
                    .copy_from_slice(&pg.v[src..src + rows * d]);
            }
        }
        Cow::Owned(out)
    }

    fn valid_dense(&self) -> Cow<'_, [f32]> {
        let (s, r) = (self.s_max, self.page_rows);
        let mut out = vec![0.0f32; s];
        let p = self.pool.inner.borrow();
        for (slot, entry) in self.table.iter().enumerate() {
            let Some(pid) = entry else { continue };
            let rows = r.min(s - slot * r);
            out[slot * r..slot * r + rows]
                .copy_from_slice(&p.pages[*pid].valid[..rows]);
        }
        Cow::Owned(out)
    }

    /// Allocation-free paged-layout probe: marks the view
    /// paged-native-readable to backends.
    fn page_rows(&self) -> Option<usize> {
        Some(self.page_rows)
    }

    /// Page-table description: O(live pages), no row data copied.
    fn page_args(&self) -> Option<KvPageArgs> {
        let p = self.pool.inner.borrow();
        let mut args = KvPageArgs {
            page_rows: self.page_rows,
            ..KvPageArgs::default()
        };
        for (slot, entry) in self.table.iter().enumerate() {
            let Some(pid) = entry else { continue };
            let pg = &p.pages[*pid];
            args.slots.push(slot);
            args.ids.push(pg.uid);
            args.stamps.push(pg.stamp);
            args.valid_rows.push(pg.valid_rows);
        }
        Some(args)
    }

    /// Visit live pages in place — zero-copy: the callback borrows the
    /// pool's page buffers directly for the duration of each call.
    fn for_each_page(&self, f: &mut dyn FnMut(KvPage<'_>)) {
        let (s, r) = (self.s_max, self.page_rows);
        let p = self.pool.inner.borrow();
        for (slot, entry) in self.table.iter().enumerate() {
            let Some(pid) = entry else { continue };
            let pg = &p.pages[*pid];
            f(KvPage {
                slot,
                rows: r.min(s - slot * r),
                valid_rows: pg.valid_rows,
                id: pg.uid,
                stamp: pg.stamp,
                k: &pg.k,
                v: &pg.v,
                valid: &pg.valid,
            });
        }
    }

    fn install_full(&mut self, k_full: &[f32], v_full: &[f32], pos0: usize,
                    pos1: usize) -> Result<()> {
        let (l, s, d, r) = (self.layers, self.s_max, self.d_kv,
                            self.page_rows);
        if k_full.len() != l * s * d || v_full.len() != l * s * d {
            bail!("paged install_full: expected [L, S, d_kv] buffers");
        }
        if pos0 >= pos1 {
            return Ok(());
        }
        if pos1 > s {
            bail!("paged install_full: range {pos0}..{pos1} beyond s_max {s}");
        }
        for slot in self.slot_of(pos0)..=self.slot_of(pos1 - 1) {
            let lo = pos0.max(slot * r);
            let hi = pos1.min((slot + 1) * r);
            // incremental refresh: skip a page whose covered rows are all
            // installed and untouched since its last full install
            let fresh = match self.table[slot] {
                Some(pid) => {
                    self.slot_install[slot] >= self.slot_touch[slot] && {
                        let p = self.pool.inner.borrow();
                        let pg = &p.pages[pid];
                        (lo..hi).all(|pos| pg.valid[pos - slot * r] > 0.0)
                    }
                }
                None => false,
            };
            if fresh {
                self.pool.inner.borrow_mut().stats.refresh_skips += 1;
                continue;
            }
            let pid = self.ensure_writable(slot)?;
            let mut newly = 0usize;
            {
                let mut p = self.pool.inner.borrow_mut();
                let pg = &mut p.pages[pid];
                for pos in lo..hi {
                    let row = pos - slot * r;
                    for layer in 0..l {
                        let src = (layer * s + pos) * d;
                        let dst = (layer * r + row) * d;
                        pg.k[dst..dst + d]
                            .copy_from_slice(&k_full[src..src + d]);
                        pg.v[dst..dst + d]
                            .copy_from_slice(&v_full[src..src + d]);
                    }
                    if pg.valid[row] == 0.0 {
                        pg.valid[row] = 1.0;
                        pg.valid_rows += 1;
                        newly += 1;
                    }
                }
                pg.stamp += 1;
                p.stats.pages_refreshed += 1;
            }
            self.valid_rows += newly;
            self.seq_gen += 1;
            self.slot_install[slot] = self.seq_gen;
            self.slot_touch[slot] = self.seq_gen;
        }
        self.register_ready_prefix_pages();
        Ok(())
    }

    fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32], w: usize,
                          pairs: &[(usize, usize)]) -> Result<()> {
        let (l, d, r) = (self.layers, self.d_kv, self.page_rows);
        if k_win.len() != l * w * d || v_win.len() != l * w * d {
            bail!("paged commit: expected [L, W, d_kv] buffers");
        }
        if pairs.is_empty() {
            return Ok(());
        }
        // group by page so each shared page is copied at most once
        let mut by_slot: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
        for &(off, pos) in pairs {
            if off >= w || pos >= self.s_max {
                bail!("paged commit: off {off} / pos {pos} out of range");
            }
            let slot = pos / r;
            match by_slot.iter_mut().find(|(s, _)| *s == slot) {
                Some((_, v)) => v.push((off, pos)),
                None => by_slot.push((slot, vec![(off, pos)])),
            }
        }
        self.seq_gen += 1;
        let gen = self.seq_gen;
        for (slot, items) in by_slot {
            let pid = self.ensure_writable(slot)?;
            let mut newly = 0usize;
            {
                let mut p = self.pool.inner.borrow_mut();
                let pg = &mut p.pages[pid];
                for (off, pos) in items {
                    let row = pos - slot * r;
                    for layer in 0..l {
                        let src = (layer * w + off) * d;
                        let dst = (layer * r + row) * d;
                        pg.k[dst..dst + d]
                            .copy_from_slice(&k_win[src..src + d]);
                        pg.v[dst..dst + d]
                            .copy_from_slice(&v_win[src..src + d]);
                    }
                    if pg.valid[row] == 0.0 {
                        pg.valid[row] = 1.0;
                        pg.valid_rows += 1;
                        newly += 1;
                    }
                }
                pg.stamp += 1;
            }
            self.valid_rows += newly;
            self.slot_touch[slot] = gen;
        }
        Ok(())
    }

    fn invalidate_from(&mut self, pos: usize) -> Result<()> {
        let r = self.page_rows;
        self.seq_gen += 1;
        let gen = self.seq_gen;
        for slot in self.slot_of(pos.min(self.s_max - 1))..self.table.len() {
            let Some(pid) = self.table[slot] else { continue };
            let lo = pos.max(slot * r);
            let hi = ((slot + 1) * r).min(self.s_max);
            if lo >= hi {
                continue;
            }
            let any = {
                let p = self.pool.inner.borrow();
                let pg = &p.pages[pid];
                (lo..hi).any(|q| pg.valid[q - slot * r] > 0.0)
            };
            if !any {
                continue;
            }
            let pid = self.ensure_writable(slot)?;
            let mut dropped = 0usize;
            {
                let mut p = self.pool.inner.borrow_mut();
                let pg = &mut p.pages[pid];
                for q in lo..hi {
                    let row = q - slot * r;
                    if pg.valid[row] > 0.0 {
                        pg.valid[row] = 0.0;
                        pg.valid_rows -= 1;
                        dropped += 1;
                    }
                }
                pg.stamp += 1;
            }
            self.valid_rows -= dropped;
            self.slot_touch[slot] = gen;
        }
        Ok(())
    }

    fn note_prefill_skipped(&mut self) {
        self.pool.inner.borrow_mut().stats.prefill_skips += 1;
    }

    /// Preemption spill: remember which rows are valid, then release
    /// every page (prefix-indexed pages become reclaimable — still
    /// adoptable, by this session's own readmit or anyone else's) plus
    /// the unused reservation. The view stays bound to its pool and is
    /// rebuilt by `readmit`.
    fn spill(&mut self) -> Option<usize> {
        if self.spilled {
            return None;
        }
        let r = self.page_rows;
        let mut runs: Vec<(usize, usize)> = Vec::new();
        let mut released = 0usize;
        {
            let mut p = self.pool.inner.borrow_mut();
            for (slot, entry) in self.table.iter().enumerate() {
                let Some(pid) = *entry else { continue };
                let rows = r.min(self.s_max - slot * r);
                for row in 0..rows {
                    if p.pages[pid].valid[row] > 0.0 {
                        let pos = slot * r + row;
                        match runs.last_mut() {
                            Some((_, hi)) if *hi == pos => *hi = pos + 1,
                            _ => runs.push((pos, pos + 1)),
                        }
                    }
                }
                p.release_page(pid);
                released += 1;
            }
            p.reserved -= self.reserved_left;
            p.stats.pages_spilled += released as u64;
        }
        self.table.fill(None);
        self.valid_rows = 0;
        self.reserved_left = 0;
        self.seq_gen += 1;
        self.slot_touch.fill(0);
        self.slot_install.fill(0);
        self.pending.clear();
        self.prefill_cached = false;
        self.spilled = true;
        self.spill_restore = runs;
        self.spill_pages_held = released;
        Some(released)
    }

    fn spilled(&self) -> bool {
        self.spilled
    }

    /// Re-admit after a spill: probe the prefix index again (the pages
    /// this view released are still indexed unless evicted, so shared —
    /// and usually even private — prompt pages come back by adoption),
    /// re-reserve the span, and record which previously-valid rows still
    /// need their content rebuilt (`take_spill_restore_runs`). Fails
    /// pool-exhausted exactly like `admit`; the view stays spilled and
    /// the call can be retried.
    fn readmit(&mut self, prompt_tokens: &[i32]) -> Result<()> {
        if !self.spilled {
            return Ok(());
        }
        let pool = self.pool.clone();
        let fresh = PagedKv::admit(&pool, prompt_tokens, &self.prefix_tag,
                                   self.prefix_rows, self.span_rows,
                                   self.causal)?;
        let mut restore: Vec<(usize, usize)> = Vec::new();
        for &(lo, hi) in &self.spill_restore {
            let mut pos = lo;
            while pos < hi {
                if fresh.is_valid(pos) {
                    pos += 1;
                    continue;
                }
                let start = pos;
                while pos < hi && !fresh.is_valid(pos) {
                    pos += 1;
                }
                restore.push((start, pos));
            }
        }
        let rebuilt =
            self.spill_pages_held.saturating_sub(fresh.pages_held());
        pool.inner.borrow_mut().stats.pages_reprefilled += rebuilt as u64;
        // the spilled view's table is empty and its reservation zero, so
        // the Drop this assignment triggers releases nothing
        *self = fresh;
        self.spill_restore = restore;
        Ok(())
    }

    fn take_spill_restore_runs(&mut self) -> Vec<(usize, usize)> {
        std::mem::take(&mut self.spill_restore)
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        let mut p = self.pool.inner.borrow_mut();
        p.reserved -= self.reserved_left;
        for entry in &self.table {
            if let Some(pid) = *entry {
                p.release_page(pid);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pages: usize) -> KvPoolCfg {
        let c = KvPoolCfg {
            layers: 2,
            d_kv: 4,
            s_max: 128,
            page_rows: 32,
            budget_bytes: 0,
        };
        KvPoolCfg { budget_bytes: pages * c.page_bytes(), ..c }
    }

    fn full(pool_cfg: &KvPoolCfg, base: f32) -> Vec<f32> {
        (0..pool_cfg.layers * pool_cfg.s_max * pool_cfg.d_kv)
            .map(|i| base + i as f32)
            .collect()
    }

    #[test]
    fn pages_allocate_lazily_and_release_on_drop() {
        let c = cfg(8);
        let pool = SharedKvPool::new(c.clone());
        assert_eq!(pool.max_pages(), 8);
        {
            let mut v = PagedKv::admit(&pool, &[], "x", 0, 96, false).unwrap();
            assert_eq!(pool.usage().reserved, 3);
            assert_eq!(pool.usage().in_use, 0);
            let kf = full(&c, 0.0);
            v.install_full(&kf, &kf, 0, 40).unwrap();
            assert_eq!(v.valid_count(), 40);
            assert!(v.is_valid(39) && !v.is_valid(40));
            let u = pool.usage();
            assert_eq!(u.in_use, 2); // rows 0..40 -> 2 pages
            assert_eq!(u.reserved, 1);
            // dense gather matches installed content
            let k = v.k_dense();
            assert_eq!(k[7 * c.d_kv], kf[7 * c.d_kv]);
            assert_eq!(v.valid_dense()[39], 1.0);
            assert_eq!(v.valid_dense()[40], 0.0);
        }
        // drop released everything (no hashes registered: prefix 0)
        let u = pool.usage();
        assert_eq!(u.in_use, 0);
        assert_eq!(u.reserved, 0);
        assert_eq!(u.reclaimable, 0);
    }

    #[test]
    fn prefix_sharing_adopts_and_skips() {
        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..40).map(|i| 5 + i % 11).collect();
        let kf = full(&c, 1.0);

        let mut a =
            PagedKv::admit(&pool, &prompt, "prefill_xla", 40, 104, false).unwrap();
        assert!(!a.prefill_cached());
        a.install_full(&kf, &kf, 0, 40).unwrap(); // prefill: registers pages
        assert_eq!(pool.stats().prefix_misses, 2);

        // same prompt, same tag: both prefix pages adopted
        let b =
            PagedKv::admit(&pool, &prompt, "prefill_xla", 40, 104, false).unwrap();
        assert!(b.prefill_cached());
        assert_eq!(pool.stats().prefix_hits, 2);
        assert_eq!(b.valid_count(), 40);
        assert!(b.prefix_ready(40));
        // adopted rows carry A's content
        assert_eq!(b.k_dense()[..4], a.k_dense()[..4]);

        // different tag (e.g. the causal ar_prefill family) must miss
        let d = PagedKv::admit(&pool, &prompt, "ar_prefill", 40, 104, false)
            .unwrap();
        assert!(!d.prefill_cached());
    }

    #[test]
    fn cow_isolates_shared_pages() {
        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..40).map(|i| 7 + i % 9).collect();
        let kf = full(&c, 2.0);
        let mut a =
            PagedKv::admit(&pool, &prompt, "t", 40, 104, false).unwrap();
        a.install_full(&kf, &kf, 0, 40).unwrap();
        let mut b =
            PagedKv::admit(&pool, &prompt, "t", 40, 104, false).unwrap();
        assert!(b.prefill_cached());

        // B commits a decode row into the shared partial page (rows 32..40
        // prompt + row 41 commit lands in slot 1)
        let w = 4;
        let kw: Vec<f32> =
            (0..c.layers * w * c.d_kv).map(|i| 900.0 + i as f32).collect();
        b.commit_window_rows(&kw, &kw, w, &[(0, 41)]).unwrap();
        assert_eq!(pool.stats().cow_copies, 1);
        assert!(b.is_valid(41));
        assert!(!a.is_valid(41), "CoW must isolate A from B's commit");
        // A's copy of row 33 is untouched; B kept the adopted content
        assert_eq!(a.k_dense()[33 * c.d_kv], b.k_dense()[33 * c.d_kv]);
    }

    #[test]
    fn incremental_refresh_skips_current_pages() {
        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let mut v = PagedKv::admit(&pool, &[], "t", 0, 128, false).unwrap();
        let kf = full(&c, 3.0);
        v.install_full(&kf, &kf, 0, 64).unwrap();
        assert_eq!(pool.stats().pages_refreshed, 2);
        assert_eq!(pool.stats().refresh_skips, 0);

        // re-install over the same rows: both pages are current -> skipped
        v.install_full(&kf, &kf, 0, 64).unwrap();
        assert_eq!(pool.stats().pages_refreshed, 2);
        assert_eq!(pool.stats().refresh_skips, 2);

        // a commit touches page 1; the next refresh rewrites only it
        let w = 4;
        let kw = vec![5.0f32; c.layers * w * c.d_kv];
        v.commit_window_rows(&kw, &kw, w, &[(0, 40)]).unwrap();
        v.install_full(&kf, &kf, 0, 64).unwrap();
        let s = pool.stats();
        assert_eq!(s.pages_refreshed, 3, "only the touched page rewrites");
        assert_eq!(s.refresh_skips, 3);
        // the refresh restored the full-forward value at row 40
        assert_eq!(v.k_dense()[40 * c.d_kv], kf[40 * c.d_kv]);
    }

    #[test]
    fn budget_exhaustion_reclaim_and_eviction() {
        let c = cfg(4);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..20).map(|i| 3 + i).collect();
        let kf = full(&c, 4.0);

        // span 96 rows -> 3 pages + 1 CoW margin (partial prompt page):
        // fits exactly
        let mut a = PagedKv::admit(&pool, &prompt, "t", 20, 96, false).unwrap();
        a.install_full(&kf, &kf, 0, 20).unwrap();
        // a second session cannot fit alongside it
        let err = PagedKv::admit(&pool, &prompt, "t", 20, 96, false).unwrap_err();
        assert!(is_pool_exhausted(&err), "{err:#}");
        assert!(pool.stats().admit_rejects >= 1);
        assert!(!pool.can_admit(&prompt, "t", 20, 96, false));

        drop(a); // prefix page becomes reclaimable, reservation returns
        assert_eq!(pool.usage().reclaimable, 1);
        assert!(pool.can_admit(&prompt, "t", 20, 96, false));

        // a different-prefix session drawing its full reservation must
        // evict the reclaimable page to satisfy the last allocation
        let other: Vec<i32> = (0..20).map(|i| 90 + i).collect();
        let mut b = PagedKv::admit(&pool, &other, "t", 20, 96, false).unwrap();
        assert!(!b.prefill_cached());
        b.install_full(&kf, &kf, 0, 20).unwrap();
        let kw = vec![1.0f32; c.layers * 4 * c.d_kv];
        // row 25 CoWs b's own registered prompt page; 40/72 take fresh
        // pages — the last allocation exhausts the slab and evicts
        b.commit_window_rows(&kw, &kw, 4, &[(0, 25), (1, 40), (2, 72)])
            .unwrap();
        assert!(pool.stats().cow_copies >= 1);
        assert!(pool.stats().evictions >= 1);
        // the evicted hash is gone: a third same-as-A session misses
        drop(b);
        let d = PagedKv::admit(&pool, &prompt, "t", 20, 96, false).unwrap();
        assert!(!d.prefill_cached());
    }

    #[test]
    fn bidirectional_partial_prefix_adopts_nothing() {
        let c = cfg(32);
        let pool = SharedKvPool::new(c.clone());
        let kf = full(&c, 8.0);
        // 40-token prompt: slot 0 full, slot 1 partial
        let base: Vec<i32> = (0..40).map(|i| 5 + i % 60).collect();
        let mut a =
            PagedKv::admit(&pool, &base, "prefill_xla", 40, 104, false)
                .unwrap();
        a.install_full(&kf, &kf, 0, 40).unwrap();

        // same first page, different tail: a bidirectional prefill's rows
        // depend on the whole prompt, so nothing may be adopted
        let mut tail: Vec<i32> = base[..32].to_vec();
        tail.extend((0..8).map(|i| 70 + i % 9));
        let v = PagedKv::admit(&pool, &tail, "prefill_xla", 40, 104, false)
            .unwrap();
        assert_eq!(v.valid_count(), 0, "partial bidirectional hit adopted");
        assert!(!v.prefill_cached());

        // the full-prompt match still adopts everything
        let w = PagedKv::admit(&pool, &base, "prefill_xla", 40, 104, false)
            .unwrap();
        assert!(w.prefill_cached());
        assert_eq!(w.valid_count(), 40);

        // a causal family shares the matching page individually
        let mut b =
            PagedKv::admit(&pool, &base, "ar_prefill", 40, 104, true)
                .unwrap();
        b.install_full(&kf, &kf, 0, 40).unwrap();
        let d = PagedKv::admit(&pool, &tail, "ar_prefill", 40, 104, true)
            .unwrap();
        assert_eq!(d.valid_count(), 32, "causal prefix shares per page");
        assert!(!d.prefill_cached());
    }

    #[test]
    fn worst_case_pages_matches_requirements() {
        let pool = SharedKvPool::new(cfg(4));
        // page-aligned span fills the pool exactly: admittable
        assert_eq!(pool.worst_case_pages(32, 128), 4);
        // partial prefix adds the CoW margin
        assert_eq!(pool.worst_case_pages(20, 96), 4);
        assert_eq!(pool.worst_case_pages(0, 96), 3);
    }

    #[test]
    fn page_args_track_table_identity_and_stamps() {
        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let mut v = PagedKv::admit(&pool, &[], "t", 0, 128, false).unwrap();
        let kf = full(&c, 7.0);
        v.install_full(&kf, &kf, 0, 40).unwrap();

        let a1 = v.page_args().expect("paged views expose a page table");
        assert_eq!(a1.slots, vec![0, 1]);
        assert_eq!(a1.page_rows, c.page_rows);
        assert_eq!(a1.valid_total(), v.valid_count());
        assert!(a1.stamps.iter().all(|&s| s > 0), "stamps are tracked");

        // a commit into slot 1 bumps only that page's stamp; identities
        // are stable (no CoW: the pages are private and unregistered)
        let w = 4;
        let kw = vec![5.0f32; c.layers * w * c.d_kv];
        v.commit_window_rows(&kw, &kw, w, &[(0, 33)]).unwrap();
        let a2 = v.page_args().unwrap();
        assert_eq!(a2.ids, a1.ids, "private pages keep their identity");
        assert_eq!(a2.stamps[0], a1.stamps[0], "untouched page unchanged");
        assert!(a2.stamps[1] > a1.stamps[1], "touched page must re-stamp");

        // page visiting agrees with the table description
        let mut seen = Vec::new();
        v.for_each_page(&mut |pg| seen.push((pg.slot, pg.id, pg.stamp)));
        let described: Vec<(usize, u64, u64)> = a2
            .slots
            .iter()
            .zip(a2.ids.iter())
            .zip(a2.stamps.iter())
            .map(|((&s, &i), &t)| (s, i, t))
            .collect();
        assert_eq!(seen, described);
    }

    #[test]
    fn staging_matches_dense_gather_and_reuses_unchanged_pages() {
        use super::super::kv_cache::KvStaging;

        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let mut v = PagedKv::admit(&pool, &[], "t", 0, 128, false).unwrap();
        let kf = full(&c, 9.0);
        v.install_full(&kf, &kf, 0, 40).unwrap();

        let mut st = KvStaging::new();
        st.stage(&v).unwrap();
        assert_eq!(st.k.as_slice(), v.k_dense().as_ref());
        assert_eq!(st.v.as_slice(), v.v_dense().as_ref());
        assert_eq!(st.valid.as_slice(), v.valid_dense().as_ref());
        let s1 = st.stats();
        assert_eq!(s1.pages_copied, 2);

        // unchanged view: every page reuses, zero new bytes staged
        st.stage(&v).unwrap();
        let s2 = st.stats();
        assert_eq!(s2.pages_copied, 2);
        assert_eq!(s2.pages_reused, 2);
        assert_eq!(s2.bytes_copied, s1.bytes_copied);

        // one commit re-stamps one page: exactly one page recopies and
        // the staged image still equals the dense gather bit for bit
        let w = 4;
        let kw = vec![5.0f32; c.layers * w * c.d_kv];
        v.commit_window_rows(&kw, &kw, w, &[(0, 33)]).unwrap();
        st.stage(&v).unwrap();
        let s3 = st.stats();
        assert_eq!(s3.pages_copied, 3, "only the touched page recopies");
        assert_eq!(st.k.as_slice(), v.k_dense().as_ref());
        assert_eq!(st.valid.as_slice(), v.valid_dense().as_ref());

        // a different view with disjoint pages through the same scratch:
        // its pages stage, the previous view's slots are zeroed
        let mut u = PagedKv::admit(&pool, &[], "t", 0, 128, false).unwrap();
        u.install_full(&kf, &kf, 64, 80).unwrap();
        st.stage(&u).unwrap();
        assert_eq!(st.k.as_slice(), u.k_dense().as_ref(),
                   "dead slots must zero back to the dense image");
        assert_eq!(st.valid.as_slice(), u.valid_dense().as_ref());
        assert!(st.stats().dead_slots_zeroed >= 2);

        // dense views are read borrow-only, never staged
        let dense = super::super::KvCache::new(c.layers, c.s_max, c.d_kv);
        assert!(st.stage(&dense).is_err());
    }

    #[test]
    fn shared_prompt_pages_reuse_across_interleaved_stagings() {
        use super::super::kv_cache::KvStaging;

        let c = cfg(32);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..32).map(|i| 5 + i % 11).collect();
        let kf = full(&c, 2.0);
        let mut a =
            PagedKv::admit(&pool, &prompt, "t", 32, 96, false).unwrap();
        a.install_full(&kf, &kf, 0, 32).unwrap(); // registers the prefix
        let b = PagedKv::admit(&pool, &prompt, "t", 32, 96, false).unwrap();
        assert!(b.prefill_cached());

        // interleaved staging A, B, A, B: the shared prompt page keeps
        // its (id, stamp) across views, so only first-touch copies
        let mut st = KvStaging::new();
        st.stage(&a).unwrap();
        let after_a = st.stats().pages_copied;
        st.stage(&b).unwrap();
        let s = st.stats();
        assert_eq!(s.pages_copied, after_a,
                   "the shared prompt page must not recopy for B");
        assert!(s.pages_reused >= 1);
        st.stage(&a).unwrap();
        st.stage(&b).unwrap();
        assert_eq!(st.stats().pages_copied, after_a,
                   "steady state stages zero pages for unchanged views");
    }

    #[test]
    fn adoption_reverifies_chain_hash_at_install_time() {
        let c = cfg(16);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..32).map(|i| 5 + i % 9).collect();
        let kf = full(&c, 3.0);
        let mut a =
            PagedKv::admit(&pool, &prompt, "t", 32, 96, false).unwrap();
        a.install_full(&kf, &kf, 0, 32).unwrap(); // registers slot-0 page
        drop(a); // page reclaimable, still indexed

        // simulate a mid-round supersede: the index still maps the chain
        // hash, but the page it points at no longer carries it (as after
        // a recycle re-registered the slot under another prompt)
        {
            let mut p = pool.inner.borrow_mut();
            let pids: Vec<usize> = p.index.values().copied().collect();
            for pid in pids {
                p.pages[pid].hash = None;
            }
        }

        // a full-prefix "hit" must treat the stale mapping as a miss:
        // nothing adopted, no prefill skip, and the index self-heals
        let b = PagedKv::admit(&pool, &prompt, "t", 32, 96, false).unwrap();
        assert!(!b.prefill_cached(),
                "a superseded mapping must never skip the prefill");
        assert_eq!(b.valid_count(), 0, "no stale rows may be adopted");
        assert!(pool.stats().stale_hash_skips >= 1);
        assert!(pool.inner.borrow().index.is_empty(),
                "stale mappings are removed at detection");
    }

    #[test]
    fn required_pages_for_credits_indexed_prefixes() {
        let c = cfg(8);
        let pool = SharedKvPool::new(c.clone());
        let prompt: Vec<i32> = (0..64).map(|i| 5 + i % 13).collect();
        // cold pool: the probe equals the no-sharing worst case
        assert_eq!(pool.required_pages_for(&prompt, "t", 64, 128, false),
                   pool.worst_case_pages(64, 128));

        let kf = full(&c, 1.0);
        let mut a =
            PagedKv::admit(&pool, &prompt, "t", 64, 128, false).unwrap();
        a.install_full(&kf, &kf, 0, 64).unwrap(); // registers 2 pages
        // warm + live: both prefix pages are credited
        assert_eq!(pool.required_pages_for(&prompt, "t", 64, 128, false),
                   pool.worst_case_pages(64, 128) - 2);
        // reclaimable pages still draw capacity when adopted: after the
        // registrant retires the probe returns to the worst case
        drop(a);
        assert_eq!(pool.required_pages_for(&prompt, "t", 64, 128, false),
                   pool.worst_case_pages(64, 128));
    }

    #[test]
    fn invalidate_updates_counts_and_generations() {
        let c = cfg(8);
        let pool = SharedKvPool::new(c.clone());
        let mut v = PagedKv::admit(&pool, &[], "t", 0, 128, false).unwrap();
        let kf = full(&c, 6.0);
        v.install_full(&kf, &kf, 0, 80).unwrap();
        assert_eq!(v.valid_count(), 80);
        v.invalidate_from(50).unwrap();
        assert_eq!(v.valid_count(), 50);
        assert!(v.is_valid(49) && !v.is_valid(50));
        // invalidated pages are stale again: refresh rewrites them
        let before = pool.stats().pages_refreshed;
        v.install_full(&kf, &kf, 0, 80).unwrap();
        let s = pool.stats();
        // slot 0 (rows 0..32) untouched -> skipped; slots 1,2 rewritten
        assert_eq!(s.pages_refreshed, before + 2);
        assert_eq!(v.valid_count(), 80);
    }
}
