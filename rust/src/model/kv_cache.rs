//! Host-side mirror of the block-approximate KV cache (paper §3.2).
//!
//! Layout matches the AOT executables: k/v are [L, S_max, H*Dh] row-major,
//! `valid` marks which cache rows the decode window may attend to. Cache
//! entries are *approximate*: a row is computed under whatever view of the
//! sequence existed when it was produced, and the KV-refresh mechanism
//! (a full `prefill` forward) rewrites all rows with the current view.

#[derive(Clone)]
pub struct KvCache {
    pub layers: usize,
    pub seq: usize,
    pub d_kv: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub valid: Vec<f32>,
}

impl KvCache {
    pub fn new(layers: usize, seq: usize, d_kv: usize) -> KvCache {
        KvCache {
            layers,
            seq,
            d_kv,
            k: vec![0.0; layers * seq * d_kv],
            v: vec![0.0; layers * seq * d_kv],
            valid: vec![0.0; seq],
        }
    }

    pub fn clear(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.valid.fill(0.0);
    }

    #[inline]
    fn row(&self, layer: usize, pos: usize) -> usize {
        (layer * self.seq + pos) * self.d_kv
    }

    /// Number of valid cache rows.
    pub fn valid_count(&self) -> usize {
        self.valid.iter().filter(|&&x| x > 0.0).count()
    }

    /// Install rows from a full-sequence forward (`prefill` output, shape
    /// [L, S, d_kv]) for positions `pos0..pos1`, marking them valid.
    /// This is both prompt prefill and the KV-refresh path.
    pub fn install_full(&mut self, k_full: &[f32], v_full: &[f32],
                        pos0: usize, pos1: usize) {
        debug_assert_eq!(k_full.len(), self.k.len());
        let d = self.d_kv;
        for l in 0..self.layers {
            let a = self.row(l, pos0);
            let b = self.row(l, pos1);
            self.k[a..b].copy_from_slice(&k_full[a..b]);
            self.v[a..b].copy_from_slice(&v_full[a..b]);
        }
        let _ = d;
        for p in pos0..pos1 {
            self.valid[p] = 1.0;
        }
    }

    /// Commit window rows (decode output k_win/v_win, shape [L, W, d_kv])
    /// into the cache: window offset `off` -> absolute position `pos`.
    pub fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32],
                              w: usize, pairs: &[(usize, usize)]) {
        let d = self.d_kv;
        debug_assert_eq!(k_win.len(), self.layers * w * d);
        for l in 0..self.layers {
            for &(off, pos) in pairs {
                debug_assert!(off < w && pos < self.seq);
                let src = (l * w + off) * d;
                let dst = self.row(l, pos);
                self.k[dst..dst + d].copy_from_slice(&k_win[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v_win[src..src + d]);
            }
        }
        for &(_, pos) in pairs {
            self.valid[pos] = 1.0;
        }
    }

    /// Invalidate rows at and after `pos` (used when re-planning).
    pub fn invalidate_from(&mut self, pos: usize) {
        for p in pos..self.seq {
            self.valid[p] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_commit() {
        let (l, s, d) = (2, 8, 3);
        let mut c = KvCache::new(l, s, d);
        let full: Vec<f32> = (0..l * s * d).map(|i| i as f32).collect();
        c.install_full(&full, &full, 0, 4);
        assert_eq!(c.valid_count(), 4);
        assert_eq!(c.k[0..3], full[0..3]);
        // commit window rows: window of 2, offset 1 -> pos 5
        let w = 2;
        let kwin: Vec<f32> = (0..l * w * d).map(|i| 100.0 + i as f32).collect();
        c.commit_window_rows(&kwin, &kwin, w, &[(1, 5)]);
        assert_eq!(c.valid_count(), 5);
        // layer 0, pos 5 row == kwin layer 0, off 1
        assert_eq!(c.k[(0 * s + 5) * d..(0 * s + 5) * d + 3],
                   kwin[(0 * w + 1) * d..(0 * w + 1) * d + 3]);
        // layer 1 row too
        assert_eq!(c.k[(1 * s + 5) * d..(1 * s + 5) * d + 3],
                   kwin[(1 * w + 1) * d..(1 * w + 1) * d + 3]);

        c.invalidate_from(4);
        assert_eq!(c.valid_count(), 4);
    }
}
