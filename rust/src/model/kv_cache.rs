//! KV-cache storage interfaces plus the dense per-session implementation.
//!
//! The decode layer reads and writes caches only through [`KvView`], so
//! two storage backends coexist behind one API:
//!
//!   * [`KvCache`] — the original dense `[L, S_max, d_kv]` mirror, the
//!     reference ("dense baseline") implementation; every row is
//!     allocated up front regardless of how many are live.
//!   * [`crate::model::kv_pool::PagedKv`] — a page-table view into the
//!     shared [`crate::model::kv_pool::SharedKvPool`], where memory
//!     scales with live tokens and same-prefix sessions share
//!     already-prefilled pages copy-on-write.
//!
//! Layout matches the AOT executables: k/v are `[L, S_max, d_kv]`
//! row-major, `valid` marks which cache rows the decode window may attend
//! to. Cache entries are *approximate*: a row is computed under whatever
//! view of the sequence existed when it was produced, and the KV-refresh
//! mechanism (a full `prefill` forward, paper §3.2) rewrites rows with
//! the current view.

use std::borrow::Cow;

use anyhow::Result;

/// One page of a KV view, exposed borrow-only to backend read paths
/// ([`KvView::for_each_page`]). Layout is the pool's page layout: `k`/`v`
/// are `[L, page_rows, d_kv]` row-major, `valid` is `[page_rows]`.
pub struct KvPage<'a> {
    /// Page slot: this page covers sequence rows
    /// `slot * page_rows .. slot * page_rows + rows`.
    pub slot: usize,
    /// Sequence rows the page covers (`page_rows`, clipped at capacity).
    pub rows: usize,
    /// Valid rows in this page (maintained counter).
    pub valid_rows: usize,
    /// Stable physical-page identity, unique across pools for the
    /// lifetime of the process (a recycled page gets a fresh id).
    pub id: u64,
    /// Content version: bumped whenever the page's k/v/valid rows change.
    /// `0` means untracked — readers must treat the content as changed.
    pub stamp: u64,
    /// `[L, page_rows, d_kv]` key rows.
    pub k: &'a [f32],
    /// `[L, page_rows, d_kv]` value rows.
    pub v: &'a [f32],
    /// `[page_rows]` row-validity mask.
    pub valid: &'a [f32],
}

/// Page-table description of a paged view: which slots hold live pages,
/// each page's identity/version and valid-row count — the argument form
/// a future paged-attention executable consumes directly, and the
/// introspection/telemetry view today. Hot-path reads go through
/// [`KvView::page_rows`] (branch) + [`KvView::for_each_page`] (borrow-
/// only visit) so no table description is allocated per forward.
#[derive(Debug, Clone, Default)]
pub struct KvPageArgs {
    /// Rows per page of the view's layout.
    pub page_rows: usize,
    /// Slots holding live pages, ascending.
    pub slots: Vec<usize>,
    /// Physical page ids, parallel to `slots`.
    pub ids: Vec<u64>,
    /// Content stamps, parallel to `slots`.
    pub stamps: Vec<u64>,
    /// Per-page valid-row counts, parallel to `slots`.
    pub valid_rows: Vec<usize>,
}

impl KvPageArgs {
    /// Total valid rows across the table — the O(live-pages) analog of a
    /// dense `[S]` mask scan.
    pub fn valid_total(&self) -> usize {
        self.valid_rows.iter().sum()
    }
}

/// Uniform cache interface shared by the dense [`KvCache`] and the paged
/// [`crate::model::kv_pool::PagedKv`] view. The mutating entry points
/// return `Result` because a paged view can exhaust the pool's page
/// budget mid-operation; the dense implementation never fails.
///
/// Backends read the cache through two paths:
///
///   * the `*_dense` getters hand the cache over as one contiguous
///     buffer — zero-cost borrows for dense storage, a full gather for a
///     paged view (kept as the reference read path, off the hot path);
///   * the paged-native path — `page_args` + `for_each_page` — exposes
///     the live pages in place, O(live-pages) per read. The simulated
///     backend fingerprints the cache through it, and the PJRT engine
///     stages only changed pages into a reusable scratch
///     ([`KvStaging`]) instead of re-gathering `[L, S_max, d_kv]` per
///     forward.
pub trait KvView {
    fn layers(&self) -> usize;

    /// Sequence-row capacity (`s_max`).
    fn capacity(&self) -> usize;

    fn d_kv(&self) -> usize;

    /// Number of valid rows. O(1) everywhere: both implementations keep
    /// a maintained counter (the simulated backend mixes this into every
    /// windowed forward, so it is on the hot path).
    fn valid_count(&self) -> usize;

    fn is_valid(&self, pos: usize) -> bool;

    /// Dense `[L, S, d_kv]` key rows (borrowed for dense storage,
    /// gathered for paged storage).
    fn k_dense(&self) -> Cow<'_, [f32]>;

    /// Dense `[L, S, d_kv]` value rows.
    fn v_dense(&self) -> Cow<'_, [f32]>;

    /// Dense `[S]` row-validity mask.
    fn valid_dense(&self) -> Cow<'_, [f32]>;

    /// Rows per page of the paged layout, `None` for dense storage — the
    /// allocation-free "is this view paged?" probe backends use before
    /// committing to the paged read path (the hot path must not build a
    /// [`KvPageArgs`] just to branch).
    fn page_rows(&self) -> Option<usize> {
        None
    }

    /// Page-table description (owned, allocating) for telemetry, tests
    /// and future on-device page-table arguments; `None` for dense
    /// storage (read it borrow-only via the `*_dense` getters). Hot
    /// paths branch on [`KvView::page_rows`] and read via
    /// [`KvView::for_each_page`] instead.
    fn page_args(&self) -> Option<KvPageArgs> {
        None
    }

    /// Visit the live pages in ascending slot order (the paged-native
    /// read path). The default presents the whole dense buffer as one
    /// untracked pseudo-page (`stamp == 0`, borrow-only for dense
    /// storage); the paged view overrides with its table, O(live-pages).
    fn for_each_page(&self, f: &mut dyn FnMut(KvPage<'_>)) {
        let (k, v, valid) = (self.k_dense(), self.v_dense(),
                             self.valid_dense());
        f(KvPage {
            slot: 0,
            rows: self.capacity(),
            valid_rows: self.valid_count(),
            id: u64::MAX,
            stamp: 0,
            k: k.as_ref(),
            v: v.as_ref(),
            valid: valid.as_ref(),
        });
    }

    /// Install rows from a full-sequence forward (`prefill` output, shape
    /// `[L, S, d_kv]`) for positions `pos0..pos1`, marking them valid.
    /// This is both prompt prefill and the KV-refresh path; the paged
    /// implementation makes the refresh *incremental* by skipping pages
    /// whose rows are already current (see `kv_pool`).
    fn install_full(&mut self, k_full: &[f32], v_full: &[f32], pos0: usize,
                    pos1: usize) -> Result<()>;

    /// Commit window rows (decode output k_win/v_win, shape
    /// `[L, W, d_kv]`) into the cache: window offset `off` -> absolute
    /// position `pos`.
    fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32], w: usize,
                          pairs: &[(usize, usize)]) -> Result<()>;

    /// Invalidate rows at and after `pos` (used when re-planning).
    fn invalidate_from(&mut self, pos: usize) -> Result<()>;

    /// True when every row `0..rows` is already valid — the prefix-
    /// adoption probe behind prompt-prefill skipping. `rows == 0` is
    /// defined as *not* ready so callers cannot accidentally "skip" a
    /// prefill that installs nothing.
    fn prefix_ready(&self, rows: usize) -> bool {
        rows > 0 && (0..rows).all(|p| self.is_valid(p))
    }

    /// Bookkeeping hook invoked when a session skipped its prompt-prefill
    /// forward thanks to a prefix-cache hit. No-op on dense caches.
    fn note_prefill_skipped(&mut self) {}

    /// Preemption spill: release every pool-backed page this view holds
    /// (prefix-indexed pages stay adoptable in the pool's reclaimable
    /// set) and remember which rows were valid so they can be rebuilt on
    /// resume. Returns the number of pages released, `None` when the
    /// view has nothing to spill (dense storage, or already spilled).
    fn spill(&mut self) -> Option<usize> {
        None
    }

    /// True between a `spill` and its successful `readmit` — the view
    /// holds no rows and must not be read or written.
    fn spilled(&self) -> bool {
        false
    }

    /// Re-admit a spilled view against its pool: re-adopt whatever the
    /// prefix index still holds and re-reserve the span. After this,
    /// [`KvView::take_spill_restore_runs`] lists the previously-valid
    /// rows that did not come back by adoption and need their content
    /// re-installed. No-op for dense storage.
    fn readmit(&mut self, _prompt_tokens: &[i32]) -> Result<()> {
        Ok(())
    }

    /// Row runs (`lo..hi`) that were valid at spill time and still need
    /// an `install_full` after `readmit`. Draining: returns each run
    /// once. Empty for dense storage.
    fn take_spill_restore_runs(&mut self) -> Vec<(usize, usize)> {
        Vec::new()
    }
}

/// Dense host-side mirror of the block-approximate KV cache: one
/// full-capacity buffer per session. Kept as the reference baseline the
/// paged pool is pinned against (`tests/kv_pool.rs`) and for
/// strategy-private caches (the speculative draft cache).
#[derive(Clone)]
pub struct KvCache {
    pub layers: usize,
    pub seq: usize,
    pub d_kv: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    valid: Vec<f32>,
    /// Maintained count of valid rows (O(1) `valid_count`; the old O(S)
    /// scan ran once per simulated forward).
    valid_rows: usize,
}

impl KvCache {
    pub fn new(layers: usize, seq: usize, d_kv: usize) -> KvCache {
        KvCache {
            layers,
            seq,
            d_kv,
            k: vec![0.0; layers * seq * d_kv],
            v: vec![0.0; layers * seq * d_kv],
            valid: vec![0.0; seq],
            valid_rows: 0,
        }
    }

    pub fn clear(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.valid.fill(0.0);
        self.valid_rows = 0;
    }

    #[inline]
    fn row(&self, layer: usize, pos: usize) -> usize {
        (layer * self.seq + pos) * self.d_kv
    }

    /// Number of valid cache rows (maintained counter).
    pub fn valid_count(&self) -> usize {
        self.valid_rows
    }

    pub fn is_valid(&self, pos: usize) -> bool {
        self.valid[pos] > 0.0
    }

    /// Row-validity mask as a dense slice (executable input layout).
    pub fn valid_slice(&self) -> &[f32] {
        &self.valid
    }

    /// Mark one row valid without writing its k/v content (test and
    /// tooling hook; keeps the maintained counter consistent, which
    /// direct field writes would not).
    pub fn mark_valid(&mut self, pos: usize) {
        if self.valid[pos] == 0.0 {
            self.valid[pos] = 1.0;
            self.valid_rows += 1;
        }
    }

    /// Install rows from a full-sequence forward (`prefill` output, shape
    /// [L, S, d_kv]) for positions `pos0..pos1`, marking them valid.
    /// This is both prompt prefill and the KV-refresh path.
    pub fn install_full(&mut self, k_full: &[f32], v_full: &[f32],
                        pos0: usize, pos1: usize) {
        debug_assert_eq!(k_full.len(), self.k.len());
        for l in 0..self.layers {
            let a = self.row(l, pos0);
            let b = self.row(l, pos1);
            self.k[a..b].copy_from_slice(&k_full[a..b]);
            self.v[a..b].copy_from_slice(&v_full[a..b]);
        }
        for p in pos0..pos1 {
            self.mark_valid(p);
        }
    }

    /// Commit window rows (decode output k_win/v_win, shape [L, W, d_kv])
    /// into the cache: window offset `off` -> absolute position `pos`.
    pub fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32],
                              w: usize, pairs: &[(usize, usize)]) {
        let d = self.d_kv;
        debug_assert_eq!(k_win.len(), self.layers * w * d);
        for l in 0..self.layers {
            for &(off, pos) in pairs {
                debug_assert!(off < w && pos < self.seq);
                let src = (l * w + off) * d;
                let dst = self.row(l, pos);
                self.k[dst..dst + d].copy_from_slice(&k_win[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v_win[src..src + d]);
            }
        }
        for &(_, pos) in pairs {
            self.mark_valid(pos);
        }
    }

    /// Invalidate rows at and after `pos` (used when re-planning).
    pub fn invalidate_from(&mut self, pos: usize) {
        for p in pos..self.seq {
            if self.valid[p] > 0.0 {
                self.valid[p] = 0.0;
                self.valid_rows -= 1;
            }
        }
    }
}

impl KvView for KvCache {
    fn layers(&self) -> usize {
        self.layers
    }

    fn capacity(&self) -> usize {
        self.seq
    }

    fn d_kv(&self) -> usize {
        self.d_kv
    }

    fn valid_count(&self) -> usize {
        KvCache::valid_count(self)
    }

    fn is_valid(&self, pos: usize) -> bool {
        KvCache::is_valid(self, pos)
    }

    fn k_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.k)
    }

    fn v_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.v)
    }

    fn valid_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.valid)
    }

    fn install_full(&mut self, k_full: &[f32], v_full: &[f32], pos0: usize,
                    pos1: usize) -> Result<()> {
        KvCache::install_full(self, k_full, v_full, pos0, pos1);
        Ok(())
    }

    fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32], w: usize,
                          pairs: &[(usize, usize)]) -> Result<()> {
        KvCache::commit_window_rows(self, k_win, v_win, w, pairs);
        Ok(())
    }

    fn invalidate_from(&mut self, pos: usize) -> Result<()> {
        KvCache::invalidate_from(self, pos);
        Ok(())
    }
}

// ---------------------------------------------------------------- staging

/// Cumulative counters of one [`KvStaging`] scratch (bench + stats).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvStageStats {
    /// `stage` calls taken (one per staged windowed forward).
    pub stage_calls: u64,
    /// Pages whose content was copied into the scratch.
    pub pages_copied: u64,
    /// Pages skipped because the scratch already held that exact
    /// (id, stamp) content at that slot — the reuse win.
    pub pages_reused: u64,
    /// Slots zeroed because the staged view no longer holds a page there.
    pub dead_slots_zeroed: u64,
    /// Bytes written into the scratch (copies + dead-slot zeroing). The
    /// dense-gather equivalent is `stage_calls * dense_bytes` where
    /// `dense_bytes = (2 * L * S_max * d_kv + S_max) * 4`.
    pub bytes_copied: u64,
}

/// Reusable bounded staging scratch for paged KV views: the engine-side
/// replacement for the per-forward `k_dense()` gather. One scratch is
/// reused across rounds and sessions; `stage` brings it to the exact
/// dense image of the given view (`k`/`v`/`valid` bit-identical to
/// `k_dense()`/`v_dense()`/`valid_dense()`), copying **only** pages whose
/// (identity, content stamp) differ from what the scratch already holds
/// at that slot. Steady state is allocation-free: buffers are sized once
/// per geometry, and the round marker avoids per-call bookkeeping
/// allocations.
///
/// Shared prompt pages (CoW-adopted, never written) keep their identity
/// and stamp across sessions, so interleaved same-prefix sessions
/// re-stage only their private tail pages — the staged-bytes bar in
/// `benches/kv_pool.rs` holds the >= 4x reduction vs. the dense gather
/// at 8 concurrent shared-prefix sessions.
#[derive(Default)]
pub struct KvStaging {
    layers: usize,
    s_max: usize,
    d_kv: usize,
    page_rows: usize,
    /// `[L, S_max, d_kv]` staged keys (dense image of the last view).
    pub k: Vec<f32>,
    /// `[L, S_max, d_kv]` staged values.
    pub v: Vec<f32>,
    /// `[S_max]` staged row-validity mask.
    pub valid: Vec<f32>,
    /// Per-slot (page id, content stamp) the scratch currently holds.
    slots: Vec<Option<(u64, u64)>>,
    /// Round marker per slot (`== round` -> seen by the current stage).
    seen: Vec<u64>,
    round: u64,
    stats: KvStageStats,
}

impl KvStaging {
    pub fn new() -> KvStaging {
        KvStaging::default()
    }

    pub fn stats(&self) -> KvStageStats {
        self.stats
    }

    /// (Re)size for a view geometry; a change resets the scratch (full
    /// zero + forgotten slot state). No-op on the steady-state hot path.
    fn ensure_geometry(&mut self, layers: usize, s_max: usize, d_kv: usize,
                       page_rows: usize) {
        if (self.layers, self.s_max, self.d_kv, self.page_rows)
            == (layers, s_max, d_kv, page_rows)
        {
            return;
        }
        self.layers = layers;
        self.s_max = s_max;
        self.d_kv = d_kv;
        self.page_rows = page_rows;
        let n = layers * s_max * d_kv;
        self.k.clear();
        self.k.resize(n, 0.0);
        self.v.clear();
        self.v.resize(n, 0.0);
        self.valid.clear();
        self.valid.resize(s_max, 0.0);
        let nslots = if page_rows == 0 { 0 } else {
            s_max.div_ceil(page_rows)
        };
        self.slots.clear();
        self.slots.resize(nslots, None);
        self.seen.clear();
        self.seen.resize(nslots, 0);
        self.round = 0;
    }

    /// Bring the scratch to the dense image of `cache` (a paged view:
    /// `page_rows` must be `Some`). After this returns, `self.k/v/valid`
    /// are bit-identical to the view's dense getters, at the cost of
    /// copying only the pages that changed since the scratch last held
    /// them. Rows of slots with no live page are zero (`valid` == 0
    /// masks them for the executable; k/v of a freshly-dead slot are
    /// zeroed too so the image stays exactly the dense gather).
    pub fn stage(&mut self, cache: &dyn KvView) -> Result<()> {
        let Some(page_rows) = cache.page_rows() else {
            anyhow::bail!("kv staging: view has no page table (dense \
                           views are read borrow-only)");
        };
        self.ensure_geometry(cache.layers(), cache.capacity(),
                             cache.d_kv(), page_rows);
        self.stats.stage_calls += 1;
        self.round += 1;
        let round = self.round;
        let (l, s, d, r) = (self.layers, self.s_max, self.d_kv,
                            self.page_rows);
        // split-borrow the buffers so the visitor closure can write them
        // while `self`'s bookkeeping fields stay separately borrowed
        let (kbuf, vbuf, valid_buf) =
            (&mut self.k, &mut self.v, &mut self.valid);
        let (slots, seen, stats) =
            (&mut self.slots, &mut self.seen, &mut self.stats);
        cache.for_each_page(&mut |pg| {
            let slot = pg.slot;
            if slot >= slots.len() {
                return; // defensive: out-of-range slot
            }
            seen[slot] = round;
            if pg.stamp != 0 && slots[slot] == Some((pg.id, pg.stamp)) {
                stats.pages_reused += 1;
                return; // identical content already staged here
            }
            let rows = pg.rows.min(s - slot * r);
            for layer in 0..l {
                let src = layer * r * d;
                let dst = (layer * s + slot * r) * d;
                kbuf[dst..dst + rows * d]
                    .copy_from_slice(&pg.k[src..src + rows * d]);
                vbuf[dst..dst + rows * d]
                    .copy_from_slice(&pg.v[src..src + rows * d]);
            }
            valid_buf[slot * r..slot * r + rows]
                .copy_from_slice(&pg.valid[..rows]);
            slots[slot] = Some((pg.id, pg.stamp));
            stats.pages_copied += 1;
            stats.bytes_copied += ((2 * l * d + 1) * rows * 4) as u64;
        });
        // zero slots the previous image held but this view does not
        for slot in 0..self.slots.len() {
            if self.seen[slot] == round || self.slots[slot].is_none() {
                continue;
            }
            let rows = r.min(s - slot * r);
            for layer in 0..l {
                let dst = (layer * s + slot * r) * d;
                self.k[dst..dst + rows * d].fill(0.0);
                self.v[dst..dst + rows * d].fill(0.0);
            }
            self.valid[slot * r..slot * r + rows].fill(0.0);
            self.slots[slot] = None;
            self.stats.dead_slots_zeroed += 1;
            self.stats.bytes_copied += ((2 * l * d + 1) * rows * 4) as u64;
        }
        Ok(())
    }

    /// Bytes one dense `[L, S_max, d_kv]` gather of the current geometry
    /// costs — the per-forward baseline `stage` is measured against.
    pub fn dense_gather_bytes(&self) -> u64 {
        ((2 * self.layers * self.d_kv + 1) * self.s_max * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_commit() {
        let (l, s, d) = (2, 8, 3);
        let mut c = KvCache::new(l, s, d);
        let full: Vec<f32> = (0..l * s * d).map(|i| i as f32).collect();
        c.install_full(&full, &full, 0, 4);
        assert_eq!(c.valid_count(), 4);
        assert_eq!(c.k[0..3], full[0..3]);
        // commit window rows: window of 2, offset 1 -> pos 5
        let w = 2;
        let kwin: Vec<f32> = (0..l * w * d).map(|i| 100.0 + i as f32).collect();
        c.commit_window_rows(&kwin, &kwin, w, &[(1, 5)]);
        assert_eq!(c.valid_count(), 5);
        // layer 0, pos 5 row == kwin layer 0, off 1
        assert_eq!(c.k[(0 * s + 5) * d..(0 * s + 5) * d + 3],
                   kwin[(0 * w + 1) * d..(0 * w + 1) * d + 3]);
        // layer 1 row too
        assert_eq!(c.k[(1 * s + 5) * d..(1 * s + 5) * d + 3],
                   kwin[(1 * w + 1) * d..(1 * w + 1) * d + 3]);

        c.invalidate_from(4);
        assert_eq!(c.valid_count(), 4);
    }

    #[test]
    fn valid_counter_stays_consistent() {
        let mut c = KvCache::new(1, 6, 2);
        c.mark_valid(2);
        c.mark_valid(2); // idempotent
        assert_eq!(c.valid_count(), 1);
        assert!(c.is_valid(2) && !c.is_valid(3));
        let full = vec![0.5f32; 12]; // [L=1, S=6, d=2]
        // overlapping install must not double count
        c.install_full(&full, &full, 1, 4);
        assert_eq!(c.valid_count(), 3);
        c.invalidate_from(0);
        assert_eq!(c.valid_count(), 0);
        c.invalidate_from(0); // idempotent
        assert_eq!(c.valid_count(), 0);
    }

    #[test]
    fn dense_views_read_as_one_untracked_pseudo_page() {
        let mut c = KvCache::new(2, 8, 3);
        let full: Vec<f32> = (0..2 * 8 * 3).map(|i| i as f32).collect();
        c.install_full(&full, &full, 0, 5);
        assert!(c.page_args().is_none(), "dense views have no page table");
        let mut pages = 0usize;
        let mut rows = 0usize;
        c.for_each_page(&mut |pg| {
            pages += 1;
            rows += pg.valid_rows;
            assert_eq!(pg.slot, 0);
            assert_eq!(pg.rows, 8);
            assert_eq!(pg.stamp, 0, "dense pseudo-page is untracked");
            assert_eq!(pg.k.len(), 2 * 8 * 3);
            assert_eq!(pg.valid[4], 1.0);
            assert_eq!(pg.valid[5], 0.0);
        });
        assert_eq!(pages, 1);
        assert_eq!(rows, c.valid_count());
    }

    #[test]
    fn view_trait_matches_inherent_api() {
        let mut c = KvCache::new(1, 4, 2);
        let full = vec![1.0f32; 8];
        {
            let view: &mut dyn KvView = &mut c;
            view.install_full(&full, &full, 0, 2).unwrap();
            assert_eq!(view.valid_count(), 2);
            assert!(view.prefix_ready(2));
            assert!(!view.prefix_ready(3));
            assert!(!view.prefix_ready(0), "empty prefix is never ready");
            assert_eq!(view.k_dense().len(), 8);
            assert_eq!(view.valid_dense()[..2], [1.0, 1.0]);
        }
        assert_eq!(c.valid_count(), 2);
    }
}
