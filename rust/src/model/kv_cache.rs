//! KV-cache storage interfaces plus the dense per-session implementation.
//!
//! The decode layer reads and writes caches only through [`KvView`], so
//! two storage backends coexist behind one API:
//!
//!   * [`KvCache`] — the original dense `[L, S_max, d_kv]` mirror, the
//!     reference ("dense baseline") implementation; every row is
//!     allocated up front regardless of how many are live.
//!   * [`crate::model::kv_pool::PagedKv`] — a page-table view into the
//!     shared [`crate::model::kv_pool::SharedKvPool`], where memory
//!     scales with live tokens and same-prefix sessions share
//!     already-prefilled pages copy-on-write.
//!
//! Layout matches the AOT executables: k/v are `[L, S_max, d_kv]`
//! row-major, `valid` marks which cache rows the decode window may attend
//! to. Cache entries are *approximate*: a row is computed under whatever
//! view of the sequence existed when it was produced, and the KV-refresh
//! mechanism (a full `prefill` forward, paper §3.2) rewrites rows with
//! the current view.

use std::borrow::Cow;

use anyhow::Result;

/// Uniform cache interface shared by the dense [`KvCache`] and the paged
/// [`crate::model::kv_pool::PagedKv`] view. The mutating entry points
/// return `Result` because a paged view can exhaust the pool's page
/// budget mid-operation; the dense implementation never fails.
///
/// The `*_dense` getters exist for backends that feed the cache to an
/// executable as one contiguous buffer (the PJRT engine): the dense cache
/// borrows its storage at zero cost, the paged view gathers its pages
/// into an owned staging buffer (until a paged-attention executable that
/// consumes page tables directly lands in the AOT layer).
pub trait KvView {
    fn layers(&self) -> usize;

    /// Sequence-row capacity (`s_max`).
    fn capacity(&self) -> usize;

    fn d_kv(&self) -> usize;

    /// Number of valid rows. O(1) everywhere: both implementations keep
    /// a maintained counter (the simulated backend mixes this into every
    /// windowed forward, so it is on the hot path).
    fn valid_count(&self) -> usize;

    fn is_valid(&self, pos: usize) -> bool;

    /// Dense `[L, S, d_kv]` key rows (borrowed for dense storage,
    /// gathered for paged storage).
    fn k_dense(&self) -> Cow<'_, [f32]>;

    /// Dense `[L, S, d_kv]` value rows.
    fn v_dense(&self) -> Cow<'_, [f32]>;

    /// Dense `[S]` row-validity mask.
    fn valid_dense(&self) -> Cow<'_, [f32]>;

    /// Install rows from a full-sequence forward (`prefill` output, shape
    /// `[L, S, d_kv]`) for positions `pos0..pos1`, marking them valid.
    /// This is both prompt prefill and the KV-refresh path; the paged
    /// implementation makes the refresh *incremental* by skipping pages
    /// whose rows are already current (see `kv_pool`).
    fn install_full(&mut self, k_full: &[f32], v_full: &[f32], pos0: usize,
                    pos1: usize) -> Result<()>;

    /// Commit window rows (decode output k_win/v_win, shape
    /// `[L, W, d_kv]`) into the cache: window offset `off` -> absolute
    /// position `pos`.
    fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32], w: usize,
                          pairs: &[(usize, usize)]) -> Result<()>;

    /// Invalidate rows at and after `pos` (used when re-planning).
    fn invalidate_from(&mut self, pos: usize) -> Result<()>;

    /// True when every row `0..rows` is already valid — the prefix-
    /// adoption probe behind prompt-prefill skipping. `rows == 0` is
    /// defined as *not* ready so callers cannot accidentally "skip" a
    /// prefill that installs nothing.
    fn prefix_ready(&self, rows: usize) -> bool {
        rows > 0 && (0..rows).all(|p| self.is_valid(p))
    }

    /// Bookkeeping hook invoked when a session skipped its prompt-prefill
    /// forward thanks to a prefix-cache hit. No-op on dense caches.
    fn note_prefill_skipped(&mut self) {}
}

/// Dense host-side mirror of the block-approximate KV cache: one
/// full-capacity buffer per session. Kept as the reference baseline the
/// paged pool is pinned against (`tests/kv_pool.rs`) and for
/// strategy-private caches (the speculative draft cache).
#[derive(Clone)]
pub struct KvCache {
    pub layers: usize,
    pub seq: usize,
    pub d_kv: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    valid: Vec<f32>,
    /// Maintained count of valid rows (O(1) `valid_count`; the old O(S)
    /// scan ran once per simulated forward).
    valid_rows: usize,
}

impl KvCache {
    pub fn new(layers: usize, seq: usize, d_kv: usize) -> KvCache {
        KvCache {
            layers,
            seq,
            d_kv,
            k: vec![0.0; layers * seq * d_kv],
            v: vec![0.0; layers * seq * d_kv],
            valid: vec![0.0; seq],
            valid_rows: 0,
        }
    }

    pub fn clear(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.valid.fill(0.0);
        self.valid_rows = 0;
    }

    #[inline]
    fn row(&self, layer: usize, pos: usize) -> usize {
        (layer * self.seq + pos) * self.d_kv
    }

    /// Number of valid cache rows (maintained counter).
    pub fn valid_count(&self) -> usize {
        self.valid_rows
    }

    pub fn is_valid(&self, pos: usize) -> bool {
        self.valid[pos] > 0.0
    }

    /// Row-validity mask as a dense slice (executable input layout).
    pub fn valid_slice(&self) -> &[f32] {
        &self.valid
    }

    /// Mark one row valid without writing its k/v content (test and
    /// tooling hook; keeps the maintained counter consistent, which
    /// direct field writes would not).
    pub fn mark_valid(&mut self, pos: usize) {
        if self.valid[pos] == 0.0 {
            self.valid[pos] = 1.0;
            self.valid_rows += 1;
        }
    }

    /// Install rows from a full-sequence forward (`prefill` output, shape
    /// [L, S, d_kv]) for positions `pos0..pos1`, marking them valid.
    /// This is both prompt prefill and the KV-refresh path.
    pub fn install_full(&mut self, k_full: &[f32], v_full: &[f32],
                        pos0: usize, pos1: usize) {
        debug_assert_eq!(k_full.len(), self.k.len());
        for l in 0..self.layers {
            let a = self.row(l, pos0);
            let b = self.row(l, pos1);
            self.k[a..b].copy_from_slice(&k_full[a..b]);
            self.v[a..b].copy_from_slice(&v_full[a..b]);
        }
        for p in pos0..pos1 {
            self.mark_valid(p);
        }
    }

    /// Commit window rows (decode output k_win/v_win, shape [L, W, d_kv])
    /// into the cache: window offset `off` -> absolute position `pos`.
    pub fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32],
                              w: usize, pairs: &[(usize, usize)]) {
        let d = self.d_kv;
        debug_assert_eq!(k_win.len(), self.layers * w * d);
        for l in 0..self.layers {
            for &(off, pos) in pairs {
                debug_assert!(off < w && pos < self.seq);
                let src = (l * w + off) * d;
                let dst = self.row(l, pos);
                self.k[dst..dst + d].copy_from_slice(&k_win[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v_win[src..src + d]);
            }
        }
        for &(_, pos) in pairs {
            self.mark_valid(pos);
        }
    }

    /// Invalidate rows at and after `pos` (used when re-planning).
    pub fn invalidate_from(&mut self, pos: usize) {
        for p in pos..self.seq {
            if self.valid[p] > 0.0 {
                self.valid[p] = 0.0;
                self.valid_rows -= 1;
            }
        }
    }
}

impl KvView for KvCache {
    fn layers(&self) -> usize {
        self.layers
    }

    fn capacity(&self) -> usize {
        self.seq
    }

    fn d_kv(&self) -> usize {
        self.d_kv
    }

    fn valid_count(&self) -> usize {
        KvCache::valid_count(self)
    }

    fn is_valid(&self, pos: usize) -> bool {
        KvCache::is_valid(self, pos)
    }

    fn k_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.k)
    }

    fn v_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.v)
    }

    fn valid_dense(&self) -> Cow<'_, [f32]> {
        Cow::Borrowed(&self.valid)
    }

    fn install_full(&mut self, k_full: &[f32], v_full: &[f32], pos0: usize,
                    pos1: usize) -> Result<()> {
        KvCache::install_full(self, k_full, v_full, pos0, pos1);
        Ok(())
    }

    fn commit_window_rows(&mut self, k_win: &[f32], v_win: &[f32], w: usize,
                          pairs: &[(usize, usize)]) -> Result<()> {
        KvCache::commit_window_rows(self, k_win, v_win, w, pairs);
        Ok(())
    }

    fn invalidate_from(&mut self, pos: usize) -> Result<()> {
        KvCache::invalidate_from(self, pos);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_commit() {
        let (l, s, d) = (2, 8, 3);
        let mut c = KvCache::new(l, s, d);
        let full: Vec<f32> = (0..l * s * d).map(|i| i as f32).collect();
        c.install_full(&full, &full, 0, 4);
        assert_eq!(c.valid_count(), 4);
        assert_eq!(c.k[0..3], full[0..3]);
        // commit window rows: window of 2, offset 1 -> pos 5
        let w = 2;
        let kwin: Vec<f32> = (0..l * w * d).map(|i| 100.0 + i as f32).collect();
        c.commit_window_rows(&kwin, &kwin, w, &[(1, 5)]);
        assert_eq!(c.valid_count(), 5);
        // layer 0, pos 5 row == kwin layer 0, off 1
        assert_eq!(c.k[(0 * s + 5) * d..(0 * s + 5) * d + 3],
                   kwin[(0 * w + 1) * d..(0 * w + 1) * d + 3]);
        // layer 1 row too
        assert_eq!(c.k[(1 * s + 5) * d..(1 * s + 5) * d + 3],
                   kwin[(1 * w + 1) * d..(1 * w + 1) * d + 3]);

        c.invalidate_from(4);
        assert_eq!(c.valid_count(), 4);
    }

    #[test]
    fn valid_counter_stays_consistent() {
        let mut c = KvCache::new(1, 6, 2);
        c.mark_valid(2);
        c.mark_valid(2); // idempotent
        assert_eq!(c.valid_count(), 1);
        assert!(c.is_valid(2) && !c.is_valid(3));
        let full = vec![0.5f32; 12]; // [L=1, S=6, d=2]
        // overlapping install must not double count
        c.install_full(&full, &full, 1, 4);
        assert_eq!(c.valid_count(), 3);
        c.invalidate_from(0);
        assert_eq!(c.valid_count(), 0);
        c.invalidate_from(0); // idempotent
        assert_eq!(c.valid_count(), 0);
    }

    #[test]
    fn view_trait_matches_inherent_api() {
        let mut c = KvCache::new(1, 4, 2);
        let full = vec![1.0f32; 8];
        {
            let view: &mut dyn KvView = &mut c;
            view.install_full(&full, &full, 0, 2).unwrap();
            assert_eq!(view.valid_count(), 2);
            assert!(view.prefix_ready(2));
            assert!(!view.prefix_ready(3));
            assert!(!view.prefix_ready(0), "empty prefix is never ready");
            assert_eq!(view.k_dense().len(), 8);
            assert_eq!(view.valid_dense()[..2], [1.0, 1.0]);
        }
        assert_eq!(c.valid_count(), 2);
    }
}
