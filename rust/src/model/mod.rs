//! Host-side model layer: parameter lifecycle, KV cache mirror, and typed
//! wrappers over the AOT executables.

pub mod exec;
pub mod kv_cache;
pub mod params;

pub use exec::{DecodeOut, PrefillOut, TrainOut, TrajectoryOut};
pub use kv_cache::KvCache;
pub use params::{OptState, ParamStore};
