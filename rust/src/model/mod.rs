//! Host-side model layer: parameter lifecycle, KV cache storage (dense
//! mirror + shared paged pool), and typed wrappers over the AOT
//! executables.

pub mod exec;
pub mod kv_cache;
pub mod kv_pool;
pub mod params;

pub use exec::{DecodeOut, PrefillOut, TrainOut, TrajectoryOut};
pub use kv_cache::{KvCache, KvPage, KvPageArgs, KvStageStats, KvStaging,
                   KvView};
pub use kv_pool::{KvPoolCfg, KvPoolStats, KvPoolUsage, PagedKv,
                  SharedKvPool};
pub use params::{OptState, ParamStore};
