//! Flat parameter store: initialisation per the manifest layout, and a
//! self-describing binary checkpoint format.
//!
//! Python never touches weights at run time — the Rust side owns the full
//! parameter lifecycle (init -> train -> checkpoint -> serve), exchanging
//! only the flat f32 vector with the AOT executables.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::ModelSpec;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"D3LLMCKP";

/// Flat f32 parameter vector + the layout it follows.
#[derive(Clone)]
pub struct ParamStore {
    pub model: String,
    pub data: Vec<f32>,
}

impl ParamStore {
    /// Random initialisation per the manifest layout ("normal" tensors get
    /// N(0, 0.02), "zeros"/"ones" as named).
    pub fn init(spec: &ModelSpec, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0f32; spec.total_params];
        for t in &spec.param_layout {
            let seg = &mut data[t.offset..t.offset + t.size];
            match t.init.as_str() {
                "normal" => {
                    for x in seg.iter_mut() {
                        *x = rng.normal_f32(0.0, 0.02);
                    }
                }
                "ones" => seg.fill(1.0),
                _ => seg.fill(0.0),
            }
        }
        ParamStore { model: spec.name.clone(), data }
    }

    pub fn zeros_like(&self) -> Vec<f32> {
        vec![0.0f32; self.data.len()]
    }

    /// View one named tensor (row-major).
    pub fn tensor<'a>(&'a self, spec: &ModelSpec, name: &str) -> Result<&'a [f32]> {
        let t = spec
            .param_layout
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("unknown tensor `{name}`"))?;
        Ok(&self.data[t.offset..t.offset + t.size])
    }

    // ------------------------------------------------------------ checkpoint

    /// Save: magic | header_len u32 LE | header json | raw f32 LE.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("total", Json::num(self.data.len() as f64)),
            ("dtype", Json::str("f32")),
        ])
        .to_string();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        let bytes: Vec<u8> =
            self.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a d3llm checkpoint");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = json::parse(std::str::from_utf8(&hbytes)?)
            .map_err(|e| anyhow!("{e}"))?;
        let model = header
            .req("model")?
            .as_str()
            .ok_or_else(|| anyhow!("bad header"))?
            .to_string();
        let total = header
            .req("total")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad header"))?;
        let mut raw = Vec::new();
        f.read_to_end(&mut raw)?;
        if raw.len() != total * 4 {
            bail!(
                "checkpoint {path:?}: payload {} bytes, header says {}",
                raw.len(),
                total * 4
            );
        }
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ParamStore { model, data })
    }

    /// Validate compatibility with a model spec before serving/training.
    pub fn check(&self, spec: &ModelSpec) -> Result<()> {
        if self.model != spec.name {
            bail!(
                "checkpoint is for model `{}`, executable wants `{}`",
                self.model,
                spec.name
            );
        }
        if self.data.len() != spec.total_params {
            bail!(
                "checkpoint has {} params, model `{}` wants {}",
                self.data.len(),
                spec.name,
                spec.total_params
            );
        }
        Ok(())
    }
}

/// AdamW optimiser state (first/second moments + step counter), persisted
/// alongside the params so training can resume.
pub struct OptState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl OptState {
    pub fn new(n: usize) -> OptState {
        OptState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}
