//! Table generators — one per paper table (DESIGN.md §5).

use anyhow::Result;

use crate::data::Family;
use crate::decode::{DecodeCfg, SelMetric, Strategy};
use crate::metrics::aup::{aup_from_points, Point, DEFAULT_ALPHA};
use crate::metrics::{A100, H100};
use crate::util::stats::mean_std;

use super::report::{pm, Table};
use super::sweep::{self, MethodSpec, SweepPoint};
use super::BenchCtx;

// ---------------------------------------------------------------- families

pub fn llada_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::new("LLaDA-sim", "llada-teacher", Strategy::Vanilla),
        MethodSpec::new("Fast-dLLM-LLaDA", "llada-teacher",
                        Strategy::FastDllm),
        MethodSpec::new("D2F-LLaDA", "llada-teacher", Strategy::D2f),
        MethodSpec::new("dParallel-LLaDA", "dparallel-llada",
                        Strategy::DParallel),
        MethodSpec::new("d3LLM-LLaDA", "d3llm-llada", Strategy::D3llm),
    ]
}

pub fn dream_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::new("Dream-sim", "dream-teacher", Strategy::Vanilla),
        MethodSpec::new("Fast-dLLM-Dream", "dream-teacher",
                        Strategy::FastDllm),
        MethodSpec::new("Fast-dLLM-v2", "fastdllm-v2", Strategy::FastDllm),
        MethodSpec::new("dParallel-Dream", "dparallel-dream",
                        Strategy::DParallel),
        MethodSpec::new("d3LLM-Dream", "d3llm-dream", Strategy::D3llm),
    ]
}

fn ar_method() -> MethodSpec {
    MethodSpec::new("Qwen-sim (AR)", "ar-sim", Strategy::Ar)
}

const EVAL_TASKS: [Family; 5] = [
    Family::Gsm8k,
    Family::Math,
    Family::Mbpp,
    Family::HumanEval,
    Family::LongGsm8k,
];

/// Family table (Tables 1/2/8 share this): per task x method report
/// headline TPF/Acc and AUP over the threshold sweep, mean ± std across
/// eval-set seeds; y_max for the AUP weight is the best accuracy any
/// method (incl. the AR reference) achieves on that task.
fn family_table(ctx: &BenchCtx, title: &str, stem: &str,
                methods: &[MethodSpec], tasks: &[(Family, bool)])
                -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seeds = ctx.opts.seeds_or(2);
    let ar = ar_method();
    let mut table = Table::new(
        title,
        &["Benchmark", "Method", "TPF", "Acc (%)", "AUP"],
    );

    for &(task, strict) in tasks {
        // collect sweeps for every method and seed
        let mut all: Vec<(String, Vec<Vec<SweepPoint>>)> = Vec::new();
        let mut ar_sweeps: Vec<Vec<SweepPoint>> = Vec::new();
        for seed_i in 0..seeds {
            let seed = 42 + seed_i as u64;
            ar_sweeps.push(sweep::sweep_method(ctx, &ar, task, n, seed,
                                               strict)?);
        }
        for m in methods {
            let mut per_seed = Vec::new();
            let mut failed = false;
            for seed_i in 0..seeds {
                let seed = 42 + seed_i as u64;
                match sweep::sweep_method(ctx, m, task, n, seed, strict) {
                    Ok(s) => per_seed.push(s),
                    Err(e) => {
                        eprintln!("[bench] skip {}: {e:#}", m.label);
                        failed = true;
                        break;
                    }
                }
            }
            if !failed {
                all.push((m.label.clone(), per_seed));
            }
        }

        // y_max per seed: best accuracy seen by anyone on this task
        let y_max: Vec<f64> = (0..seeds)
            .map(|si| {
                let mut best = ar_sweeps[si][0].rec.acc;
                for (_, per_seed) in &all {
                    for p in &per_seed[si] {
                        best = best.max(p.rec.acc);
                    }
                }
                best
            })
            .collect();

        let task_label = if strict {
            format!("{}+", task.name())
        } else {
            task.name().to_string()
        };

        // AR reference row
        {
            let tpfs: Vec<f64> =
                ar_sweeps.iter().map(|s| s[0].rec.tpf).collect();
            let accs: Vec<f64> =
                ar_sweeps.iter().map(|s| s[0].rec.acc).collect();
            let aups: Vec<f64> = (0..seeds)
                .map(|si| {
                    aup_from_points(&sweep::to_points(&ar_sweeps[si]),
                                    DEFAULT_ALPHA, Some(y_max[si]))
                })
                .collect();
            push_method_row(&mut table, &task_label, &ar.label, &tpfs,
                            &accs, &aups);
        }
        let by_label: std::collections::BTreeMap<&str, &Vec<Vec<SweepPoint>>> =
            all.iter().map(|(l, p)| (l.as_str(), p)).collect();
        for m in methods.iter()
            .filter(|m| by_label.contains_key(m.label.as_str()))
        {
            let per_seed = by_label[m.label.as_str()];
            let tpfs: Vec<f64> = per_seed
                .iter()
                .map(|s| sweep::headline(m, s).rec.tpf)
                .collect();
            let accs: Vec<f64> = per_seed
                .iter()
                .map(|s| sweep::headline(m, s).rec.acc)
                .collect();
            let aups: Vec<f64> = (0..seeds)
                .map(|si| {
                    aup_from_points(&sweep::to_points(&per_seed[si]),
                                    DEFAULT_ALPHA, Some(y_max[si]))
                })
                .collect();
            push_method_row(&mut table, &task_label, &m.label, &tpfs, &accs,
                            &aups);
        }

        // adaptive-controller row: where the `load`-mode controller lands
        // under saturation, shown next to the static threshold grid so
        // the table places it on the static Pareto frontier. Skipped for
        // strict ("+") tasks, which the custom-eval path does not cover.
        if !strict {
            for m in methods.iter()
                .filter(|m| m.strategy == Strategy::D3llm)
            {
                let mut tpfs = Vec::new();
                let mut accs = Vec::new();
                let mut aups = Vec::new();
                for seed_i in 0..seeds {
                    let seed = 42 + seed_i as u64;
                    match sweep::eval_adaptive_row(ctx, m, task, n, seed) {
                        Ok(p) => {
                            let pt = Point { rho: p.rec.tpf,
                                             acc: p.rec.acc };
                            aups.push(aup_from_points(&[pt], DEFAULT_ALPHA,
                                                      Some(y_max[seed_i])));
                            tpfs.push(p.rec.tpf);
                            accs.push(p.rec.acc);
                        }
                        Err(e) => {
                            eprintln!("[bench] skip adaptive row for {}: \
                                       {e:#}", m.label);
                            break;
                        }
                    }
                }
                if tpfs.len() == seeds {
                    let label = format!("{} (adaptive)", m.label);
                    push_method_row(&mut table, &task_label, &label, &tpfs,
                                    &accs, &aups);
                }
            }
        }
    }
    table.print();
    table.write(stem)
}

fn push_method_row(table: &mut Table, task: &str, label: &str, tpfs: &[f64],
                   accs: &[f64], aups: &[f64]) {
    let (tm, ts) = mean_std(tpfs);
    let (am, as_) = mean_std(accs);
    let (um, us) = mean_std(aups);
    table.row(vec![
        task.to_string(),
        label.to_string(),
        pm(tm, ts, 2),
        pm(am, as_, 1),
        pm(um, us, 1),
    ]);
}

// -------------------------------------------------------------- Tables 1-2

pub fn table1(ctx: &BenchCtx) -> Result<()> {
    family_table(
        ctx,
        "Table 1 — LLaDA-family: TPF / Accuracy / AUP across 5 tasks",
        "table1",
        &llada_methods(),
        &EVAL_TASKS.map(|t| (t, false)),
    )
}

pub fn table2(ctx: &BenchCtx) -> Result<()> {
    family_table(
        ctx,
        "Table 2 — Dream-family: TPF / Accuracy / AUP across 5 tasks",
        "table2",
        &dream_methods(),
        &EVAL_TASKS.map(|t| (t, false)),
    )
}

// -------------------------------------------------------------- Tables 3-4

/// TPS tables: measured CPU TPS plus the calibrated H100/A100 cost-model
/// TPS (DESIGN.md §1 hardware substitution), with speedups vs the AR row.
fn tps_table(ctx: &BenchCtx, title: &str, stem: &str,
             methods: &[MethodSpec]) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seed = 42u64;
    let task = Family::Gsm8k;
    let ar = ar_method();

    let mut table = Table::new(
        title,
        &["Method", "CPU TPS", "H100-sim TPS", "A100-sim TPS", "Acc (%)"],
    );

    let ar_sweep = sweep::sweep_method(ctx, &ar, task, n, seed, false)?;
    let ar_rec = &ar_sweep[0].rec;
    let ar_cpu = ar_rec.tps_cpu;
    let ar_h100 = ar_rec.mix().modeled_tps(&H100);
    let ar_a100 = ar_rec.mix().modeled_tps(&A100);
    table.row(vec![
        ar.label.clone(),
        format!("{:.1} (1.0x)", ar_cpu),
        format!("{:.1} (1.0x)", ar_h100),
        format!("{:.1} (1.0x)", ar_a100),
        format!("{:.1}", ar_rec.acc),
    ]);

    for m in methods {
        let sweep_pts = match sweep::sweep_method(ctx, m, task, n, seed,
                                                  false) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[bench] skip {}: {e:#}", m.label);
                continue;
            }
        };
        let rec = &sweep::headline(m, &sweep_pts).rec;
        let h100 = rec.mix().modeled_tps(&H100);
        let a100 = rec.mix().modeled_tps(&A100);
        table.row(vec![
            m.label.clone(),
            format!("{:.1} ({:.1}x)", rec.tps_cpu, rec.tps_cpu / ar_cpu),
            format!("{:.1} ({:.1}x)", h100, h100 / ar_h100),
            format!("{:.1} ({:.1}x)", a100, a100 / ar_a100),
            format!("{:.1}", rec.acc),
        ]);
    }
    table.print();
    table.write(stem)
}

pub fn table3(ctx: &BenchCtx) -> Result<()> {
    tps_table(
        ctx,
        "Table 3 — LLaDA-family throughput on GSM8K (measured CPU + \
         calibrated H100/A100 cost model)",
        "table3",
        &llada_methods(),
    )
}

pub fn table4(ctx: &BenchCtx) -> Result<()> {
    tps_table(
        ctx,
        "Table 4 — Dream-family throughput on GSM8K (measured CPU + \
         calibrated H100/A100 cost model)",
        "table4",
        &dream_methods(),
    )
}

// --------------------------------------------------------------- Table 5

/// Ablation: distillation recipe rows (different checkpoints, full decode)
/// then decoding rows (full checkpoint, reduced decode configs).
pub fn table5(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(12);
    let seed = 42u64;
    let task = Family::Gsm8k;
    let thresholds = [0.1f32, 0.25, 0.45, 0.8, 1.3];
    let headline_t = 0.45f32;

    let mut table = Table::new(
        "Table 5 — Ablation on distillation recipe and decoding strategy \
         (GSM8K)",
        &["Config", "TPF", "Acc (%)", "AUP"],
    );

    let full_cfg = DecodeCfg::preset(Strategy::D3llm);

    // ---- distillation-recipe rows (decode fixed = full d3llm)
    let recipe_rows: [(&str, &str); 4] = [
        ("no distillation (teacher) + multi-block + early-stop",
         "llada-teacher"),
        ("+ pseudo-trajectory", "ablate-pt"),
        ("+ curriculum noise", "ablate-pt-noise"),
        ("+ curriculum window (full d3LLM)", "d3llm-llada"),
    ];
    for (label, ckpt) in recipe_rows {
        if let Err(e) = add_cfg_row(ctx, &mut table, label, ckpt, &full_cfg,
                                    &format!("t5-{ckpt}"), task, &thresholds,
                                    headline_t, n, seed) {
            eprintln!("[bench] skip `{label}`: {e:#}");
        }
    }

    // ---- decoding rows (checkpoint fixed = d3llm-llada)
    let mut single = DecodeCfg::preset(Strategy::FastDllm);
    single.metric = SelMetric::Entropy(0.45);
    single.early_stop = false;
    add_cfg_row(ctx, &mut table,
                "full recipe, single-block decode, no early-stop",
                "d3llm-llada", &single, "t5-dec-single", task, &thresholds,
                headline_t, n, seed)?;

    let mut no_es = full_cfg.clone();
    no_es.early_stop = false;
    add_cfg_row(ctx, &mut table, "full recipe, multi-block, no early-stop",
                "d3llm-llada", &no_es, "t5-dec-noes", task, &thresholds,
                headline_t, n, seed)?;
    add_cfg_row(ctx, &mut table, "full recipe, multi-block + early-stop",
                "d3llm-llada", &full_cfg, "t5-dec-full", task, &thresholds,
                headline_t, n, seed)?;

    table.print();
    table.write("table5")
}

#[allow(clippy::too_many_arguments)]
fn add_cfg_row(ctx: &BenchCtx, table: &mut Table, label: &str, ckpt: &str,
               cfg: &DecodeCfg, tag: &str, task: Family, thresholds: &[f32],
               headline_t: f32, n: usize, seed: u64) -> Result<()> {
    let mut pts = Vec::new();
    let mut headline = None;
    for &t in thresholds {
        let rec = sweep::eval_custom(ctx, ckpt, cfg, tag, task, t, n, seed)?;
        if (t - headline_t).abs() < 1e-6 {
            headline = Some(rec.clone());
        }
        pts.push(Point { rho: rec.tpf, acc: rec.acc });
    }
    let headline = headline.unwrap_or_else(|| unreachable!());
    let aup = aup_from_points(&pts, DEFAULT_ALPHA, None);
    table.row(vec![
        label.to_string(),
        format!("{:.2}", headline.tpf),
        format!("{:.1}", headline.acc),
        format!("{aup:.1}"),
    ]);
    Ok(())
}

// ------------------------------------------------------------ Tables 6-7

fn hyperparam_table(ctx: &BenchCtx, title: &str, stem: &str,
                    rows: &[(&str, &str)]) -> Result<()> {
    let n = ctx.opts.n_or(12);
    let seed = 42u64;
    let task = Family::Gsm8k;
    let thresholds = [0.1f32, 0.25, 0.45, 0.8, 1.3];
    let cfg = DecodeCfg::preset(Strategy::D3llm);
    let mut table =
        Table::new(title, &["Schedule", "TPF", "Acc (%)", "AUP"]);
    for (label, ckpt) in rows {
        if let Err(e) = add_cfg_row(ctx, &mut table, label, ckpt, &cfg,
                                    &format!("{stem}-{ckpt}"), task,
                                    &thresholds, 0.45, n, seed) {
            eprintln!("[bench] skip `{label}`: {e:#}");
        }
    }
    table.print();
    table.write(stem)
}

pub fn table6(ctx: &BenchCtx) -> Result<()> {
    hyperparam_table(
        ctx,
        "Table 6 — Curriculum noise-level schedules (GSM8K)",
        "table6",
        &[
            ("fixed t=0.5", "noise-fixed-05"),
            ("curriculum 0.2 -> 0.5", "noise-02-05"),
            ("curriculum 0.0 -> 0.5", "noise-00-05"),
            ("curriculum 0.0 -> 0.8 (default)", "d3llm-llada"),
        ],
    )
}

pub fn table7(ctx: &BenchCtx) -> Result<()> {
    hyperparam_table(
        ctx,
        "Table 7 — Curriculum window-size schedules (GSM8K)",
        "table7",
        &[
            ("fixed k=32", "ablate-pt-noise"),
            ("curriculum 0 -> 32", "win-00-32"),
            ("curriculum 16 -> 32 (default)", "d3llm-llada"),
            ("curriculum 24 -> 32", "win-24-32"),
        ],
    )
}

// --------------------------------------------------------------- Table 8

pub fn table8(ctx: &BenchCtx) -> Result<()> {
    let methods = vec![
        MethodSpec::new("Qwen-Coder-sim (AR)", "ar-sim", Strategy::Ar),
        MethodSpec::new("Dream-Coder-sim", "coder-teacher",
                        Strategy::Vanilla),
        MethodSpec::new("d3LLM-Coder", "d3llm-coder", Strategy::D3llm),
    ];
    family_table(
        ctx,
        "Table 8 — Coder family: HumanEval / MBPP analogs, '+' = strict \
         step-verifying checker",
        "table8",
        &methods[1..], // AR row is added by family_table itself
        &[
            (Family::CoderHumanEval, false),
            (Family::CoderHumanEval, true),
            (Family::CoderMbpp, false),
            (Family::CoderMbpp, true),
        ],
    )
    .map(|_| {
        let _ = methods; // AR handled internally
    })
    .map(|_| ())
}

// ----------------------------------------------------------- Tables 9-10

pub fn table9_10(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seed = 42u64;
    let task = Family::Gsm8k;
    let alphas = [1.0, 2.0, 3.0, 5.0, 10.0];

    for (stem, title, methods) in [
        ("table9",
         "Table 9 — AUP alpha sensitivity, LLaDA family (GSM8K)",
         llada_methods()),
        ("table10",
         "Table 10 — AUP alpha sensitivity, Dream family (GSM8K)",
         dream_methods()),
    ] {
        let mut table = Table::new(
            title,
            &["Method", "a=1", "a=2", "a=3", "a=5", "a=10"],
        );
        // shared y_max across the family (incl. AR)
        let ar = ar_method();
        let ar_sweep = sweep::sweep_method(ctx, &ar, task, n, seed, false)?;
        let mut y_max = ar_sweep[0].rec.acc;
        let mut sweeps = Vec::new();
        let mut kept = Vec::new();
        for m in &methods {
            match sweep::sweep_method(ctx, m, task, n, seed, false) {
                Ok(s) => {
                    for p in &s {
                        y_max = y_max.max(p.rec.acc);
                    }
                    sweeps.push(s);
                    kept.push(m.clone());
                }
                Err(e) => eprintln!("[bench] skip {}: {e:#}", m.label),
            }
        }
        let methods = kept;
        let mut row = vec![ar.label.clone()];
        for &a in &alphas {
            row.push(format!(
                "{:.1}",
                aup_from_points(&sweep::to_points(&ar_sweep), a, Some(y_max))
            ));
        }
        table.row(row);
        for (m, s) in methods.iter().zip(&sweeps) {
            let mut row = vec![m.label.clone()];
            for &a in &alphas {
                row.push(format!(
                    "{:.1}",
                    aup_from_points(&sweep::to_points(s), a, Some(y_max))
                ));
            }
            table.row(row);
        }
        table.print();
        table.write(stem)?;
    }
    Ok(())
}

// --------------------------------------------------------------- Table 11

pub fn table11(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seed = 42u64;
    let methods = vec![
        MethodSpec::new("d3LLM-Dream", "d3llm-dream", Strategy::D3llm),
        MethodSpec::new("d3LLM-LLaDA", "d3llm-llada", Strategy::D3llm),
        MethodSpec::new("EAGLE-sim (spec)", "ar-sim", Strategy::Spec),
    ];
    let mut table = Table::new(
        "Table 11 — d3LLM vs speculative decoding (EAGLE-3 analog)",
        &["Benchmark", "Method", "TPF", "Acc (%)", "AUP"],
    );
    for task in EVAL_TASKS {
        // task-wide y_max across the three methods
        let mut sweeps = Vec::new();
        let mut kept = Vec::new();
        let mut y_max: f64 = 0.0;
        for m in &methods {
            match sweep::sweep_method(ctx, m, task, n, seed, false) {
                Ok(s) => {
                    for p in &s {
                        y_max = y_max.max(p.rec.acc);
                    }
                    sweeps.push(s);
                    kept.push(m.clone());
                }
                Err(e) => eprintln!("[bench] skip {}: {e:#}", m.label),
            }
        }
        for (m, s) in kept.iter().zip(&sweeps) {
            let h = &sweep::headline(m, s).rec;
            let aup =
                aup_from_points(&sweep::to_points(s), DEFAULT_ALPHA,
                                Some(y_max));
            table.row(vec![
                task.name().to_string(),
                m.label.clone(),
                format!("{:.2}", h.tpf),
                format!("{:.1}", h.acc),
                format!("{aup:.1}"),
            ]);
        }
    }
    table.print();
    table.write("table11")
}
