//! Threshold sweeps: the machinery that turns one (checkpoint, strategy)
//! into accuracy-parallelism points — the raw material of every AUP score
//! and every curve figure. All runs go through the eval cache.

use anyhow::Result;

use crate::data::{self, Family};
use crate::decode::{AdaptiveCfg, AdaptiveController, AdaptiveMode,
                    DecodeCfg, LoadSignal, Strategy};
use crate::eval::evaluate;
use crate::metrics::aup::Point;

use super::cache::{EvalCache, EvalRecord};
use super::BenchCtx;

/// One contender in a family table.
#[derive(Debug, Clone)]
pub struct MethodSpec {
    /// display name, e.g. "d3LLM-LLaDA"
    pub label: String,
    /// checkpoint name under checkpoints/
    pub ckpt: String,
    pub strategy: Strategy,
    /// sweep knob values; empty = single run at the preset default.
    pub sweep: Vec<f32>,
    /// index into `sweep` of the method's headline operating point
    pub headline: usize,
}

impl MethodSpec {
    pub fn new(label: &str, ckpt: &str, strategy: Strategy) -> MethodSpec {
        let sweep = match strategy {
            Strategy::Vanilla | Strategy::Ar | Strategy::Spec => vec![],
            // entropy grid around `decode::DEFAULT_ENTROPY_THRESHOLD`
            // (the 0.45 headline); the top of the grid doubles as the
            // adaptive controller's default `entropy_ceiling`
            Strategy::D3llm => vec![0.1, 0.25, 0.45, 0.8, 1.3],
            // confidence-threshold methods; the bottom of the grid
            // doubles as the adaptive controller's default `conf_floor`
            _ => vec![0.99, 0.95, 0.85, 0.7, 0.55],
        };
        let headline = if sweep.is_empty() { 0 } else { 2 };
        MethodSpec {
            label: label.to_string(),
            ckpt: ckpt.to_string(),
            strategy,
            sweep,
            headline,
        }
    }
}

/// One evaluated operating point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub threshold: f32,
    pub rec: EvalRecord,
}

/// Evaluate one (method, task, seed) at one threshold, cached.
pub fn eval_point(ctx: &BenchCtx, m: &MethodSpec, task: Family,
                  threshold: f32, n: usize, seed: u64, strict: bool)
                  -> Result<EvalRecord> {
    let variant = "xla";
    let mut cfg = DecodeCfg::preset(m.strategy);
    cfg.variant = variant.to_string();
    if threshold > 0.0 {
        cfg = cfg.with_threshold(threshold);
    }
    let block = ctx.eng.manifest.constants.block;
    let key = EvalCache::key(&m.ckpt, m.strategy.name(), threshold,
                             task.name(), n, seed, variant, strict,
                             cfg.refresh_every, block);
    if let Some(rec) = ctx.cache.borrow().get(&key) {
        return Ok(rec.clone());
    }
    let params = ctx.ckpt(&m.ckpt)?;
    let draft = if m.strategy == Strategy::Spec {
        Some(ctx.ckpt("draft")?)
    } else {
        None
    };
    let samples = data::eval_set(&ctx.tk, task, n, seed);
    let out = evaluate(&ctx.eng, &cfg, &params.data,
                       draft.as_ref().map(|d| d.data.as_slice()), &ctx.tk,
                       &samples, strict)?;
    let rec = EvalRecord::from_run(&out.metrics, &out.mix);
    eprintln!(
        "[bench] {} {} th={threshold:.2} seed={seed}: acc {:.1} tpf {:.2}",
        m.label,
        task.name(),
        rec.acc,
        rec.tpf
    );
    ctx.cache.borrow_mut().put(key, rec.clone());
    Ok(rec)
}

/// Evaluate an arbitrary decode configuration (ablation rows that are not
/// plain presets). `tag` names the configuration in the cache.
pub fn eval_custom(ctx: &BenchCtx, ckpt: &str, cfg: &DecodeCfg, tag: &str,
                   task: Family, threshold: f32, n: usize, seed: u64)
                   -> Result<EvalRecord> {
    let block = ctx.eng.manifest.constants.block;
    let key = EvalCache::key(ckpt, tag, threshold, task.name(), n, seed,
                             &cfg.variant, false, cfg.refresh_every, block);
    if let Some(rec) = ctx.cache.borrow().get(&key) {
        return Ok(rec.clone());
    }
    let params = ctx.ckpt(ckpt)?;
    let cfg = if threshold > 0.0 {
        cfg.clone().with_threshold(threshold)
    } else {
        cfg.clone()
    };
    let samples = data::eval_set(&ctx.tk, task, n, seed);
    let out = evaluate(&ctx.eng, &cfg, &params.data, None, &ctx.tk,
                       &samples, false)?;
    let rec = EvalRecord::from_run(&out.metrics, &out.mix);
    eprintln!(
        "[bench] {tag} {} th={threshold:.2} seed={seed}: acc {:.1} tpf {:.2}",
        task.name(),
        rec.acc,
        rec.tpf
    );
    ctx.cache.borrow_mut().put(key, rec.clone());
    Ok(rec)
}

/// Where the adaptive controller lands on the static sweep's axis: drive
/// a `load`-mode controller to saturation under a sustained synthetic
/// backlog, take the threshold it emits for this method's metric, and
/// evaluate the method there (cached under an `adaptive-*` tag). The
/// returned point rides alongside the static grid so the sweep table
/// shows the controller's overload operating point relative to the
/// static Pareto frontier.
pub fn eval_adaptive_row(ctx: &BenchCtx, m: &MethodSpec, task: Family,
                         n: usize, seed: u64) -> Result<SweepPoint> {
    let mut cfg = DecodeCfg::preset(m.strategy);
    cfg.variant = "xla".to_string();
    let mut ctrl = AdaptiveController::new(AdaptiveCfg {
        mode: AdaptiveMode::Load,
        ..AdaptiveCfg::default()
    });
    // deterministic saturation: a few rounds of a full backlog are
    // enough for the pressure EWMA to converge to ~1
    for _ in 0..12 {
        ctrl.observe(&LoadSignal {
            queue_depth: ctrl.cfg.backlog_full,
            active_sessions: 4,
            est_wait_ms: 0.0,
            round_ms: 0.0,
        });
    }
    let budget = ctrl
        .budget_for(cfg.metric, 0.0)
        .expect("load mode always emits a budget");
    let threshold = budget.entropy_threshold;
    let tag = format!("adaptive-{}", m.strategy.name());
    let rec = eval_custom(ctx, &m.ckpt, &cfg, &tag, task, threshold, n,
                          seed)?;
    Ok(SweepPoint { threshold, rec })
}

/// Full sweep of one (method, task, seed).
pub fn sweep_method(ctx: &BenchCtx, m: &MethodSpec, task: Family, n: usize,
                    seed: u64, strict: bool) -> Result<Vec<SweepPoint>> {
    let thresholds: Vec<f32> = if m.sweep.is_empty() {
        vec![0.0] // single preset-default run
    } else {
        m.sweep.clone()
    };
    thresholds
        .into_iter()
        .map(|t| {
            Ok(SweepPoint {
                threshold: t,
                rec: eval_point(ctx, m, task, t, n, seed, strict)?,
            })
        })
        .collect()
}

/// Convert sweep points to AUP points.
pub fn to_points(points: &[SweepPoint]) -> Vec<Point> {
    points
        .iter()
        .map(|p| Point { rho: p.rec.tpf, acc: p.rec.acc })
        .collect()
}

/// Headline record of a sweep (the method's default operating point).
pub fn headline<'a>(m: &MethodSpec, points: &'a [SweepPoint])
                    -> &'a SweepPoint {
    &points[m.headline.min(points.len() - 1)]
}
