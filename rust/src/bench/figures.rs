//! Figure generators: accuracy-parallelism curves (Figs. 4a/5/7/9), AUP
//! radar/histogram data (Figs. 4b/4c/6/8/10), and the AUP illustration
//! (Fig. 1). Output is CSV series; plots/plot_figures.py renders PNGs when
//! matplotlib is available (build-time only).

use anyhow::Result;

use crate::data::Family;
use crate::metrics::aup::{aup_from_points, Point, DEFAULT_ALPHA};

use super::sweep::{self, MethodSpec};
use super::tables::{dream_methods, llada_methods};
use super::BenchCtx;

const EVAL_TASKS: [Family; 5] = [
    Family::Gsm8k,
    Family::Math,
    Family::Mbpp,
    Family::HumanEval,
    Family::LongGsm8k,
];

fn coder_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::new("Dream-Coder-sim", "coder-teacher",
                        crate::decode::Strategy::Vanilla),
        MethodSpec::new("d3LLM-Coder", "d3llm-coder",
                        crate::decode::Strategy::D3llm),
    ]
}

/// Figure 1: the AUP construction, on real d3LLM sweep data — per point:
/// accuracy, weight W(y), weighted contribution. Regenerates the paper's
/// illustration with measured numbers.
pub fn figure1(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let m = MethodSpec::new("d3LLM-LLaDA", "d3llm-llada",
                            crate::decode::Strategy::D3llm);
    let s = sweep::sweep_method(ctx, &m, Family::Gsm8k, n, 42, false)?;
    let pts = sweep::to_points(&s);
    let y_max = pts.iter().map(|p| p.acc).fold(0.0, f64::max);
    let alpha = DEFAULT_ALPHA;

    let mut rows = Vec::new();
    let mut sorted = pts.clone();
    sorted.sort_by(|a, b| a.rho.partial_cmp(&b.rho).unwrap());
    for p in &sorted {
        let w = (-alpha * (1.0 - p.acc / y_max)).exp().min(1.0);
        rows.push(vec![
            format!("{:.3}", p.rho),
            format!("{:.2}", p.acc),
            format!("{w:.4}"),
            format!("{:.2}", p.acc * w),
        ]);
    }
    crate::util::write_csv("results/figure1_aup_illustration.csv",
                           &["tpf", "acc", "weight", "weighted_acc"],
                           &rows)?;
    let aup = aup_from_points(&pts, alpha, Some(y_max));
    eprintln!("[bench] figure1: AUP = {aup:.1} (alpha={alpha})");
    Ok(())
}

/// Accuracy-parallelism curves for each family x task (Figures 4a/5/7/9).
pub fn curves(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seed = 42u64;
    for (family, methods) in [
        ("llada", llada_methods()),
        ("dream", dream_methods()),
        ("coder", coder_methods()),
    ] {
        let tasks: Vec<Family> = if family == "coder" {
            vec![Family::CoderHumanEval, Family::CoderMbpp]
        } else {
            EVAL_TASKS.to_vec()
        };
        let mut rows = Vec::new();
        for task in tasks {
            for m in &methods {
                let Ok(s) = sweep::sweep_method(ctx, m, task, n, seed, false)
                else {
                    continue;
                };
                for p in &s {
                    rows.push(vec![
                        task.name().to_string(),
                        m.label.clone(),
                        format!("{:.4}", p.threshold),
                        format!("{:.3}", p.rec.tpf),
                        format!("{:.2}", p.rec.acc),
                    ]);
                }
            }
        }
        crate::util::write_csv(
            format!("results/curves_{family}.csv"),
            &["task", "method", "threshold", "tpf", "acc"],
            &rows,
        )?;
    }
    eprintln!("[bench] curves written (results/curves_*.csv)");
    Ok(())
}

/// Per-task AUP matrices for the radar charts / histograms
/// (Figures 4b, 4c, 6, 8, 10).
pub fn radar(ctx: &BenchCtx) -> Result<()> {
    let n = ctx.opts.n_or(10);
    let seed = 42u64;
    for (family, methods) in [
        ("llada", llada_methods()),
        ("dream", dream_methods()),
        ("coder", coder_methods()),
    ] {
        let tasks: Vec<Family> = if family == "coder" {
            vec![Family::CoderHumanEval, Family::CoderMbpp]
        } else {
            EVAL_TASKS.to_vec()
        };
        let mut rows = Vec::new();
        for task in tasks {
            // family-wide y_max per task
            let mut sweeps = Vec::new();
            let mut kept = Vec::new();
            let mut y_max: f64 = 0.0;
            for m in &methods {
                match sweep::sweep_method(ctx, m, task, n, seed, false) {
                    Ok(s) => {
                        for p in &s {
                            y_max = y_max.max(p.rec.acc);
                        }
                        sweeps.push(s);
                        kept.push(m.clone());
                    }
                    Err(_) => continue,
                }
            }
            for (m, s) in kept.iter().zip(&sweeps) {
                let pts: Vec<Point> = sweep::to_points(s);
                let aup = aup_from_points(&pts, DEFAULT_ALPHA, Some(y_max));
                rows.push(vec![
                    task.name().to_string(),
                    m.label.clone(),
                    format!("{aup:.2}"),
                ]);
            }
        }
        crate::util::write_csv(
            format!("results/radar_{family}.csv"),
            &["task", "method", "aup"],
            &rows,
        )?;
    }
    eprintln!("[bench] radar AUP matrices written (results/radar_*.csv)");
    Ok(())
}
