//! Performance profiling harness (`repro bench --exp perf`):
//! per-executable latency, host-dispatch overhead, hot-path variant
//! comparison (pallas vs fused-xla), and end-to-end strategy throughput.
//! Feeds EXPERIMENTS.md §Perf.

use anyhow::Result;

use crate::data::{self, Family};
use crate::decode::{self, DecodeCfg, Strategy};
use crate::model::{exec, KvCache, ParamStore};
use crate::util::stats::{bench, bench_line, Summary};

use super::BenchCtx;

pub fn run(ctx: &BenchCtx) -> Result<()> {
    let eng = &ctx.eng;
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main")?.clone();
    // use a real checkpoint when available so numerics are representative
    let params = ctx
        .ckpt("d3llm-llada")
        .map(|p| p.data.clone())
        .unwrap_or_else(|_| ParamStore::init(&spec, 7).data);

    let mut lines: Vec<String> = Vec::new();

    // ---- L2/L1 executables: prefill + decode, both variants
    let tokens: Vec<i32> = (0..c.s_max as i32).map(|i| 5 + i % 90).collect();
    let valid: Vec<f32> = (0..c.s_max)
        .map(|i| if i < 256 { 1.0 } else { 0.0 })
        .collect();
    for variant in ["xla", "pallas"] {
        let name = format!("prefill_{variant}");
        eng.warmup(&[name.as_str()])?;
        let secs = bench(2, 8, || {
            exec::prefill(eng, &name, &params, &tokens, &valid).unwrap();
        });
        lines.push(bench_line(&name, &secs));
    }

    let cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
    let win_tokens = vec![c.mask_id; c.window];
    let win_pos: Vec<i32> = (0..c.window as i32).collect();
    let win_valid = vec![1.0f32; c.window];
    for variant in ["xla", "pallas"] {
        let name = format!("decode_{variant}");
        eng.warmup(&[name.as_str()])?;
        let secs = bench(2, 16, || {
            exec::decode_window(eng, &name, &params, &win_tokens, &win_pos,
                                &win_valid, &cache)
                .unwrap();
        });
        lines.push(bench_line(&name, &secs));
    }

    // ---- AR step (the smallest dispatch: overhead shows up here)
    {
        eng.warmup(&["ar_step"])?;
        let secs = bench(4, 32, || {
            exec::decode_window(eng, "ar_step", &params, &[5], &[0], &[1.0],
                                &cache)
                .unwrap();
        });
        lines.push(bench_line("ar_step", &secs));
    }

    // ---- L3 §Perf A/B: literal path vs device-resident-params execute_b
    for (label, buffered) in [("decode literal-args (before)", false),
                              ("decode buffered-args (after)", true)] {
        eng.set_buffered(buffered);
        let secs = bench(3, 24, || {
            exec::decode_window(eng, "decode_xla", &params, &win_tokens,
                                &win_pos, &win_valid, &cache)
                .unwrap();
        });
        lines.push(bench_line(label, &secs));
    }
    eng.set_buffered(true);

    // ---- dispatch overhead: engine-reported upload vs total
    eng.reset_stats();
    for _ in 0..16 {
        exec::decode_window(eng, "decode_xla", &params, &win_tokens,
                            &win_pos, &win_valid, &cache)?;
    }
    if let Some(s) = eng.stats().get("decode_xla") {
        lines.push(format!(
            "decode_xla host-upload share: {:.1}% ({:.3} ms of {:.3} ms/call)",
            100.0 * s.upload_secs / s.total_secs,
            s.upload_secs / s.calls as f64 * 1e3,
            s.total_secs / s.calls as f64 * 1e3,
        ));
    }

    // ---- end-to-end strategy throughput on one GSM8K prompt
    let samples = data::eval_set(&ctx.tk, Family::Gsm8k, 3, 1);
    for strategy in [Strategy::Ar, Strategy::Vanilla, Strategy::FastDllm,
                     Strategy::D3llm] {
        let cfg = DecodeCfg::preset(strategy);
        let mut secs = Vec::new();
        let mut toks = 0usize;
        for s in &samples {
            let t0 = std::time::Instant::now();
            let r = decode::generate(eng, &cfg, &params, None, &s.prompt,
                                     96)?;
            secs.push(t0.elapsed().as_secs_f64());
            toks += r.tokens.len();
        }
        let total: f64 = secs.iter().sum();
        lines.push(format!(
            "e2e {:<10} {:>8.1} tok/s   ({} tokens, {})",
            strategy.name(),
            toks as f64 / total,
            toks,
            bench_line("", &secs).trim_start()
        ));
    }

    let report = lines.join("\n");
    println!("{report}");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/perf.md",
                   format!("# Perf profile\n\n```\n{report}\n```\n"))?;
    eprintln!("[bench] wrote results/perf.md");
    let _ = Summary::of(&[]);
    Ok(())
}
