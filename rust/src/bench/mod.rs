//! Benchmark harnesses: one generator per paper table/figure
//! (DESIGN.md §5 maps experiment ids to modules). Everything lands in
//! results/ as markdown + CSV; EXPERIMENTS.md summarises paper-vs-measured.

pub mod analysis;
pub mod cache;
pub mod figures;
pub mod perf;
pub mod report;
pub mod sweep;
pub mod tables;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;
use crate::train::TrainCfg;

use cache::EvalCache;

#[derive(Debug, Clone, Copy, Default)]
pub struct BenchOpts {
    /// samples per eval run (0 = per-experiment default)
    pub n: usize,
    /// shrink everything for smoke runs
    pub fast: bool,
    /// eval-set replicas for +-std (0 = default 2)
    pub seeds: usize,
}

impl BenchOpts {
    pub fn n_or(&self, default: usize) -> usize {
        let n = if self.n > 0 { self.n } else { default };
        if self.fast {
            (n / 2).max(4)
        } else {
            n
        }
    }

    pub fn seeds_or(&self, default: usize) -> usize {
        let s = if self.seeds > 0 { self.seeds } else { default };
        if self.fast {
            1
        } else {
            s
        }
    }
}

/// Shared bench context: engine, tokenizer, checkpoint + eval caches.
pub struct BenchCtx {
    pub eng: Engine,
    pub tk: Tokenizer,
    pub opts: BenchOpts,
    pub cache: RefCell<EvalCache>,
    ckpts: RefCell<HashMap<String, Rc<ParamStore>>>,
}

impl BenchCtx {
    pub fn new(opts: BenchOpts) -> Result<BenchCtx> {
        let eng = Engine::load("artifacts")?;
        let tk = Tokenizer::new(eng.manifest.constants.vocab)?;
        Ok(BenchCtx {
            eng,
            tk,
            opts,
            cache: RefCell::new(EvalCache::open("results/eval_cache.json")),
            ckpts: RefCell::new(HashMap::new()),
        })
    }

    pub fn ckpt(&self, name: &str) -> Result<Rc<ParamStore>> {
        if let Some(p) = self.ckpts.borrow().get(name) {
            return Ok(p.clone());
        }
        let path = TrainCfg::ckpt_path(Path::new("checkpoints"), name);
        let p = Rc::new(ParamStore::load(&path).map_err(|e| {
            anyhow!("{e:#}. Run `repro train-all` to build checkpoints")
        })?);
        self.ckpts.borrow_mut().insert(name.to_string(), p.clone());
        Ok(p)
    }
}

/// Dispatcher: `repro bench --exp <id>`.
pub fn run(exp: &str, opts: BenchOpts) -> Result<()> {
    let ctx = BenchCtx::new(opts)?;
    std::fs::create_dir_all("results")?;
    match exp {
        "table1" => tables::table1(&ctx),
        "table2" => tables::table2(&ctx),
        "table3" => tables::table3(&ctx),
        "table4" => tables::table4(&ctx),
        "table5" => tables::table5(&ctx),
        "table6" => tables::table6(&ctx),
        "table7" => tables::table7(&ctx),
        "table8" => tables::table8(&ctx),
        "table9" | "table10" | "table9_10" => tables::table9_10(&ctx),
        "table11" => tables::table11(&ctx),
        "figure1" => figures::figure1(&ctx),
        "curves" => figures::curves(&ctx),
        "radar" => figures::radar(&ctx),
        "perf" => perf::run(&ctx),
        "summary" => {
            let text = analysis::render_summary(Path::new("results"))?;
            std::fs::write("results/summary.md", &text)?;
            println!("{text}");
            Ok(())
        }
        "all" => {
            tables::table1(&ctx)?;
            tables::table2(&ctx)?;
            tables::table3(&ctx)?;
            tables::table4(&ctx)?;
            tables::table5(&ctx)?;
            tables::table6(&ctx)?;
            tables::table7(&ctx)?;
            tables::table8(&ctx)?;
            tables::table9_10(&ctx)?;
            tables::table11(&ctx)?;
            figures::figure1(&ctx)?;
            figures::curves(&ctx)?;
            figures::radar(&ctx)?;
            Ok(())
        }
        other => Err(anyhow!(
            "unknown experiment `{other}` (table1..table11, figure1, \
             curves, radar, perf, all)"
        )),
    }
}
