//! Markdown/CSV emitters for the table harnesses.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// A rendered table: header + rows, written as both .md and .csv.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn write(&self, stem: &str) -> Result<()> {
        let md_path = format!("results/{stem}.md");
        if let Some(parent) = Path::new(&md_path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&md_path)?;
        writeln!(f, "# {}\n", self.title)?;
        writeln!(f, "| {} |", self.header.join(" | "))?;
        writeln!(
            f,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        )?;
        for row in &self.rows {
            writeln!(f, "| {} |", row.join(" | "))?;
        }
        crate::util::write_csv(
            format!("results/{stem}.csv"),
            &self.header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            &self.rows,
        )?;
        eprintln!("[bench] wrote results/{stem}.md (+.csv)");
        Ok(())
    }

    /// Also print to stdout for interactive runs.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        println!("{}", self.header.join(" | "));
        for row in &self.rows {
            println!("{}", row.join(" | "));
        }
    }
}

/// mean ± std formatting used across tables.
pub fn pm(mean: f64, std: f64, prec: usize) -> String {
    format!("{mean:.prec$} ± {std:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pm_formats() {
        assert_eq!(pm(9.112, 0.14, 2), "9.11 ± 0.14");
        assert_eq!(pm(73.06, 0.31, 1), "73.1 ± 0.3");
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
