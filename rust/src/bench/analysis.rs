//! Post-hoc analysis: reads the bench CSV outputs and checks the paper's
//! qualitative claims ("shape checks"), then emits the EXPERIMENTS.md
//! summary section. This is the automated paper-vs-measured comparator.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// One parsed family-table row.
#[derive(Debug, Clone)]
pub struct Row {
    pub task: String,
    pub method: String,
    pub tpf: f64,
    pub acc: f64,
    pub aup: f64,
}

fn parse_pm(s: &str) -> f64 {
    s.split('±').next().unwrap_or("0").trim().parse().unwrap_or(0.0)
}

/// Read a family table CSV (Benchmark, Method, TPF, Acc, AUP).
pub fn read_family_csv(path: impl AsRef<Path>) -> Result<Vec<Row>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    let mut rows = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() < 5 {
            continue;
        }
        rows.push(Row {
            task: cells[0].to_string(),
            method: cells[1].to_string(),
            tpf: parse_pm(cells[2]),
            acc: parse_pm(cells[3]),
            aup: parse_pm(cells[4]),
        });
    }
    Ok(rows)
}

/// The outcome of one qualitative claim check.
#[derive(Debug, Clone)]
pub struct Claim {
    pub name: String,
    pub holds: bool,
    pub detail: String,
}

fn by_task(rows: &[Row]) -> BTreeMap<String, Vec<Row>> {
    let mut m: BTreeMap<String, Vec<Row>> = BTreeMap::new();
    for r in rows {
        m.entry(r.task.clone()).or_default().push(r.clone());
    }
    m
}

fn find<'a>(rows: &'a [Row], needle: &str) -> Option<&'a Row> {
    rows.iter().find(|r| r.method.contains(needle))
}

/// Shape checks for a family table (paper Tables 1/2): d3LLM wins AUP,
/// TPF ordering, bounded accuracy cost, vanilla TPF == 1.
pub fn check_family(rows: &[Row], d3_name: &str, vanilla_name: &str)
                    -> Vec<Claim> {
    let mut claims = Vec::new();
    let tasks = by_task(rows);
    let mut d3_wins = 0usize;
    let mut n_tasks = 0usize;
    let mut tpf_ordered = 0usize;
    let mut acc_ok = 0usize;
    let mut vanilla_tpf_one = true;

    for (_task, trows) in &tasks {
        let Some(d3) = find(trows, d3_name) else { continue };
        let Some(van) = find(trows, vanilla_name) else { continue };
        n_tasks += 1;
        // d3LLM has the best AUP among dLLM methods (AR reference excluded)
        let best_aup = trows
            .iter()
            .filter(|r| !r.method.contains("AR"))
            .map(|r| r.aup)
            .fold(f64::MIN, f64::max);
        if d3.aup >= best_aup - 1e-9 {
            d3_wins += 1;
        }
        // d3LLM has the highest TPF in the family
        let best_tpf = trows
            .iter()
            .filter(|r| !r.method.contains("AR"))
            .map(|r| r.tpf)
            .fold(f64::MIN, f64::max);
        if d3.tpf >= best_tpf - 1e-9 {
            tpf_ordered += 1;
        }
        // accuracy cost vs vanilla bounded (paper: "negligible"; we allow
        // 5 points on the scaled-down testbed)
        if d3.acc >= van.acc - 5.0 {
            acc_ok += 1;
        }
        if (van.tpf - 1.0).abs() > 0.05 {
            vanilla_tpf_one = false;
        }
    }

    claims.push(Claim {
        name: format!("{d3_name} best AUP"),
        holds: n_tasks > 0 && d3_wins * 2 > n_tasks,
        detail: format!("{d3_wins}/{n_tasks} tasks"),
    });
    claims.push(Claim {
        name: format!("{d3_name} highest TPF"),
        holds: n_tasks > 0 && tpf_ordered * 2 > n_tasks,
        detail: format!("{tpf_ordered}/{n_tasks} tasks"),
    });
    claims.push(Claim {
        name: "accuracy cost bounded (<=5pt vs vanilla)".into(),
        holds: n_tasks > 0 && acc_ok * 2 > n_tasks,
        detail: format!("{acc_ok}/{n_tasks} tasks"),
    });
    claims.push(Claim {
        name: format!("{vanilla_name} TPF = 1.0"),
        holds: vanilla_tpf_one,
        detail: String::new(),
    });
    claims
}

/// Speedup summary vs the vanilla row on one task (paper's "10x over
/// vanilla LLaDA/Dream" claim, via TPF ratio).
pub fn speedup_vs_vanilla(rows: &[Row], task: &str, d3: &str, vanilla: &str)
                          -> Option<f64> {
    let trows = by_task(rows).remove(task)?;
    let d = find(&trows, d3)?.tpf;
    let v = find(&trows, vanilla)?.tpf;
    (v > 0.0).then(|| d / v)
}

/// Render the EXPERIMENTS.md summary for all family tables present.
pub fn render_summary(results_dir: &Path) -> Result<String> {
    let mut out = String::new();
    for (stem, d3, vanilla, paper_shape) in [
        ("table1", "d3LLM-LLaDA", "LLaDA-sim",
         "paper: d3LLM best AUP on 5/5 LLaDA tasks, TPF 9.11 on GSM8K"),
        ("table2", "d3LLM-Dream", "Dream-sim",
         "paper: d3LLM best AUP on 4/5 Dream tasks"),
        ("table8", "d3LLM-Coder", "Dream-Coder-sim",
         "paper: d3LLM-Coder ~2.5-2.9x TPF at comparable accuracy"),
    ] {
        let path = results_dir.join(format!("{stem}.csv"));
        if !path.exists() {
            continue;
        }
        let rows = read_family_csv(&path)?;
        writeln!(out, "### {stem} ({paper_shape})\n").ok();
        for c in check_family(&rows, d3, vanilla) {
            writeln!(out, "- [{}] {} {}",
                     if c.holds { "x" } else { " " }, c.name, c.detail)
                .ok();
        }
        if let Some(s) =
            speedup_vs_vanilla(&rows, "gsm8k", d3, vanilla)
        {
            writeln!(out, "- TPF speedup vs vanilla on GSM8K: {s:.1}x \
                           (paper: ~9-10x)")
                .ok();
        }
        writeln!(out).ok();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<Row> {
        let mk = |task: &str, method: &str, tpf: f64, acc: f64, aup: f64| Row {
            task: task.into(), method: method.into(), tpf, acc, aup,
        };
        vec![
            mk("gsm8k", "Qwen-sim (AR)", 1.0, 80.0, 80.0),
            mk("gsm8k", "LLaDA-sim", 1.0, 72.0, 72.0),
            mk("gsm8k", "Fast-dLLM-LLaDA", 2.5, 71.0, 150.0),
            mk("gsm8k", "d3LLM-LLaDA", 6.0, 71.5, 380.0),
            mk("math", "LLaDA-sim", 1.0, 30.0, 30.0),
            mk("math", "Fast-dLLM-LLaDA", 2.0, 29.0, 50.0),
            mk("math", "d3LLM-LLaDA", 4.0, 28.5, 95.0),
        ]
    }

    #[test]
    fn claims_hold_on_paper_shaped_data() {
        let claims = check_family(&rows(), "d3LLM-LLaDA", "LLaDA-sim");
        assert!(claims.iter().all(|c| c.holds),
                "{:?}", claims.iter().filter(|c| !c.holds).collect::<Vec<_>>());
    }

    #[test]
    fn claims_fail_when_d3_loses() {
        let mut r = rows();
        for row in &mut r {
            if row.method == "d3LLM-LLaDA" {
                row.aup = 10.0;
            }
        }
        let claims = check_family(&r, "d3LLM-LLaDA", "LLaDA-sim");
        assert!(!claims[0].holds);
    }

    #[test]
    fn speedup_math() {
        let s = speedup_vs_vanilla(&rows(), "gsm8k", "d3LLM-LLaDA",
                                   "LLaDA-sim")
            .unwrap();
        assert!((s - 6.0).abs() < 1e-9);
    }

    #[test]
    fn pm_parsing() {
        assert!((parse_pm("9.11 ± 0.14") - 9.11).abs() < 1e-9);
        assert!((parse_pm("73.1") - 73.1).abs() < 1e-9);
    }
}
