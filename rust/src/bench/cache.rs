//! Eval-result cache: every (checkpoint, strategy, threshold, task, n,
//! seed, variant, refresh cadence, block geometry) evaluation is stored
//! in results/eval_cache.json so tables, curves and radar charts share
//! sweep data instead of re-decoding, and interrupted bench runs resume
//! where they stopped. Entries written under older key schemas (which
//! omitted the refresh cadence and block size, letting ablation sweeps
//! collide) are invalidated on open.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::metrics::{ForwardMix, RunMetrics};
use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub acc: f64,
    pub tpf: f64,
    pub tps_cpu: f64,
    pub gen_tokens: usize,
    pub forwards: usize,
    pub full_forwards: usize,
    pub window_forwards: usize,
    pub ar_steps: usize,
    pub wall_secs: f64,
}

impl EvalRecord {
    pub fn from_run(m: &RunMetrics, mix: &ForwardMix) -> EvalRecord {
        EvalRecord {
            acc: m.accuracy(),
            tpf: m.tpf(),
            tps_cpu: m.tps(),
            gen_tokens: m.gen_tokens,
            forwards: m.forwards,
            full_forwards: mix.full_forwards,
            window_forwards: mix.window_forwards,
            ar_steps: mix.ar_steps,
            wall_secs: m.wall_secs,
        }
    }

    pub fn mix(&self) -> ForwardMix {
        ForwardMix {
            full_forwards: self.full_forwards,
            window_forwards: self.window_forwards,
            ar_steps: self.ar_steps,
            gen_tokens: self.gen_tokens,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("acc", Json::num(self.acc)),
            ("tpf", Json::num(self.tpf)),
            ("tps_cpu", Json::num(self.tps_cpu)),
            ("gen_tokens", Json::num(self.gen_tokens as f64)),
            ("forwards", Json::num(self.forwards as f64)),
            ("full_forwards", Json::num(self.full_forwards as f64)),
            ("window_forwards", Json::num(self.window_forwards as f64)),
            ("ar_steps", Json::num(self.ar_steps as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
        ])
    }

    fn from_json(j: &Json) -> Result<EvalRecord> {
        let g = |k: &str| -> Result<f64> {
            j.req(k)?.as_f64().ok_or_else(|| anyhow!("bad field {k}"))
        };
        Ok(EvalRecord {
            acc: g("acc")?,
            tpf: g("tpf")?,
            tps_cpu: g("tps_cpu")?,
            gen_tokens: g("gen_tokens")? as usize,
            forwards: g("forwards")? as usize,
            full_forwards: g("full_forwards")? as usize,
            window_forwards: g("window_forwards")? as usize,
            ar_steps: g("ar_steps")? as usize,
            wall_secs: g("wall_secs")?,
        })
    }
}

pub struct EvalCache {
    path: PathBuf,
    map: BTreeMap<String, EvalRecord>,
    dirty: usize,
}

/// `|`-separated fields in the current key schema; entries with any
/// other count are stale (pre-refresh/block keys) and dropped on open.
const KEY_FIELDS: usize = 10;

impl EvalCache {
    pub fn open(path: impl Into<PathBuf>) -> EvalCache {
        let path = path.into();
        let mut map = BTreeMap::new();
        let mut stale = 0usize;
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(Json::Obj(entries)) = json::parse(&text) {
                for (k, v) in entries {
                    if k.split('|').count() != KEY_FIELDS {
                        stale += 1; // old key schema: invalidate
                        continue;
                    }
                    if let Ok(r) = EvalRecord::from_json(&v) {
                        map.insert(k, r);
                    }
                }
            }
        }
        if stale > 0 {
            eprintln!(
                "[cache] dropped {stale} eval entries written under an \
                 older key schema (missing refresh/block fields)"
            );
        }
        EvalCache { path, map, dirty: 0 }
    }

    /// Canonical cache key. `refresh_every` (KV-refresh cadence) and
    /// `block` (decode block size) are part of the identity: sweeps
    /// differing only in refresh cadence or block geometry used to
    /// collide on one entry.
    #[allow(clippy::too_many_arguments)]
    pub fn key(ckpt: &str, strategy: &str, threshold: f32, task: &str,
               n: usize, seed: u64, variant: &str, strict: bool,
               refresh_every: usize, block: usize) -> String {
        format!(
            "{ckpt}|{strategy}|{threshold:.4}|{task}|{n}|{seed}|{variant}|{}\
             |r{refresh_every}|b{block}",
            strict as u8
        )
    }

    pub fn get(&self, key: &str) -> Option<&EvalRecord> {
        self.map.get(key)
    }

    pub fn put(&mut self, key: String, rec: EvalRecord) {
        self.map.insert(key, rec);
        self.dirty += 1;
        if self.dirty >= 4 {
            let _ = self.save();
        }
    }

    pub fn save(&mut self) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let obj = Json::Obj(
            self.map
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        );
        std::fs::write(&self.path, obj.to_string())?;
        self.dirty = 0;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Drop for EvalCache {
    fn drop(&mut self) {
        let _ = self.save();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("d3llm_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let rec = EvalRecord {
            acc: 72.5, tpf: 5.1, tps_cpu: 120.0, gen_tokens: 610,
            forwards: 120, full_forwards: 10, window_forwards: 110,
            ar_steps: 0, wall_secs: 5.0,
        };
        {
            let mut c = EvalCache::open(&path);
            c.put(EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                                 false, 8, 32), rec.clone());
            c.save().unwrap();
        }
        let c = EvalCache::open(&path);
        let k = EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                               false, 8, 32);
        let got = c.get(&k).unwrap();
        assert!((got.acc - 72.5).abs() < 1e-9);
        assert_eq!(got.window_forwards, 110);
    }

    #[test]
    fn refresh_and_block_are_part_of_the_key() {
        let a = EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                               false, 8, 32);
        let b = EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                               false, 4, 32);
        let c = EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                               false, 8, 16);
        assert_ne!(a, b, "refresh cadence must split cache entries");
        assert_ne!(a, c, "block geometry must split cache entries");
        assert_eq!(a.split('|').count(), KEY_FIELDS);
    }

    #[test]
    fn stale_key_schema_is_invalidated_on_open() {
        let dir = std::env::temp_dir().join("d3llm_cache_migrate_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let rec = EvalRecord {
            acc: 1.0, tpf: 1.0, tps_cpu: 1.0, gen_tokens: 1, forwards: 1,
            full_forwards: 1, window_forwards: 0, ar_steps: 0,
            wall_secs: 1.0,
        };
        {
            let mut c = EvalCache::open(&path);
            // an old 8-field key (pre refresh/block) alongside a current one
            c.put("x|d3llm|0.4500|gsm8k|10|1|xla|0".to_string(),
                  rec.clone());
            c.put(EvalCache::key("x", "d3llm", 0.45, "gsm8k", 10, 1, "xla",
                                 false, 8, 32), rec.clone());
            c.save().unwrap();
        }
        let c = EvalCache::open(&path);
        assert_eq!(c.len(), 1, "stale-schema entry must be dropped");
        assert!(c.get("x|d3llm|0.4500|gsm8k|10|1|xla|0").is_none());
    }
}
