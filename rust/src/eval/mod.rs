//! Evaluation: run a decode strategy over an eval set and score it.
//!
//! Eval decodes are routed through the interleaved scheduler
//! (`coordinator::scheduler::run_pool_bounded`): up to
//! [`DEFAULT_EVAL_WIDTH`] sessions in flight, with each round's
//! same-shape forwards coalesced into one batched backend call — so
//! evaluation gets the serving stack's batched throughput for free while
//! per-sample decodes stay bit-identical to the sequential path (session
//! trajectories are schedule-independent; see
//! `tests/scheduler_determinism.rs`).

use anyhow::Result;

use crate::coordinator::scheduler::run_pool_bounded;
use crate::data::{check, Family, Sample};
use crate::decode::{Backend, DecodeCfg, DecodeSession};
use crate::metrics::{ForwardMix, RunMetrics};
use crate::tokenizer::Tokenizer;

/// Default number of eval sessions in flight. Bounds resident cache
/// memory at `width` dense `KvCache` buffers; coalesced same-shape
/// rounds run through the lowered B>1 executables when the artifact set
/// ships them (manifest format_version >= 2) and fall back to loops on
/// v1 artifacts — pass width 1 to `evaluate_pooled` to reproduce classic
/// sequential evaluation exactly.
pub const DEFAULT_EVAL_WIDTH: usize = 8;

/// Per-task generation length (tokens, block multiple).
pub fn gen_len_for(family: Family, block: usize, gen_max: usize) -> usize {
    let blocks = match family {
        Family::Gsm8k | Family::LongGsm8k => 3,
        Family::Math => 4,
        Family::HumanEval | Family::CoderHumanEval => 3,
        Family::Mbpp | Family::CoderMbpp => 3,
    };
    (blocks * block).min(gen_max)
}

/// Outcome of one eval run (one method x one task x one threshold).
#[derive(Debug, Clone, Default)]
pub struct EvalOutcome {
    pub metrics: RunMetrics,
    pub mix: ForwardMix,
}

/// Evaluate `cfg` with checkpoint `params` over `samples`, interleaving
/// [`DEFAULT_EVAL_WIDTH`] decode sessions through the scheduler.
/// `strict` enables the "+"-style step-verifying checker.
pub fn evaluate(backend: &dyn Backend, cfg: &DecodeCfg, params: &[f32],
                draft_params: Option<&[f32]>, tk: &Tokenizer,
                samples: &[Sample], strict: bool) -> Result<EvalOutcome> {
    evaluate_pooled(backend, cfg, params, draft_params, tk, samples, strict,
                    DEFAULT_EVAL_WIDTH)
}

/// `evaluate` with an explicit interleaving width (width 1 reproduces
/// classic sequential evaluation token-for-token).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_pooled(backend: &dyn Backend, cfg: &DecodeCfg,
                       params: &[f32], draft_params: Option<&[f32]>,
                       tk: &Tokenizer, samples: &[Sample], strict: bool,
                       width: usize) -> Result<EvalOutcome> {
    let c = backend.constants().clone();
    let results = run_pool_bounded(backend, params, samples.len(), width,
                                   |i| {
        let s = &samples[i];
        let gen_len = gen_len_for(s.family, c.block, c.gen_max);
        DecodeSession::with_draft(backend, cfg.clone(), &s.prompt, gen_len,
                                  draft_params)
    })?;
    let mut out = EvalOutcome::default();
    for (s, r) in samples.iter().zip(&results) {
        let ok = check(tk, s, &r.tokens, strict);
        out.metrics.samples += 1;
        out.metrics.correct += ok as usize;
        out.metrics.gen_tokens += r.unmasked;
        out.metrics.forwards += r.forwards;
        out.metrics.draft_forwards += r.draft_forwards;
        out.metrics.wall_secs += r.wall_secs;
        out.mix.merge(&r.mix);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_len_is_block_multiple() {
        for &f in Family::all_eval() {
            let g = gen_len_for(f, 32, 128);
            assert_eq!(g % 32, 0);
            assert!(g <= 128 && g >= 64);
        }
    }
}
