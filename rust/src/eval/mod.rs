//! Evaluation: run a decode strategy over an eval set and score it.

use anyhow::Result;

use crate::data::{check, Family, Sample};
use crate::decode::{self, DecodeCfg};
use crate::metrics::{ForwardMix, RunMetrics};
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;

/// Per-task generation length (tokens, block multiple).
pub fn gen_len_for(family: Family, block: usize, gen_max: usize) -> usize {
    let blocks = match family {
        Family::Gsm8k | Family::LongGsm8k => 3,
        Family::Math => 4,
        Family::HumanEval | Family::CoderHumanEval => 3,
        Family::Mbpp | Family::CoderMbpp => 3,
    };
    (blocks * block).min(gen_max)
}

/// Outcome of one eval run (one method x one task x one threshold).
#[derive(Debug, Clone, Default)]
pub struct EvalOutcome {
    pub metrics: RunMetrics,
    pub mix: ForwardMix,
}

/// Evaluate `cfg` with checkpoint `params` over `samples`.
/// `strict` enables the "+"-style step-verifying checker.
pub fn evaluate(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                draft_params: Option<&[f32]>, tk: &Tokenizer,
                samples: &[Sample], strict: bool) -> Result<EvalOutcome> {
    let c = eng.manifest.constants.clone();
    let mut out = EvalOutcome::default();
    for s in samples {
        let gen_len = gen_len_for(s.family, c.block, c.gen_max);
        let r = decode::generate(eng, cfg, params, draft_params, &s.prompt,
                                 gen_len)?;
        let ok = check(tk, s, &r.tokens, strict);
        out.metrics.samples += 1;
        out.metrics.correct += ok as usize;
        out.metrics.gen_tokens += r.unmasked;
        out.metrics.forwards += r.forwards;
        out.metrics.draft_forwards += r.draft_forwards;
        out.metrics.wall_secs += r.wall_secs;
        out.mix.merge(&r.mix);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_len_is_block_multiple() {
        for &f in Family::all_eval() {
            let g = gen_len_for(f, 32, 128);
            assert_eq!(g % 32, 0);
            assert!(g <= 128 && g >= 64);
        }
    }
}
