//! Deterministic, dependency-free PRNG (SplitMix64) with the sampling
//! helpers the data generators, initialisers and property tests need.
//!
//! SplitMix64 passes BigCrush, is trivially seedable (no warmup) and makes
//! every experiment in this repo reproducible from a single u64 seed.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi exclusive). Panics if lo >= hi.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.range(-3, 9);
            assert!((-3..9).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
