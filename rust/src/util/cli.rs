//! Tiny CLI argument parser (no clap offline): `--key value`, `--flag`,
//! and positional arguments, with typed getters and a usage printer.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. A `--key` followed by a non-`--` token is a
    /// key/value pair; a `--key` followed by another `--` token (or end),
    /// or a known boolean flag, stands alone.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        const BOOL_FLAGS: &[&str] =
            &["fast", "force", "strict", "verbose", "help"];
        let mut out = Args::default();
        let items: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(key) = a.strip_prefix("--") {
                let key = key.to_string();
                out.present.push(key.clone());
                let takes_value = i + 1 < items.len()
                    && !items[i + 1].starts_with("--")
                    && !BOOL_FLAGS.contains(&key.as_str());
                if takes_value {
                    out.flags.insert(key, items[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.insert(key, String::new());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.f64_or(key, default as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("train --steps 200 --fast teacher --lr 2e-3");
        assert_eq!(a.positional, vec!["train", "teacher"]);
        assert_eq!(a.usize_or("steps", 0), 200);
        assert!(a.has("fast"));
        assert!((a.f64_or("lr", 0.0) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.usize_or("port", 7070), 7070);
        assert_eq!(a.str_or("host", "127.0.0.1"), "127.0.0.1");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("--verbose --steps 3");
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None);
        assert_eq!(a.usize_or("steps", 0), 3);
    }
}
