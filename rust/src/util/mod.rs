//! Dependency-free substrates: PRNG, JSON, CLI parsing, stats/benching.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

use std::io::Write;
use std::path::Path;

/// Write a CSV file (header + rows) under `results/`, creating parents.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Emit one benchmark result record: prints the `BENCH {json}` line the
/// CI log scrapers expect and, when `BENCH_JSON_DIR` is set, also writes
/// it to `<dir>/BENCH_<name>.json` so the workflow can persist the perf
/// trajectory as an artifact (`.github/workflows/ci.yml` sets the dir
/// and uploads `BENCH_*.json`).
pub fn emit_bench_json(name: &str, json: &str) {
    println!("BENCH {json}");
    let Ok(dir) = std::env::var("BENCH_JSON_DIR") else { return };
    if dir.is_empty() {
        return;
    }
    let path = Path::new(&dir).join(format!("BENCH_{name}.json"));
    let write = std::fs::create_dir_all(&dir)
        .and_then(|()| std::fs::write(&path, format!("{json}\n")));
    match write {
        Ok(()) => eprintln!("[bench] wrote {path:?}"),
        Err(e) => eprintln!("[bench] could not write {path:?}: {e}"),
    }
}

/// Hex-less short hash (FNV-1a) for cache keys / file names.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv_stable() {
        assert_eq!(super::fnv1a(b"d3llm"), super::fnv1a(b"d3llm"));
        assert_ne!(super::fnv1a(b"a"), super::fnv1a(b"b"));
    }
}
