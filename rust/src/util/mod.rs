//! Dependency-free substrates: PRNG, JSON, CLI parsing, stats/benching.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

use std::io::Write;
use std::path::Path;

/// Write a CSV file (header + rows) under `results/`, creating parents.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Hex-less short hash (FNV-1a) for cache keys / file names.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv_stable() {
        assert_eq!(super::fnv1a(b"d3llm"), super::fnv1a(b"d3llm"));
        assert_ne!(super::fnv1a(b"a"), super::fnv1a(b"b"));
    }
}
