//! Minimal JSON parser / serializer.
//!
//! The offline registry has no serde facade, so the manifest loader, the
//! serving protocol and the results emitters use this hand-rolled
//! implementation: a strict recursive-descent parser over the JSON grammar
//! plus a compact writer. Numbers are kept as f64 (the manifest never needs
//! integers above 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error (for required manifest fields).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // --------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------------ serialize
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------ parser

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (manifest/protocol never needs more)
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "{\"a\":}"] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" slash\\ nl\n tab\t");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        let u = parse(r#""☃""#).unwrap();
        assert_eq!(u.as_str(), Some("☃"));
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[1e3, -2.5e-2, 12345678]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1000.0));
        assert_eq!(a[1].as_f64(), Some(-0.025));
        assert_eq!(a[2].as_i64(), Some(12345678));
    }

    #[test]
    fn obj_builder() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr([Json::str("a")])),
        ]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":["a"]}"#);
    }
}
