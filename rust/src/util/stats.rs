//! Summary statistics + a micro-benchmark harness (criterion is not
//! available offline; `cargo bench` targets use `harness = false` and this
//! module).

use std::time::Instant;

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Percentile over a pre-sorted slice (nearest-rank interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let s = Summary::of(xs);
    (s.mean, s.std)
}

/// Time a closure: `warmup` throwaway calls, then `iters` timed calls.
/// Returns per-call wall-clock seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Render a bench line the way the harness-less `cargo bench` targets print.
pub fn bench_line(name: &str, secs: &[f64]) -> String {
    let s = Summary::of(secs);
    format!(
        "{name:<42} mean {:>9.3} ms   p50 {:>9.3} ms   p95 {:>9.3} ms   (n={})",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn p99_sits_between_p95_and_max() {
        let xs: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p99 - 197.01).abs() < 1e-9, "p99 {}", s.p99);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn bench_runs_exact_iters() {
        let mut count = 0;
        let xs = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(xs.len(), 5);
    }
}
