//! d3LLM reproduction: ultra-fast diffusion-LLM serving via
//! pseudo-trajectory distillation, as a three-layer Rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! Layer 3 (this crate): serving coordinator — decode strategies, block
//! state machine, KV-cache management, batching/serving, training and
//! distillation drivers, metrics (AUP), and the benchmark harnesses that
//! regenerate every table and figure of the paper.

pub mod model;
pub mod runtime;
pub mod util;
pub mod tokenizer;
pub mod data;
pub mod decode;
pub mod metrics;
pub mod eval;
pub mod train;
pub mod trajectory;
pub mod bench;
pub mod coordinator;
pub mod config;
