//! Noisy-sequence construction (paper §3.1, Figure 2).
//!
//! Given ground truth (x, y), a teacher pseudo-trajectory T (rank per
//! position), mask ratio t and decode window w = (s, s+k]:
//!
//!   prefix j <= s             -> ground-truth token
//!   window s < j <= s+k       -> visible iff the teacher had unmasked it
//!                                by state s + ceil(k(1-t)) (so the window
//!                                is masked at ratio t, in *teacher order*)
//!   beyond j > s+k            -> MASK
//!
//! The student is trained to predict ground-truth labels at every masked
//! generation position (CE loss), which teaches the teacher's unmasking
//! order: exactly the tokens the teacher would have decoded by now are
//! visible, everything else must be inferred in parallel.
//!
//! (The paper's formula indexes the trajectory at s + ceil(k t); with t
//! described as the *mask* ratio ramping 0 -> 0.8 "easier to harder", the
//! consistent reading is that the window retains ratio t of masked tokens,
//! i.e. state s + ceil(k(1-t)); we implement that and note the discrepancy
//! in DESIGN.md.)
//!
//! Also implements the contenders' recipes: random-mask distillation
//! (dParallel's certainty-forcing analog) and plain masked-diffusion
//! pretraining (LLaDA-style) and AR LM batches.

use crate::data::Sample;
use crate::runtime::manifest::Constants;
use crate::tokenizer::{EOS, MASK};
use crate::util::rng::Rng;

/// Loss weight for EOS-padding positions (beyond the response). The gen
/// region is much longer than typical responses, so unweighted padding
/// makes EOS dominate the masked-token distribution and a small model
/// floods sequences with EOS; downweighting keeps the supervision (no
/// unsupervised garbage enters the decode context) without the prior.
pub const PAD_LOSS_WEIGHT: f32 = 0.15;

use super::Ranks;

/// Which masking recipe builds the batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Recipe {
    /// LLaDA-style pretraining: iid masking at t ~ U(0.15, 1).
    DiffusionPretrain,
    /// Paper's pseudo-trajectory distillation (needs ranks).
    PseudoTraj,
    /// Window-random masking (no trajectory): the "no pseudo-trajectory"
    /// ablation row and the dParallel certainty-forcing analog.
    RandomMask,
    /// Causal LM (AR baseline, draft model, Fast-dLLM-v2 init).
    ArLm,
}

/// One training example in executable layout (length s_train each).
pub struct NoisyExample {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub attn_valid: Vec<f32>,
}

/// Ground-truth token for generation offset j (response padded with EOS —
/// the teacher "continues generation beyond the EOS token", §3.1).
#[inline]
fn gt(sample: &Sample, j: usize) -> i32 {
    sample.response.get(j).copied().unwrap_or(EOS)
}

/// Build one noisy example.
///
/// `t` = mask ratio, `k` = decode window length, `ranks` = teacher
/// trajectory (PseudoTraj only). `s` (prefix length) is sampled uniformly.
pub fn build_noisy(sample: &Sample, recipe: Recipe, ranks: Option<&Ranks>,
                   t: f64, k: usize, c: &Constants, rng: &mut Rng)
                   -> NoisyExample {
    let s_train = c.s_train;
    let n = c.gen_train;
    let p = sample.prompt.len();
    assert!(p + n <= s_train, "prompt {p} too long");

    let mut tokens = vec![0i32; s_train];
    let mut labels = vec![0i32; s_train];
    let mut loss_mask = vec![0.0f32; s_train];
    let mut attn_valid = vec![0.0f32; s_train];
    tokens[..p].copy_from_slice(&sample.prompt);
    labels[..p].copy_from_slice(&sample.prompt);
    for v in attn_valid.iter_mut().take(p + n) {
        *v = 1.0;
    }

    match recipe {
        Recipe::ArLm => {
            // tokens = prompt ++ y; labels shifted left; loss on the
            // positions that *predict* response tokens.
            for j in 0..n {
                tokens[p + j] = gt(sample, j);
            }
            for i in 0..p + n - 1 {
                labels[i] = tokens[i + 1];
            }
            labels[p + n - 1] = EOS;
            // predictions for response tokens come from positions
            // p-1 .. p+resp_len-1 (incl. the EOS prediction)
            let resp_end = p + sample.response.len().min(n);
            for i in (p - 1)..resp_end.min(s_train) {
                loss_mask[i] = 1.0;
            }
        }
        Recipe::DiffusionPretrain => {
            let ratio = 0.15 + 0.85 * rng.f64();
            let resp = sample.response.len().min(n);
            for j in 0..n {
                let y = gt(sample, j);
                labels[p + j] = y;
                if rng.bool(ratio) {
                    tokens[p + j] = MASK;
                    loss_mask[p + j] =
                        if j < resp { 1.0 } else { PAD_LOSS_WEIGHT };
                } else {
                    tokens[p + j] = y;
                }
            }
        }
        Recipe::PseudoTraj | Recipe::RandomMask => {
            let k = k.clamp(1, n);
            let s = rng.usize(n - k + 1); // prefix length (decoded tokens)
            let visible_in_window = ((k as f64) * (1.0 - t)).ceil() as usize;
            // per-window random visibility for RandomMask
            let mut vis_idx: Vec<usize> = (s..s + k).collect();
            if recipe == Recipe::RandomMask {
                rng.shuffle(&mut vis_idx);
            }
            let rank_cut = (s + visible_in_window) as i32;
            for j in 0..n {
                let y = gt(sample, j);
                labels[p + j] = y;
                let visible = if j < s {
                    true
                } else if j >= s + k {
                    false
                } else {
                    match recipe {
                        Recipe::PseudoTraj => {
                            let r = ranks.expect("PseudoTraj needs ranks");
                            r[p + j] < rank_cut
                        }
                        _ => {
                            // first `visible_in_window` of the shuffled set
                            vis_idx
                                .iter()
                                .take(visible_in_window)
                                .any(|&v| v == j)
                        }
                    }
                };
                if visible {
                    tokens[p + j] = y;
                } else {
                    tokens[p + j] = MASK;
                    loss_mask[p + j] = if j < sample.response.len().min(n) {
                        1.0
                    } else {
                        PAD_LOSS_WEIGHT
                    };
                }
            }
        }
    }

    NoisyExample { tokens, labels, loss_mask, attn_valid }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Family};
    use crate::tokenizer::Tokenizer;

    fn consts() -> Constants {
        Constants {
            vocab: 128, pad_id: 0, mask_id: 1, eos_id: 2, bos_id: 3,
            sep_id: 4, s_max: 384, s_train: 192, gen_max: 128, gen_train: 96,
            window: 96, block: 32, verify_w: 16, b_train: 8, b_traj: 8,
            rank_never: 100_000,
        }
    }

    fn sample() -> Sample {
        let tk = Tokenizer::new(128).unwrap();
        generate(&tk, Family::Gsm8k, &mut Rng::new(3))
    }

    /// Synthetic left-to-right trajectory over the gen region.
    fn ltr_ranks(s: &Sample, c: &Constants) -> Ranks {
        let mut r = vec![c.rank_never; c.s_train];
        for j in 0..c.gen_train {
            r[s.prompt.len() + j] = j as i32;
        }
        r
    }

    #[test]
    fn pseudo_traj_respects_trajectory_order() {
        let c = consts();
        let s = sample();
        let ranks = ltr_ranks(&s, &c);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let ex = build_noisy(&s, Recipe::PseudoTraj, Some(&ranks), 0.5,
                                 32, &c, &mut rng);
            let p = s.prompt.len();
            // with a left-to-right trajectory the visible gen prefix is
            // contiguous: no unmasked position after the first MASK
            let gen = &ex.tokens[p..p + c.gen_train];
            if let Some(first_mask) = gen.iter().position(|&t| t == MASK) {
                assert!(gen[first_mask..].iter().all(|&t| t == MASK));
            }
            // loss exactly on masked gen positions
            for j in 0..c.gen_train {
                let masked = ex.tokens[p + j] == MASK;
                assert_eq!(ex.loss_mask[p + j] > 0.0, masked);
            }
        }
    }

    #[test]
    fn mask_ratio_monotone_in_t() {
        let c = consts();
        let s = sample();
        let ranks = ltr_ranks(&s, &c);
        let count = |t: f64| {
            let mut rng = Rng::new(7);
            let mut total = 0usize;
            for _ in 0..50 {
                let ex = build_noisy(&s, Recipe::PseudoTraj, Some(&ranks), t,
                                     32, &c, &mut rng);
                total +=
                    ex.loss_mask.iter().filter(|&&m| m > 0.0).count();
            }
            total
        };
        let lo = count(0.1);
        let hi = count(0.9);
        assert!(hi > lo, "masking must grow with t: {lo} vs {hi}");
    }

    #[test]
    fn random_mask_window_ratio() {
        let c = consts();
        let s = sample();
        let mut rng = Rng::new(5);
        // t=1 => whole window masked
        let ex = build_noisy(&s, Recipe::RandomMask, None, 1.0, 32, &c,
                             &mut rng);
        let p = s.prompt.len();
        let masked =
            ex.tokens[p..p + c.gen_train].iter().filter(|&&t| t == MASK)
                .count();
        assert!(masked >= 32, "window fully masked plus tail: {masked}");
    }

    #[test]
    fn ar_lm_labels_are_shifted() {
        let c = consts();
        let s = sample();
        let mut rng = Rng::new(6);
        let ex = build_noisy(&s, Recipe::ArLm, None, 0.0, 32, &c, &mut rng);
        let p = s.prompt.len();
        // position p-1 predicts the first response token
        assert_eq!(ex.labels[p - 1], s.response[0]);
        assert!(ex.loss_mask[p - 1] > 0.0);
        // inside the response, label = next token
        assert_eq!(ex.labels[p], ex.tokens[p + 1]);
        // no masks anywhere
        assert!(!ex.tokens.iter().any(|&t| t == MASK));
    }

    #[test]
    fn pretrain_loss_only_on_masks() {
        let c = consts();
        let s = sample();
        let mut rng = Rng::new(8);
        let ex = build_noisy(&s, Recipe::DiffusionPretrain, None, 0.0, 32,
                             &c, &mut rng);
        for i in 0..c.s_train {
            if ex.loss_mask[i] > 0.0 {
                assert_eq!(ex.tokens[i], MASK);
                assert_ne!(ex.labels[i], MASK);
            }
        }
    }
}
