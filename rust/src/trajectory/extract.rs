//! Pooled teacher decoding-order extraction (paper §3.1).
//!
//! The teacher scan — unmask exactly one token per step, restricted to
//! the earliest incomplete block, picking the highest-confidence masked
//! position — is expressed as a `DecodePolicy`, so extraction runs as
//! ordinary resumable sessions through the serving scheduler
//! (`coordinator::scheduler::run_pool_bounded`) instead of a bespoke
//! sequential loop:
//!
//!   * many samples interleave round-robin, and the same-shape forwards
//!     of one round (every trajectory session plans the identical
//!     prefill / window shape) coalesce into batched
//!     `Backend::prefill_batch` / `decode_window_batch` calls;
//!   * sessions can bind to a `SharedKvPool`, so samples sharing a
//!     prompt prefix adopt already-prefilled teacher pages and a
//!     full-prefix hit skips the prompt-prefill forward entirely;
//!   * the per-sample scan is schedule-independent — width-1 extraction
//!     is token-for-token identical to interleaved extraction
//!     (`tests/props.rs` pins it).
//!
//! The scan decodes on the serving hot path: one prompt prefill into the
//! session cache, then one windowed forward per unmask step with the
//! whole generation region in the window. With the window covering the
//! gen region (`gen_train <= window`, the compiled geometry) and only
//! prompt rows cached, this is the block-approximate-cache view of the
//! exact on-device scan (`Backend::trajectory`, kept as the reference
//! path in `extract_on_device`).

use anyhow::{bail, Result};

use crate::data::Sample;
use crate::decode::policy::mismatch;
use crate::decode::{exec_names, Backend, DecodeCfg, DecodePolicy,
                    DecodeSession, KvAdmissionGeometry, PolicyCtx,
                    RoundOut, RoundPlan, Strategy};
use crate::model::kv_pool::SharedKvPool;
use crate::tokenizer::MASK;

/// Executable variant the extraction sessions decode with.
pub const EXTRACT_VARIANT: &str = "xla";

/// Teacher scan as a resumable decode policy: prompt prefill, then one
/// windowed forward per scan step, each unmasking the single
/// highest-confidence masked position of the earliest incomplete block
/// and recording the step as that position's rank. No early stop — the
/// teacher "continues generation beyond the EOS token" (§3.1) so every
/// generation position receives a rank.
pub struct TeacherTrajectoryPolicy {
    prefilled: bool,
    window: usize,
    rank_never: i32,
    prefill_exec: String,
    decode_exec: String,
    /// Per-generation-offset unmask step (RANK_NEVER until unmasked).
    ranks: Vec<i32>,
    step_no: i32,
}

impl TeacherTrajectoryPolicy {
    pub fn new(backend: &dyn Backend, cfg: &DecodeCfg, gen_len: usize)
               -> Result<TeacherTrajectoryPolicy> {
        let c = backend.constants();
        if gen_len > c.window {
            bail!("trajectory extraction needs gen region ({gen_len}) <= \
                   decode window ({})", c.window);
        }
        let (prefill_exec, decode_exec) = exec_names(&cfg.variant);
        Ok(TeacherTrajectoryPolicy {
            prefilled: false,
            window: c.window,
            rank_never: c.rank_never,
            prefill_exec,
            decode_exec,
            ranks: vec![c.rank_never; gen_len],
            step_no: 0,
        })
    }
}

impl DecodePolicy for TeacherTrajectoryPolicy {
    fn plan(&mut self, _backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if !self.prefilled {
            // prompt prefill into the session cache (shareable pages)
            return Ok(RoundPlan::Full {
                exec: self.prefill_exec.clone(),
                tokens: ctx.st.tokens.clone(),
                valid: ctx.st.prompt_valid(),
            });
        }
        // the scan runs exactly gen_len steps; the step cap also bounds a
        // pathological checkpoint whose argmax is the MASK id itself
        if self.step_no as usize >= ctx.st.gen_len
            || ctx.st.first_incomplete_block().is_none()
        {
            return Ok(RoundPlan::Finished);
        }
        // window = the whole generation region against the prompt cache
        let lo = ctx.st.gen_start();
        let mut win_tokens = vec![0i32; self.window];
        let mut win_pos = vec![0i32; self.window];
        let mut win_valid = vec![0.0f32; self.window];
        for off in 0..ctx.st.gen_len {
            win_tokens[off] = ctx.st.tokens[lo + off];
            win_pos[off] = (lo + off) as i32;
            win_valid[off] = 1.0;
        }
        Ok(RoundPlan::Window {
            exec: self.decode_exec.clone(),
            tokens: win_tokens,
            pos: win_pos,
            valid: win_valid,
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        match out {
            RoundOut::Full(pre) => {
                ctx.cache.install_full(&pre.kcache, &pre.vcache, 0,
                                       ctx.st.prompt_len)?;
                self.prefilled = true;
                Ok(false)
            }
            RoundOut::Window(out) => {
                ctx.res.forwards += 1;
                ctx.res.mix.window_forwards += 1;
                let b = ctx
                    .st
                    .first_incomplete_block()
                    .ok_or_else(|| mismatch("trajectory"))?;
                let (blo, bhi) = ctx.st.block_range(b);
                let lo = ctx.st.gen_start();
                let mut best: Option<(usize, f32)> = None;
                for p in blo..bhi {
                    if ctx.st.tokens[p] != MASK {
                        continue;
                    }
                    let conf = out.conf[p - lo];
                    if best.map(|(_, bc)| conf > bc).unwrap_or(true) {
                        best = Some((p, conf));
                    }
                }
                let (p, _) = best.expect("incomplete block has masks");
                ctx.st.tokens[p] = out.argmax[p - lo];
                if self.ranks[p - lo] == self.rank_never {
                    self.ranks[p - lo] = self.step_no;
                }
                self.step_no += 1;
                Ok(self.step_no as usize >= ctx.st.gen_len
                    || ctx.st.first_incomplete_block().is_none())
            }
            RoundOut::None => Err(mismatch("trajectory")),
        }
    }

    fn prefilled(&self) -> bool {
        self.prefilled
    }

    /// Full-prefix pool hit: skip the prompt-prefill forward (see the
    /// single-/multi-block twins).
    fn try_skip_prefill(&mut self, _backend: &dyn Backend,
                        ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        if self.prefilled || !ctx.cache.prefix_ready(ctx.st.prompt_len) {
            return Ok(false);
        }
        self.prefilled = true;
        Ok(true)
    }

    fn take_unmask_ranks(&mut self) -> Option<Vec<i32>> {
        Some(std::mem::take(&mut self.ranks))
    }
}

/// Build one teacher-extraction session for `sample`, optionally bound to
/// a shared KV pool (same-prompt samples then share prefilled prompt
/// pages). The session's cache footprint is the prompt prefix only — the
/// scan never commits generation rows.
pub fn teacher_session(backend: &dyn Backend, sample: &Sample,
                       variant: &str, kv: Option<&SharedKvPool>)
                       -> Result<DecodeSession> {
    let c = backend.constants();
    let gen_len = c.gen_train;
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.variant = variant.to_string();
    cfg.early_stop = false;
    let policy =
        Box::new(TeacherTrajectoryPolicy::new(backend, &cfg, gen_len)?);
    let geo = KvAdmissionGeometry {
        prefix_rows: sample.prompt.len(),
        prefix_tag: exec_names(variant).0,
        span_rows: sample.prompt.len(),
        causal_prefix: false,
    };
    DecodeSession::with_policy(backend, cfg, &sample.prompt, gen_len,
                               policy, kv, Some(geo))
}
