//! Pseudo-trajectory pipeline (paper §3.1): teacher decoding-order
//! extraction (pooled through the serving scheduler, with a disk cache),
//! the noisy-sequence construction equation, and the curriculum
//! schedules.

pub mod curriculum;
pub mod extract;
pub mod noisy;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::scheduler::run_pool_bounded;
use crate::data::Sample;
use crate::decode::Backend;
use crate::model::kv_pool::{KvPoolCfg, SharedKvPool};
use crate::runtime::manifest::Constants;
use crate::tokenizer::MASK;
use crate::util::fnv1a;

pub use curriculum::Curriculum;
pub use extract::{teacher_session, TeacherTrajectoryPolicy,
                  EXTRACT_VARIANT};
pub use noisy::{build_noisy, NoisyExample, Recipe};

/// Teacher decoding ranks for one sample: rank[i] = step at which the
/// teacher unmasked training-sequence position i (RANK_NEVER elsewhere).
pub type Ranks = Vec<i32>;

/// On-disk cache schema magic. Bumped whenever the rank layout or the
/// key derivation changes; files carrying any other magic are stale and
/// are invalidated on open (mirrors the `EvalCache` schema handling).
const CACHE_MAGIC: &[u8; 8] = b"D3TRAJ02";

/// Extract pseudo-trajectories for a corpus by running teacher-scan
/// sessions through the interleaved scheduler: up to `b_traj` samples in
/// flight, same-shape rounds coalesced into batched backend calls, and
/// all sessions bound to one run-scoped `SharedKvPool` so samples that
/// repeat a prompt adopt each other's teacher pages and skip the prompt
/// prefill. Results are cached on disk keyed by the teacher parameters,
/// the corpus prompts and the compile geometry — extraction runs once
/// per teacher and is reused by every distillation variant.
pub fn extract_all(backend: &dyn Backend, teacher: &[f32],
                   samples: &[Sample], cache_dir: impl AsRef<Path>,
                   label: &str) -> Result<Vec<Ranks>> {
    let c = backend.constants().clone();
    let width = c.b_traj.max(1);
    let spec = backend.model_spec("main")?.clone();
    // extraction sessions reserve prompt pages only; budget the in-flight
    // width with 4x slack so retired prefixes stay adoptable (LRU beyond)
    let mut kcfg = KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block.max(1),
        budget_bytes: 0,
    };
    kcfg.budget_bytes =
        kcfg.page_bytes() * 4 * width * kcfg.span_pages(c.s_train).max(1);
    let kv = SharedKvPool::new(kcfg);
    extract_all_pooled(backend, teacher, samples, cache_dir, label, width,
                       Some(&kv))
}

/// `extract_all` with an explicit interleaving width and (optionally) a
/// shared KV pool: samples sharing a prompt prefix then adopt each
/// other's prefilled teacher pages, and a full-prefix hit skips the
/// prompt-prefill forward. Width-1 output is token-for-token identical
/// to any wider schedule (`tests/props.rs`).
pub fn extract_all_pooled(backend: &dyn Backend, teacher: &[f32],
                          samples: &[Sample], cache_dir: impl AsRef<Path>,
                          label: &str, width: usize,
                          kv: Option<&SharedKvPool>) -> Result<Vec<Ranks>> {
    let c = backend.constants().clone();
    let s = c.s_train;

    invalidate_stale(cache_dir.as_ref(), label);
    let key = cache_key(&c, EXTRACT_VARIANT, teacher, samples);
    let path =
        cache_dir.as_ref().join(format!("traj_{label}_{key:016x}.bin"));
    if path.exists() {
        if let Ok(cached) = load_cache(&path, samples.len(), s) {
            eprintln!("[traj] cache hit: {path:?}");
            return Ok(cached);
        }
    }

    for sample in samples {
        let p = sample.prompt.len();
        if p + c.gen_train > s {
            bail!("prompt too long for trajectory extraction: {p}");
        }
    }

    let t0 = std::time::Instant::now();
    let results =
        run_pool_bounded(backend, teacher, samples.len(), width, |i| {
            teacher_session(backend, &samples[i], EXTRACT_VARIANT, kv)
        })?;

    let mut out: Vec<Ranks> = Vec::with_capacity(samples.len());
    for (sample, r) in samples.iter().zip(results) {
        let ranks = r.unmask_ranks.ok_or_else(|| {
            anyhow!("trajectory session returned no ranks")
        })?;
        let p = sample.prompt.len();
        let mut row = vec![c.rank_never; s];
        row[p..p + ranks.len()].copy_from_slice(&ranks);
        out.push(row);
    }
    eprintln!(
        "[traj] extracted {} trajectories ({width} wide) in {:.1}s",
        out.len(),
        t0.elapsed().as_secs_f64()
    );
    save_cache(&path, &out)?;
    Ok(out)
}

/// Exact on-device reference path: the batched whole-scan `trajectory`
/// executable (`Backend::trajectory`), chunked at `b_traj`. Uncached —
/// the pooled `extract_all` is the production path; this one exists for
/// cross-checks and for backends whose compiled scan is cheaper than
/// per-step forwards.
pub fn extract_on_device(backend: &dyn Backend, teacher: &[f32],
                         samples: &[Sample]) -> Result<Vec<Ranks>> {
    let c = backend.constants().clone();
    let (b, s) = (c.b_traj.max(1), c.s_train);
    let mut out: Vec<Ranks> = Vec::with_capacity(samples.len());
    for chunk in samples.chunks(b) {
        let mut tokens = vec![MASK; b * s];
        let mut attn_valid = vec![0.0f32; b * s];
        let mut gen_mask = vec![0.0f32; b * s];
        for (bi, sample) in chunk.iter().enumerate() {
            let p = sample.prompt.len();
            if p + c.gen_train > s {
                bail!("prompt too long for trajectory extraction: {p}");
            }
            tokens[bi * s..bi * s + p].copy_from_slice(&sample.prompt);
            for i in 0..p + c.gen_train {
                attn_valid[bi * s + i] = 1.0;
            }
            for i in p..p + c.gen_train {
                gen_mask[bi * s + i] = 1.0;
            }
        }
        let r = backend.trajectory(teacher, &tokens, &attn_valid,
                                   &gen_mask)?;
        for (bi, _) in chunk.iter().enumerate() {
            out.push(r.rank[bi * s..(bi + 1) * s].to_vec());
        }
    }
    Ok(out)
}

/// Cache identity: schema version, compile geometry (sequence/window
/// shapes, batch, vocab, block, exec family), the *full* teacher
/// parameter vector, and every corpus prompt. The old key hashed only
/// every 97th teacher float and the first 64 prompts, so two teachers
/// (or two corpora) could silently collide on one cache file.
fn cache_key(c: &Constants, variant: &str, teacher: &[f32],
             samples: &[Sample]) -> u64 {
    let mut h = 0xD3u64 ^ u64::from(CACHE_MAGIC[7]);
    for g in [c.s_train, c.gen_train, c.b_traj, c.vocab, c.block, c.window]
    {
        h = h.rotate_left(9) ^ g as u64;
    }
    h = h.rotate_left(9) ^ fnv1a(variant.as_bytes());
    let mut th: u64 = 0xcbf29ce484222325;
    for x in teacher {
        th ^= x.to_bits() as u64;
        th = th.wrapping_mul(0x100000001b3);
    }
    h = h.rotate_left(13) ^ th;
    for s in samples {
        let bytes: Vec<u8> =
            s.prompt.iter().flat_map(|t| t.to_le_bytes()).collect();
        h = h.rotate_left(7) ^ fnv1a(&bytes);
    }
    h ^ ((samples.len() as u64) << 48)
}

/// Drop `traj_{label}_*.bin` files written under an older schema magic
/// (or corrupted beyond recognition) so stale ranks can never be served
/// after a layout change.
fn invalidate_stale(cache_dir: &Path, label: &str) {
    let prefix = format!("traj_{label}_");
    let Ok(entries) = std::fs::read_dir(cache_dir) else { return };
    let mut dropped = 0usize;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with(&prefix) || !name.ends_with(".bin") {
            continue;
        }
        let mut magic = [0u8; 8];
        let fresh = std::fs::File::open(entry.path())
            .and_then(|mut f| f.read_exact(&mut magic))
            .is_ok()
            && &magic == CACHE_MAGIC;
        if !fresh && std::fs::remove_file(entry.path()).is_ok() {
            dropped += 1;
        }
    }
    if dropped > 0 {
        eprintln!(
            "[traj] dropped {dropped} stale-schema cache file(s) under \
             {cache_dir:?}"
        );
    }
}

fn save_cache(path: &Path, ranks: &[Ranks]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(CACHE_MAGIC)?;
    f.write_all(&(ranks.len() as u32).to_le_bytes())?;
    for r in ranks {
        let bytes: Vec<u8> = r.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

fn load_cache(path: &Path, n: usize, s: usize) -> Result<Vec<Ranks>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != CACHE_MAGIC {
        bail!("bad trajectory cache magic");
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    if u32::from_le_bytes(len4) as usize != n {
        bail!("trajectory cache holds a different corpus size");
    }
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() != n * s * 4 {
        bail!("trajectory cache truncated");
    }
    Ok(raw
        .chunks_exact(s * 4)
        .map(|chunk| {
            chunk
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::data::{train_corpus, Family};
    use crate::decode::SimBackend;
    use crate::tokenizer::Tokenizer;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("d3llm_traj_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn corpus(sim: &SimBackend, n: usize, seed: u64) -> Vec<Sample> {
        let tk = Tokenizer::new(sim.constants().vocab).unwrap();
        train_corpus(&tk, &[(Family::Gsm8k, 1.0)], n, seed)
    }

    #[test]
    fn pooled_extraction_caches_and_reloads_on_sim() {
        let sim = SimBackend::new(14);
        let c = sim.constants().clone();
        let corpus = corpus(&sim, 6, 3);
        let teacher = vec![0.37f32; 64];
        let dir = tmp_dir("cache_roundtrip");

        let first =
            extract_all(&sim, &teacher, &corpus, &dir, "test").unwrap();
        assert_eq!(first.len(), corpus.len());
        // gen ranks are a permutation; prompt ranks are NEVER
        for (sample, row) in corpus.iter().zip(&first) {
            let p = sample.prompt.len();
            let mut gen: Vec<i32> = row[p..p + c.gen_train].to_vec();
            gen.sort();
            assert_eq!(gen, (0..c.gen_train as i32).collect::<Vec<_>>());
            assert!(row[..p].iter().all(|&r| r == c.rank_never));
        }

        let calls_before = sim.prefill_calls() + sim.window_calls();
        let second =
            extract_all(&sim, &teacher, &corpus, &dir, "test").unwrap();
        assert_eq!(first, second, "cache must return identical ranks");
        assert_eq!(sim.prefill_calls() + sim.window_calls(), calls_before,
                   "cache hit must not re-run any forward");
    }

    #[test]
    fn pooled_extraction_reads_paged_views_identically_to_dense() {
        let sim = SimBackend::new(21);
        let corpus = corpus(&sim, 5, 9);
        let teacher = vec![0.31f32; 64];

        // dense caches (no pool binding) vs. page-table views bound to a
        // run-scoped pool: the teacher scan's windowed forwards read the
        // cache paged-natively, and the ranks must not move a bit
        let dense = extract_all_pooled(&sim, &teacher, &corpus,
                                       tmp_dir("pvd_dense"), "pd", 4, None)
            .unwrap();
        let spec = sim.model_spec("main").unwrap().clone();
        let c = sim.constants().clone();
        let kv = SharedKvPool::new(KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block.max(1),
            budget_bytes: 1 << 20,
        });
        let paged = extract_all_pooled(&sim, &teacher, &corpus,
                                       tmp_dir("pvd_paged"), "pp", 4,
                                       Some(&kv))
            .unwrap();
        assert_eq!(dense, paged,
                   "paged-native teacher scan diverged from dense ranks");
    }

    #[test]
    fn cache_key_separates_teachers_and_corpora() {
        let sim = SimBackend::new(2);
        let c = sim.constants().clone();
        let corpus_a = corpus(&sim, 4, 1);
        let corpus_b = corpus(&sim, 4, 2);
        let ta = vec![0.5f32; 64];
        let mut tb = ta.clone();
        tb[63] = 0.5000001; // old strided hash skipped this float
        let ka = cache_key(&c, EXTRACT_VARIANT, &ta, &corpus_a);
        assert_ne!(ka, cache_key(&c, EXTRACT_VARIANT, &tb, &corpus_a),
                   "every teacher float must be part of the key");
        assert_ne!(ka, cache_key(&c, EXTRACT_VARIANT, &ta, &corpus_b),
                   "corpus identity must be part of the key");
        assert_ne!(ka, cache_key(&c, "pallas", &ta, &corpus_a),
                   "exec family must be part of the key");
        let mut c2 = c.clone();
        c2.s_train += 1;
        assert_ne!(ka, cache_key(&c2, EXTRACT_VARIANT, &ta, &corpus_a),
                   "compile geometry must be part of the key");
    }

    #[test]
    fn stale_schema_cache_is_invalidated_on_open() {
        let sim = SimBackend::new(4);
        let corpus = corpus(&sim, 3, 7);
        let teacher = vec![0.2f32; 64];
        let dir = tmp_dir("stale_schema");
        // a v1-schema leftover under the same label
        let stale = dir.join("traj_test_00000000deadbeef.bin");
        std::fs::write(&stale, b"D3TRAJ01junkjunkjunk").unwrap();

        let out = extract_all(&sim, &teacher, &corpus, &dir, "test").unwrap();
        assert_eq!(out.len(), 3);
        assert!(!stale.exists(), "stale-schema file must be dropped");
    }
}
