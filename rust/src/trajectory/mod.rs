//! Pseudo-trajectory pipeline (paper §3.1): teacher decoding-order
//! extraction (with a disk cache), the noisy-sequence construction
//! equation, and the curriculum schedules.

pub mod curriculum;
pub mod noisy;

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::Sample;
use crate::model::exec;
use crate::runtime::Engine;
use crate::tokenizer::MASK;
use crate::util::fnv1a;

pub use curriculum::Curriculum;
pub use noisy::{build_noisy, NoisyExample, Recipe};

/// Teacher decoding ranks for one sample: rank[i] = step at which the
/// teacher unmasked training-sequence position i (RANK_NEVER elsewhere).
pub type Ranks = Vec<i32>;

/// Extract pseudo-trajectories for a corpus, batched through the on-device
/// `trajectory` executable. Results are cached on disk keyed by
/// (teacher checkpoint, corpus) content hashes — extraction runs once per
/// teacher and is reused by every distillation variant.
pub fn extract_all(eng: &Engine, teacher: &[f32], samples: &[Sample],
                   cache_dir: impl AsRef<Path>, label: &str)
                   -> Result<Vec<Ranks>> {
    let c = eng.manifest.constants.clone();
    let (b, s) = (c.b_traj, c.s_train);

    let key = cache_key(teacher, samples);
    let path = cache_dir.as_ref().join(format!("traj_{label}_{key:016x}.bin"));
    if path.exists() {
        if let Ok(cached) = load_cache(&path, samples.len(), s) {
            eprintln!("[traj] cache hit: {path:?}");
            return Ok(cached);
        }
    }

    let mut out: Vec<Ranks> = Vec::with_capacity(samples.len());
    let t0 = std::time::Instant::now();
    for chunk in samples.chunks(b) {
        let mut tokens = vec![MASK; b * s];
        let mut attn_valid = vec![0.0f32; b * s];
        let mut gen_mask = vec![0.0f32; b * s];
        for (bi, sample) in chunk.iter().enumerate() {
            let p = sample.prompt.len();
            if p + c.gen_train > s {
                bail!("prompt too long for trajectory extraction: {p}");
            }
            tokens[bi * s..bi * s + p].copy_from_slice(&sample.prompt);
            for i in 0..p + c.gen_train {
                attn_valid[bi * s + i] = 1.0;
            }
            for i in p..p + c.gen_train {
                gen_mask[bi * s + i] = 1.0;
            }
        }
        let r = exec::trajectory(eng, teacher, &tokens, &attn_valid,
                                 &gen_mask)?;
        for (bi, _) in chunk.iter().enumerate() {
            out.push(r.rank[bi * s..(bi + 1) * s].to_vec());
        }
    }
    eprintln!(
        "[traj] extracted {} trajectories in {:.1}s",
        out.len(),
        t0.elapsed().as_secs_f64()
    );
    save_cache(&path, &out)?;
    Ok(out)
}

fn cache_key(teacher: &[f32], samples: &[Sample]) -> u64 {
    // params: hash a strided sample (hashing 400k floats fully is fine too,
    // but this keeps corpus rebuilds cheap)
    let mut h = 0xD3u64;
    for (i, x) in teacher.iter().enumerate() {
        if i % 97 == 0 {
            h = h.rotate_left(13) ^ x.to_bits() as u64;
        }
    }
    for s in samples.iter().take(64) {
        let bytes: Vec<u8> =
            s.prompt.iter().flat_map(|t| t.to_le_bytes()).collect();
        h = h.rotate_left(7) ^ fnv1a(&bytes);
    }
    h ^ (samples.len() as u64) << 48
}

fn save_cache(path: &Path, ranks: &[Ranks]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"D3TRAJ01")?;
    f.write_all(&(ranks.len() as u32).to_le_bytes())?;
    for r in ranks {
        let bytes: Vec<u8> = r.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

fn load_cache(path: &Path, n: usize, s: usize) -> Result<Vec<Ranks>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != b"D3TRAJ01" {
        bail!("bad trajectory cache magic");
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    if u32::from_le_bytes(len4) as usize != n {
        bail!("trajectory cache holds a different corpus size");
    }
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() != n * s * 4 {
        bail!("trajectory cache truncated");
    }
    Ok(raw
        .chunks_exact(s * 4)
        .map(|chunk| {
            chunk
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        })
        .collect())
}

/// Default trajectory cache directory.
pub fn default_cache_dir() -> PathBuf {
    PathBuf::from("data/cache")
}
