//! Curriculum schedules (paper §3.1): the mask-ratio ramp ("curriculum
//! noise level", 0.0 -> 0.8) and the decoding-window ramp ("curriculum
//! window size", 16 -> 32), both linear in training progress.

/// Linear schedule between two endpoints over training progress [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Schedule {
    pub start: f64,
    pub end: f64,
}

impl Schedule {
    pub fn fixed(v: f64) -> Schedule {
        Schedule { start: v, end: v }
    }

    pub fn at(&self, progress: f64) -> f64 {
        let p = progress.clamp(0.0, 1.0);
        self.start + (self.end - self.start) * p
    }
}

/// Full curriculum configuration for a distillation run.
#[derive(Debug, Clone, Copy)]
pub struct Curriculum {
    /// mask ratio t
    pub noise: Schedule,
    /// decoding window length k (tokens)
    pub window: Schedule,
}

impl Curriculum {
    /// The paper's default: t 0.0 -> 0.8, k 16 -> 32.
    pub fn paper_default() -> Curriculum {
        Curriculum {
            noise: Schedule { start: 0.0, end: 0.8 },
            window: Schedule { start: 16.0, end: 32.0 },
        }
    }

    /// Ablation: no curricula (fixed t = 0.5, k = 32).
    pub fn fixed(t: f64, k: f64) -> Curriculum {
        Curriculum { noise: Schedule::fixed(t), window: Schedule::fixed(k) }
    }

    pub fn t_at(&self, progress: f64) -> f64 {
        self.noise.at(progress).clamp(0.0, 1.0)
    }

    pub fn k_at(&self, progress: f64) -> usize {
        (self.window.at(progress).round() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_ramp() {
        let s = Schedule { start: 0.0, end: 0.8 };
        assert_eq!(s.at(0.0), 0.0);
        assert!((s.at(0.5) - 0.4).abs() < 1e-12);
        assert!((s.at(1.0) - 0.8).abs() < 1e-12);
        assert!((s.at(2.0) - 0.8).abs() < 1e-12); // clamped
    }

    #[test]
    fn paper_defaults() {
        let c = Curriculum::paper_default();
        assert_eq!(c.k_at(0.0), 16);
        assert_eq!(c.k_at(1.0), 32);
        assert_eq!(c.t_at(0.0), 0.0);
        assert!((c.t_at(1.0) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn fixed_is_flat() {
        let c = Curriculum::fixed(0.5, 32.0);
        for p in [0.0, 0.3, 0.9] {
            assert_eq!(c.t_at(p), 0.5);
            assert_eq!(c.k_at(p), 32);
        }
    }
}
