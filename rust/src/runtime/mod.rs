//! PJRT runtime: manifest ABI + executable engine.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, TypedArgs};
pub use manifest::Manifest;
