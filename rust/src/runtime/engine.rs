//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once on the
//! CPU PJRT client, and executes them from the Rust request path.
//!
//! Design notes:
//!   * HLO **text** is the interchange format (see aot.py / DESIGN.md).
//!   * Executables are compiled lazily on first use and memoised, so a
//!     serving process only pays for the graphs its decode strategy needs.
//!   * `TypedArgs` validates every call against the manifest signature
//!     (shape, dtype, argument order) — a mismatched call fails loudly in
//!     the runtime instead of silently inside XLA.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::kv_cache::{KvStageStats, KvStaging};

use super::manifest::{ArgSpec, DType, ExecSpec, Manifest};

/// Per-executable call statistics (the L3 profiler reads these).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    /// Host-side time spent building input literals.
    pub upload_secs: f64,
}

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    executables: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    stats: RefCell<HashMap<String, ExecStats>>,
    /// Device-resident parameter buffers (perf: skip re-uploading the flat
    /// weight vector on every forward). Keyed by a content fingerprint.
    param_bufs: RefCell<HashMap<u64, PjRtBuffer>>,
    /// Hot-path toggle: route `run_buffered` through execute_b with the
    /// cached parameter buffer (default on; flip for A/B perf runs).
    buffered: std::cell::Cell<bool>,
    /// Reusable bounded staging scratch for paged KV views: windowed
    /// forwards against a `PagedKv` copy only the pages that changed
    /// since the scratch last held them, instead of re-gathering the full
    /// `[L, S_max, d_kv]` cache per call (see `model::kv_cache::KvStaging`).
    /// Dense caches bypass it entirely (borrow-only). Single-threaded
    /// interior mutability, like the executable cache above.
    kv_stage: RefCell<KvStaging>,
}

/// Non-parameter argument for the buffered hot path.
pub enum ArgData<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// Content fingerprint for a parameter vector (strided FNV — parameters
/// change only on checkpoint swaps, never mid-decode).
pub fn param_fingerprint(params: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325 ^ params.len() as u64;
    let stride = (params.len() / 64).max(1);
    for i in (0..params.len()).step_by(stride) {
        h ^= params[i].to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Engine {
    /// Load the manifest from `dir` and create a CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            dir,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            param_bufs: RefCell::new(HashMap::new()),
            buffered: std::cell::Cell::new(true),
            kv_stage: RefCell::new(KvStaging::new()),
        })
    }

    /// Borrow the paged-KV staging scratch (the `decode_window` wrapper
    /// stages paged views through it; dense views never touch it).
    pub fn kv_stage(&self) -> std::cell::RefMut<'_, KvStaging> {
        self.kv_stage.borrow_mut()
    }

    /// Cumulative staging counters (pages copied/reused, bytes staged).
    pub fn kv_stage_stats(&self) -> KvStageStats {
        self.kv_stage.borrow().stats()
    }

    /// Toggle the buffered (device-resident params + execute_b) hot path.
    pub fn set_buffered(&self, on: bool) {
        self.buffered.set(on);
    }

    pub fn buffered(&self) -> bool {
        self.buffered.get()
    }

    /// Drop cached device parameter buffers (e.g. after a checkpoint swap
    /// storm in tests).
    pub fn clear_param_cache(&self) {
        self.param_bufs.borrow_mut().clear();
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether the loaded artifact set ships an executable by this name.
    /// Wrappers probe this to pick the paged/batched lowering when the
    /// manifest has one and fall back to the staged/per-item path for
    /// older (v1) artifact dirs.
    pub fn has_executable(&self, name: &str) -> bool {
        self.manifest.executables.contains_key(name)
    }

    /// Compile (or fetch memoised) executable by manifest name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.exec(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e:?}"))?;
        eprintln!(
            "[engine] compiled `{name}` in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of executables (used by the server at startup so
    /// first-request latency is not a compile).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute `name` with validated inputs; returns decomposed outputs.
    pub fn run(&self, name: &str, args: TypedArgs) -> Result<Vec<Literal>> {
        let spec = self.manifest.exec(name)?.clone();
        args.validate(&spec)?;
        self.ensure_compiled(name)?;

        let t0 = Instant::now();
        let outputs = {
            let execs = self.executables.borrow();
            let exe = execs.get(name).unwrap();
            let result = exe
                .execute::<Literal>(&args.literals)
                .map_err(|e| anyhow!("executing `{name}`: {e:?}"))?;
            result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching `{name}` output: {e:?}"))?
        };
        let elapsed = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.borrow_mut();
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.total_secs += elapsed;
            s.upload_secs += args.upload_secs;
        }

        // Graphs are lowered with return_tuple=True: decompose.
        let parts = outputs
            .to_tuple()
            .map_err(|e| anyhow!("`{name}` output not a tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "`{name}` returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Buffered hot path: params live on device (uploaded once per
    /// checkpoint), remaining args go straight to device buffers, and the
    /// graph runs via execute_b — no Literal round-trip on the inputs.
    pub fn run_buffered(&self, name: &str, params: &[f32],
                        rest: &[ArgData]) -> Result<Vec<Literal>> {
        let spec = self.manifest.exec(name)?.clone();
        if rest.len() + 1 != spec.inputs.len() {
            bail!("`{name}` expects {} inputs, got {}", spec.inputs.len(),
                  rest.len() + 1);
        }
        if spec.inputs[0].shape != [params.len()] {
            bail!("`{name}` param length mismatch");
        }
        self.ensure_compiled(name)?;

        let t_up = Instant::now();
        // ---- cached device-resident parameter buffer
        let key = param_fingerprint(params);
        if !self.param_bufs.borrow().contains_key(&key) {
            let buf = self
                .client
                .buffer_from_host_buffer(params, &[params.len()], None)
                .map_err(|e| anyhow!("param upload: {e:?}"))?;
            self.param_bufs.borrow_mut().insert(key, buf);
        }
        // ---- fresh buffers for the per-call arguments
        let mut fresh: Vec<PjRtBuffer> = Vec::with_capacity(rest.len());
        for (i, arg) in rest.iter().enumerate() {
            let want = &spec.inputs[i + 1];
            let buf = match arg {
                ArgData::F32(data, shape) => {
                    if want.dtype != DType::F32 || want.shape != *shape {
                        bail!("`{name}` arg {} shape/dtype mismatch", i + 1);
                    }
                    self.client.buffer_from_host_buffer(data, shape, None)
                }
                ArgData::I32(data, shape) => {
                    if want.dtype != DType::I32 || want.shape != *shape {
                        bail!("`{name}` arg {} shape/dtype mismatch", i + 1);
                    }
                    self.client.buffer_from_host_buffer(data, shape, None)
                }
            }
            .map_err(|e| anyhow!("arg upload: {e:?}"))?;
            fresh.push(buf);
        }
        let upload = t_up.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let outputs = {
            let bufs = self.param_bufs.borrow();
            let pbuf = bufs.get(&key).unwrap();
            let mut all: Vec<&PjRtBuffer> = Vec::with_capacity(rest.len() + 1);
            all.push(pbuf);
            all.extend(fresh.iter());
            let execs = self.executables.borrow();
            let exe = execs.get(name).unwrap();
            let result = exe
                .execute_b::<&PjRtBuffer>(&all)
                .map_err(|e| anyhow!("executing `{name}` (buffered): {e:?}"))?;
            result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching `{name}` output: {e:?}"))?
        };
        let elapsed = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.borrow_mut();
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.total_secs += elapsed + upload;
            s.upload_secs += upload;
        }

        let parts = outputs
            .to_tuple()
            .map_err(|e| anyhow!("`{name}` output not a tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("`{name}` returned {} outputs, manifest says {}",
                  parts.len(), spec.outputs.len());
        }
        Ok(parts)
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}

/// Input builder that records host-side upload cost and validates against
/// the manifest signature.
pub struct TypedArgs {
    literals: Vec<Literal>,
    kinds: Vec<(Vec<usize>, DType)>,
    upload_secs: f64,
}

impl TypedArgs {
    pub fn new() -> Self {
        TypedArgs { literals: Vec::new(), kinds: Vec::new(), upload_secs: 0.0 }
    }

    pub fn f32(mut self, data: &[f32], shape: &[usize]) -> Result<Self> {
        let t0 = Instant::now();
        let lit = literal_f32(data, shape)?;
        self.upload_secs += t0.elapsed().as_secs_f64();
        self.literals.push(lit);
        self.kinds.push((shape.to_vec(), DType::F32));
        Ok(self)
    }

    pub fn i32(mut self, data: &[i32], shape: &[usize]) -> Result<Self> {
        let t0 = Instant::now();
        let lit = literal_i32(data, shape)?;
        self.upload_secs += t0.elapsed().as_secs_f64();
        self.literals.push(lit);
        self.kinds.push((shape.to_vec(), DType::I32));
        Ok(self)
    }

    pub fn scalar_f32(mut self, x: f32) -> Self {
        self.literals.push(Literal::scalar(x));
        self.kinds.push((vec![], DType::F32));
        self
    }

    pub fn scalar_i32(mut self, x: i32) -> Self {
        self.literals.push(Literal::scalar(x));
        self.kinds.push((vec![], DType::I32));
        self
    }

    fn validate(&self, spec: &ExecSpec) -> Result<()> {
        if self.kinds.len() != spec.inputs.len() {
            bail!(
                "`{}` expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                self.kinds.len()
            );
        }
        for (i, (got, want)) in
            self.kinds.iter().zip(spec.inputs.iter()).enumerate()
        {
            if got.0 != want.shape || got.1 != want.dtype {
                bail!(
                    "`{}` arg {i} (`{}`): got {:?}/{:?}, manifest wants {:?}/{:?}",
                    spec.name,
                    want.name,
                    got.0,
                    got.1,
                    want.shape,
                    want.dtype
                );
            }
        }
        Ok(())
    }
}

impl Default for TypedArgs {
    fn default() -> Self {
        Self::new()
    }
}

// ----------------------------------------------------------------- literals

pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("literal_f32: data len {} != shape {:?}", data.len(), shape);
    }
    let lit = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("literal_i32: data len {} != shape {:?}", data.len(), shape);
    }
    let lit = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Read a literal back as Vec<f32>, validating the element count.
pub fn to_vec_f32(lit: &Literal, spec: &ArgSpec) -> Result<Vec<f32>> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("reading `{}`: {e:?}", spec.name))?;
    if v.len() != spec.elements() {
        bail!("`{}`: got {} elements, want {}", spec.name, v.len(),
              spec.elements());
    }
    Ok(v)
}

pub fn to_vec_i32(lit: &Literal, spec: &ArgSpec) -> Result<Vec<i32>> {
    let v = lit
        .to_vec::<i32>()
        .map_err(|e| anyhow!("reading `{}`: {e:?}", spec.name))?;
    if v.len() != spec.elements() {
        bail!("`{}`: got {} elements, want {}", spec.name, v.len(),
              spec.elements());
    }
    Ok(v)
}

pub fn scalar_f32_out(lit: &Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("scalar out: {e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar"))
}
