//! artifacts/manifest.json loader — the ABI between the Python AOT build
//! and this runtime. Every shape, dtype, argument order and compile-time
//! constant the executables were lowered with is recorded there; the Rust
//! side validates against it instead of assuming.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct Constants {
    pub vocab: usize,
    pub pad_id: i32,
    pub mask_id: i32,
    pub eos_id: i32,
    pub bos_id: i32,
    pub sep_id: i32,
    pub s_max: usize,
    pub s_train: usize,
    pub gen_max: usize,
    pub gen_train: usize,
    pub window: usize,
    pub block: usize,
    pub verify_w: usize,
    pub b_train: usize,
    pub b_traj: usize,
    pub rank_never: i32,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: String,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub s_max: usize,
    pub d_kv: usize,
    pub total_params: usize,
    pub param_layout: Vec<TensorSpec>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Page-table geometry a paged executable was lowered with (manifest
/// format_version >= 2). The runtime refuses to feed a page table whose
/// layout disagrees with this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagedAbi {
    /// Rows per packed KV-page entry.
    pub page_rows: usize,
    /// Page-table length (packed entries per sequence).
    pub max_pages: usize,
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    /// Lowered batch size of a B>1 executable (format_version >= 2);
    /// `None` = unbatched.
    pub batch: Option<usize>,
    /// Page-table ABI of a paged executable (format_version >= 2);
    /// `None` = consumes dense cache buffers.
    pub paged: Option<PagedAbi>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub constants: Constants,
    pub models: BTreeMap<String, ModelSpec>,
    pub executables: BTreeMap<String, ExecSpec>,
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("field `{key}` is not a number"))
}

fn get_i32(j: &Json, key: &str) -> Result<i32> {
    Ok(j.req(key)?
        .as_i64()
        .ok_or_else(|| anyhow!("field `{key}` is not a number"))? as i32)
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("field `{key}` is not a string"))?
        .to_string())
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

fn parse_arg(j: &Json) -> Result<ArgSpec> {
    let dtype = match get_str(j, "dtype")?.as_str() {
        "f32" => DType::F32,
        "i32" => DType::I32,
        other => bail!("unsupported dtype `{other}`"),
    };
    Ok(ArgSpec {
        name: get_str(j, "name")?,
        shape: parse_shape(j.req("shape")?)?,
        dtype,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        // v1: dense/per-item executables only. v2 adds optional per-
        // executable `batch` / `paged` ABI fields; their *absence* (or a
        // v1 manifest) means the runtime keeps its per-item and staged
        // fallback paths, so old artifact dirs keep loading unchanged.
        let version = get_usize(&j, "format_version")?;
        if !(1..=2).contains(&version) {
            bail!("unsupported manifest format_version {version}");
        }

        let c = j.req("constants")?;
        let constants = Constants {
            vocab: get_usize(c, "vocab")?,
            pad_id: get_i32(c, "pad_id")?,
            mask_id: get_i32(c, "mask_id")?,
            eos_id: get_i32(c, "eos_id")?,
            bos_id: get_i32(c, "bos_id")?,
            sep_id: get_i32(c, "sep_id")?,
            s_max: get_usize(c, "s_max")?,
            s_train: get_usize(c, "s_train")?,
            gen_max: get_usize(c, "gen_max")?,
            gen_train: get_usize(c, "gen_train")?,
            window: get_usize(c, "window")?,
            block: get_usize(c, "block")?,
            verify_w: get_usize(c, "verify_w")?,
            b_train: get_usize(c, "b_train")?,
            b_traj: get_usize(c, "b_traj")?,
            rank_never: get_i32(c, "rank_never")?,
        };

        let mut models = BTreeMap::new();
        for (name, m) in j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models is not an object"))?
        {
            let mut layout = Vec::new();
            let mut expect_offset = 0usize;
            for t in m
                .req("param_layout")?
                .as_arr()
                .ok_or_else(|| anyhow!("param_layout not array"))?
            {
                let spec = TensorSpec {
                    name: get_str(t, "name")?,
                    shape: parse_shape(t.req("shape")?)?,
                    offset: get_usize(t, "offset")?,
                    size: get_usize(t, "size")?,
                    init: get_str(t, "init")?,
                };
                if spec.offset != expect_offset {
                    bail!("param layout hole at `{}`", spec.name);
                }
                if spec.size != spec.shape.iter().product::<usize>() {
                    bail!("param size mismatch at `{}`", spec.name);
                }
                expect_offset += spec.size;
                layout.push(spec);
            }
            let spec = ModelSpec {
                name: name.clone(),
                d_model: get_usize(m, "d_model")?,
                n_layers: get_usize(m, "n_layers")?,
                n_heads: get_usize(m, "n_heads")?,
                d_head: get_usize(m, "d_head")?,
                d_ff: get_usize(m, "d_ff")?,
                vocab: get_usize(m, "vocab")?,
                s_max: get_usize(m, "s_max")?,
                d_kv: get_usize(m, "d_kv")?,
                total_params: get_usize(m, "total_params")?,
                param_layout: layout,
            };
            if spec.total_params != expect_offset {
                bail!("model `{name}` total_params != layout sum");
            }
            if spec.d_kv != spec.n_heads * spec.d_head {
                bail!("model `{name}` d_kv mismatch");
            }
            models.insert(name.clone(), spec);
        }

        let mut executables = BTreeMap::new();
        for e in j
            .req("executables")?
            .as_arr()
            .ok_or_else(|| anyhow!("executables not array"))?
        {
            let batch = match e.get("batch") {
                Some(b) => Some(
                    b.as_usize()
                        .ok_or_else(|| anyhow!("`batch` is not a number"))?,
                ),
                None => None,
            };
            let paged = match e.get("paged") {
                Some(p) => Some(PagedAbi {
                    page_rows: get_usize(p, "page_rows")?,
                    max_pages: get_usize(p, "max_pages")?,
                }),
                None => None,
            };
            let spec = ExecSpec {
                name: get_str(e, "name")?,
                file: get_str(e, "file")?,
                model: get_str(e, "model")?,
                inputs: e
                    .req("inputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("inputs not array"))?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<_>>()?,
                outputs: e
                    .req("outputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("outputs not array"))?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<_>>()?,
                batch,
                paged,
            };
            if !models.contains_key(&spec.model) {
                bail!("executable `{}` references unknown model", spec.name);
            }
            if version < 2 && (spec.batch.is_some() || spec.paged.is_some()) {
                bail!("executable `{}`: batch/paged ABI fields require \
                       manifest format_version 2", spec.name);
            }
            if spec.batch == Some(0) {
                bail!("executable `{}`: batch size 0", spec.name);
            }
            if let Some(p) = spec.paged {
                if p.page_rows == 0 || p.max_pages == 0 {
                    bail!("executable `{}`: degenerate paged geometry \
                           {}x{}", spec.name, p.max_pages, p.page_rows);
                }
            }
            executables.insert(spec.name.clone(), spec);
        }

        Ok(Manifest { constants, models, executables })
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable `{name}`"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format_version": 1,
      "constants": {"vocab":128,"pad_id":0,"mask_id":1,"eos_id":2,"bos_id":3,
        "sep_id":4,"s_max":384,"s_train":192,"gen_max":128,"gen_train":96,
        "window":96,"block":32,"verify_w":16,"b_train":8,"b_traj":8,
        "rank_never":100000},
      "models": {"main": {"name":"main","d_model":4,"n_layers":1,"n_heads":2,
        "d_head":2,"d_ff":8,"vocab":128,"s_max":384,"d_kv":4,
        "total_params":12,
        "param_layout":[
          {"name":"a","shape":[2,3],"offset":0,"size":6,"init":"normal"},
          {"name":"b","shape":[6],"offset":6,"size":6,"init":"zeros"}]}},
      "executables": [{"name":"x","file":"x.hlo.txt","model":"main",
        "inputs":[{"name":"p","shape":[12],"dtype":"f32"}],
        "outputs":[{"name":"o","shape":[],"dtype":"i32"}]}]
    }"#;

    #[test]
    fn parses_minimal() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.constants.block, 32);
        assert_eq!(m.models["main"].total_params, 12);
        assert_eq!(m.executables["x"].inputs[0].elements(), 12);
        assert_eq!(m.executables["x"].outputs[0].elements(), 1);
    }

    #[test]
    fn rejects_layout_hole() {
        let bad = MINI.replace("\"offset\":6", "\"offset\":7");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = MINI.replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_model_ref() {
        let bad = MINI.replace("\"model\":\"main\"", "\"model\":\"nope\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn v1_specs_default_to_unbatched_dense() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.executables["x"].batch, None);
        assert_eq!(m.executables["x"].paged, None);
    }

    /// MINI upgraded to v2 with batch/paged ABI fields on the executable.
    fn mini_v2() -> String {
        MINI.replace("\"format_version\": 1", "\"format_version\": 2")
            .replace(
                "\"model\":\"main\",",
                "\"model\":\"main\",\"batch\":4,\
                 \"paged\":{\"page_rows\":32,\"max_pages\":12},",
            )
    }

    #[test]
    fn parses_v2_batch_and_paged_fields() {
        let m = Manifest::parse(&mini_v2()).unwrap();
        let x = &m.executables["x"];
        assert_eq!(x.batch, Some(4));
        assert_eq!(x.paged, Some(PagedAbi { page_rows: 32, max_pages: 12 }));
    }

    #[test]
    fn rejects_v2_fields_on_v1_manifest() {
        let bad = mini_v2().replace("\"format_version\": 2", "\"format_version\": 1");
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("format_version 2"), "{err}");
    }

    #[test]
    fn rejects_degenerate_paged_geometry() {
        let bad = mini_v2().replace("\"page_rows\":32", "\"page_rows\":0");
        assert!(Manifest::parse(&bad).is_err());
        let bad = mini_v2().replace("\"max_pages\":12", "\"max_pages\":0");
        assert!(Manifest::parse(&bad).is_err());
        let bad = mini_v2().replace("\"batch\":4", "\"batch\":0");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_malformed_paged_object() {
        let bad = mini_v2().replace("\"max_pages\":12", "\"max_pages\":\"twelve\"");
        assert!(Manifest::parse(&bad).is_err());
        // paged object missing a required key
        let bad = mini_v2().replace(",\"max_pages\":12", "");
        assert!(Manifest::parse(&bad).is_err());
    }
}
