//! Training / distillation driver: drives the fused train-step forwards
//! through the `Backend` abstraction, so the identical pipeline runs on
//! the PJRT `Engine` (AOT executables; Python never sees a weight) and on
//! the deterministic `SimBackend` (closed-form update; end-to-end CI
//! coverage in `tests/distill_e2e.rs`).

pub mod presets;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::data::{train_corpus, Family, Sample};
use crate::decode::Backend;
use crate::model::{OptState, ParamStore};
use crate::tokenizer::Tokenizer;
use crate::trajectory::{self, build_noisy, Curriculum, Recipe};
use crate::util::rng::Rng;

/// One training run (a named checkpoint).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// checkpoint name: saved to checkpoints/<name>.ckpt
    pub name: String,
    /// "main" or "draft"
    pub model: String,
    pub recipe: Recipe,
    pub curriculum: Curriculum,
    pub steps: usize,
    pub lr: f32,
    /// certainty-forcing entropy regulariser weight
    pub ent_weight: f32,
    pub corpus_size: usize,
    pub mixture: Vec<(Family, f64)>,
    pub seed: u64,
    /// initialise student weights from this checkpoint
    pub init_from: Option<String>,
    /// teacher checkpoint for pseudo-trajectory extraction
    pub teacher: Option<String>,
    pub log_every: usize,
}

impl TrainCfg {
    pub fn ckpt_path(dir: &Path, name: &str) -> PathBuf {
        dir.join(format!("{name}.ckpt"))
    }
}

/// Progress record for loss curves (bench/figures reads these).
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub t: f64,
    pub k: usize,
}

pub struct TrainOutcome {
    pub params: ParamStore,
    pub log: Vec<StepLog>,
}

/// Run one training job; saves the checkpoint and returns the loss log.
pub fn train(backend: &dyn Backend, cfg: &TrainCfg, ckpt_dir: &Path)
             -> Result<TrainOutcome> {
    let c = backend.constants().clone();
    let spec = backend.model_spec(&cfg.model)?.clone();
    let tk = Tokenizer::new(c.vocab)?;

    let exec_name = match (cfg.recipe, cfg.model.as_str()) {
        (Recipe::ArLm, "main") => "train_ar",
        (Recipe::ArLm, "draft") => "draft_train_ar",
        (Recipe::ArLm, m) => bail!("no AR train exec for model `{m}`"),
        (_, "main") => "train_diff",
        (_, m) => bail!("no diffusion train exec for model `{m}`"),
    };

    // ---- corpus
    let corpus: Vec<Sample> =
        train_corpus(&tk, &cfg.mixture, cfg.corpus_size, cfg.seed);

    // ---- weights
    let mut params = match &cfg.init_from {
        Some(name) => {
            let p = ParamStore::load(TrainCfg::ckpt_path(ckpt_dir, name))?;
            p.check(&spec)?;
            eprintln!("[train:{}] init from `{name}`", cfg.name);
            p
        }
        None => ParamStore::init(&spec, cfg.seed ^ 0x1111),
    };

    // ---- pseudo-trajectories (cached per teacher+corpus; the cache
    // lives next to the checkpoints so runs stay hermetic, and the
    // extraction sessions interleave through the serving scheduler)
    let ranks = if cfg.recipe == Recipe::PseudoTraj {
        let tname = cfg
            .teacher
            .as_ref()
            .ok_or_else(|| anyhow!("PseudoTraj requires a teacher"))?;
        let teacher = ParamStore::load(TrainCfg::ckpt_path(ckpt_dir, tname))?;
        teacher.check(&spec)?;
        Some(trajectory::extract_all(
            backend,
            &teacher.data,
            &corpus,
            ckpt_dir.join("traj-cache"),
            tname,
        )?)
    } else {
        None
    };

    // ---- loop
    let (b, s) = (c.b_train, c.s_train);
    let mut opt = OptState::new(params.data.len());
    let mut rng = Rng::new(cfg.seed ^ 0xBEEF);
    let mut order: Vec<usize> = (0..corpus.len()).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;
    let mut log = Vec::with_capacity(cfg.steps);
    let t0 = std::time::Instant::now();

    // Fused chunking: when the backend has a K-step fused lowering for
    // this objective, K batches are staged up front as `[K, B, s_train]`
    // and one call applies K sequential optimizer steps — batch
    // construction depends only on the rng/curriculum stream, never on
    // updated weights, so the schedule is arithmetically the per-step
    // loop it replaces. A tail shorter than K (and every backend without
    // the lowering) runs per-step.
    let fused_k = backend.fused_train_chunk(exec_name).filter(|&k| k >= 2);
    let mut step = 1usize;
    while step <= cfg.steps {
        let remaining = cfg.steps - step + 1;
        let nsteps = fused_k.filter(|&k| k <= remaining).unwrap_or(1);

        let mut tks = Vec::with_capacity(nsteps);
        let mut tokens = Vec::with_capacity(nsteps * b * s);
        let mut labels = Vec::with_capacity(nsteps * b * s);
        let mut loss_mask = Vec::with_capacity(nsteps * b * s);
        let mut attn_valid = Vec::with_capacity(nsteps * b * s);
        for i in 0..nsteps {
            let progress =
                (step + i - 1) as f64 / (cfg.steps.max(2) - 1) as f64;
            let t = cfg.curriculum.t_at(progress);
            let k = cfg.curriculum.k_at(progress);
            tks.push((t, k));
            for _ in 0..b {
                if cursor >= order.len() {
                    rng.shuffle(&mut order);
                    cursor = 0;
                }
                let idx = order[cursor];
                cursor += 1;
                let ex = build_noisy(
                    &corpus[idx],
                    cfg.recipe,
                    ranks.as_ref().map(|r| &r[idx]),
                    t,
                    k,
                    &c,
                    &mut rng,
                );
                tokens.extend(ex.tokens);
                labels.extend(ex.labels);
                loss_mask.extend(ex.loss_mask);
                attn_valid.extend(ex.attn_valid);
            }
        }

        let losses = if nsteps > 1 {
            let out = backend.train_step_fused(
                exec_name, nsteps, &params.data, &opt.m, &opt.v,
                step as i32, &tokens, &labels, &loss_mask, &attn_valid,
                cfg.lr, cfg.ent_weight,
            )?;
            params.data = out.params;
            opt.m = out.m;
            opt.v = out.v;
            out.loss
        } else {
            let out = backend.train_step(
                exec_name, &params.data, &opt.m, &opt.v, step as i32,
                &tokens, &labels, &loss_mask, &attn_valid, cfg.lr,
                cfg.ent_weight,
            )?;
            params.data = out.params;
            opt.m = out.m;
            opt.v = out.v;
            vec![out.loss]
        };
        opt.step = (step + nsteps - 1) as i32;

        for (i, &loss) in losses.iter().enumerate() {
            let (t, k) = tks[i];
            let s_i = step + i;
            log.push(StepLog { step: s_i, loss, t, k });
            if cfg.log_every > 0 && s_i % cfg.log_every == 0 {
                eprintln!(
                    "[train:{}] step {s_i}/{} loss {:.4} t={:.2} k={k} \
                     ({:.1}s)",
                    cfg.name,
                    cfg.steps,
                    loss,
                    t,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        step += nsteps;
    }

    let path = TrainCfg::ckpt_path(ckpt_dir, &cfg.name);
    params.save(&path)?;
    eprintln!(
        "[train:{}] saved {path:?} after {} steps ({:.1}s)",
        cfg.name,
        cfg.steps,
        t0.elapsed().as_secs_f64()
    );
    Ok(TrainOutcome { params, log })
}

/// Write a loss-curve CSV next to the results.
pub fn save_log(log: &[StepLog], path: impl AsRef<Path>) -> Result<()> {
    let rows: Vec<Vec<String>> = log
        .iter()
        .map(|l| {
            vec![l.step.to_string(), format!("{:.6}", l.loss),
                 format!("{:.3}", l.t), l.k.to_string()]
        })
        .collect();
    crate::util::write_csv(path, &["step", "loss", "t", "k"], &rows)
}
