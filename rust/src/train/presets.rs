//! Named training runs: every checkpoint the paper's tables need, with
//! dependency ordering (`plan()` returns a topologically valid sequence).
//!
//! Family map (paper model -> checkpoint):
//!   LLaDA            -> llada-teacher    (masked-diffusion from scratch)
//!   Dream            -> dream-teacher    (AR-init, then diffusion)
//!   Dream-Coder      -> coder-teacher    (diffusion on the code corpus)
//!   Qwen-2.5-it      -> ar-sim           (causal LM)
//!   EAGLE-3 draft    -> draft            (tiny causal LM)
//!   d3LLM-*          -> d3llm-*          (pseudo-trajectory distillation)
//!   dParallel-*      -> dparallel-*      (certainty-forcing random-mask)
//!   Fast-dLLM-v2     -> fastdllm-v2      (AR-init block-diffusion finetune)
//! plus the ablation / hyperparameter variants of Tables 5-7.

use crate::data::{coder_mixture, main_mixture};
use crate::trajectory::{curriculum::Schedule, Curriculum, Recipe};

use super::TrainCfg;

fn base(name: &str) -> TrainCfg {
    TrainCfg {
        name: name.to_string(),
        model: "main".to_string(),
        recipe: Recipe::PseudoTraj,
        curriculum: Curriculum::paper_default(),
        steps: 300,
        lr: 3e-3,
        ent_weight: 0.0,
        corpus_size: 384,
        mixture: main_mixture(),
        seed: 0xD3,
        init_from: None,
        teacher: None,
        log_every: 50,
    }
}

/// The full checkpoint plan in dependency order.
pub fn plan(fast: bool) -> Vec<TrainCfg> {
    let scale = if fast { 4 } else { 1 };
    let teacher_steps = 1600 / scale;
    let student_steps = 320 / scale;
    // lr: 6e-3 converges ~4x faster than 2.5e-3 at this scale (measured);
    // students fine-tune from a teacher and use a gentler 3e-3.
    let teacher_lr = 6e-3;
    let student_lr = 3e-3;
    let _ = student_lr;

    let mut out: Vec<TrainCfg> = Vec::new();

    // ---- foundations
    // AR training destabilises above ~3e-3 at this scale (measured);
    // masked-diffusion tolerates (and benefits from) 6e-3.
    out.push(TrainCfg {
        recipe: Recipe::ArLm,
        steps: (teacher_steps * 5) / 4,
        lr: 2.5e-3,
        corpus_size: 768,
        ..base("ar-sim")
    });
    out.push(TrainCfg {
        model: "draft".into(),
        recipe: Recipe::ArLm,
        steps: teacher_steps / 2,
        lr: 2.5e-3,
        corpus_size: 768,
        ..base("draft")
    });
    out.push(TrainCfg {
        recipe: Recipe::DiffusionPretrain,
        steps: teacher_steps,
        lr: teacher_lr,
        corpus_size: 768,
        ..base("llada-teacher")
    });
    out.push(TrainCfg {
        recipe: Recipe::DiffusionPretrain,
        steps: (teacher_steps * 5) / 8,
        lr: teacher_lr,
        corpus_size: 768,
        init_from: Some("ar-sim".into()),
        ..base("dream-teacher")
    });
    out.push(TrainCfg {
        recipe: Recipe::DiffusionPretrain,
        steps: (teacher_steps * 3) / 4,
        lr: teacher_lr,
        corpus_size: 768,
        mixture: coder_mixture(),
        ..base("coder-teacher")
    });

    // ---- main distilled students (Tables 1, 2, 8)
    for (student, teacher, mixture, ent) in [
        ("d3llm-llada", "llada-teacher", main_mixture(), 0.2),
        ("d3llm-dream", "dream-teacher", main_mixture(), 0.1),
        ("d3llm-coder", "coder-teacher", coder_mixture(), 0.1),
    ] {
        out.push(TrainCfg {
            recipe: Recipe::PseudoTraj,
            steps: student_steps,
            ent_weight: ent,
            mixture,
            init_from: Some(teacher.into()),
            teacher: Some(teacher.into()),
            ..base(student)
        });
    }

    // ---- contender students
    for (student, teacher, ent) in [
        ("dparallel-llada", "llada-teacher", 0.2),
        ("dparallel-dream", "dream-teacher", 0.1),
    ] {
        out.push(TrainCfg {
            recipe: Recipe::RandomMask,
            steps: student_steps,
            ent_weight: ent,
            init_from: Some(teacher.into()),
            ..base(student)
        });
    }
    // Fast-dLLM-v2: AR model adapted into a block-diffusion model
    out.push(TrainCfg {
        recipe: Recipe::RandomMask,
        curriculum: Curriculum::fixed(0.5, 32.0),
        steps: student_steps,
        init_from: Some("ar-sim".into()),
        ..base("fastdllm-v2")
    });

    // ---- Table 5 ablation checkpoints (distillation recipe column)
    // row 2: pseudo-trajectory only (no curricula)
    out.push(TrainCfg {
        recipe: Recipe::PseudoTraj,
        curriculum: Curriculum::fixed(0.5, 32.0),
        steps: student_steps,
        ent_weight: 0.2,
        init_from: Some("llada-teacher".into()),
        teacher: Some("llada-teacher".into()),
        ..base("ablate-pt")
    });
    // row 3: + curriculum noise (window still fixed)
    out.push(TrainCfg {
        recipe: Recipe::PseudoTraj,
        curriculum: Curriculum {
            noise: Schedule { start: 0.0, end: 0.8 },
            window: Schedule::fixed(32.0),
        },
        steps: student_steps,
        ent_weight: 0.2,
        init_from: Some("llada-teacher".into()),
        teacher: Some("llada-teacher".into()),
        ..base("ablate-pt-noise")
    });

    // ---- Table 6 noise-schedule sweep (full model uses 0.0 -> 0.8)
    for (name, s0, s1) in [
        ("noise-fixed-05", 0.5, 0.5),
        ("noise-02-05", 0.2, 0.5),
        ("noise-00-05", 0.0, 0.5),
    ] {
        out.push(TrainCfg {
            recipe: Recipe::PseudoTraj,
            curriculum: Curriculum {
                noise: Schedule { start: s0, end: s1 },
                window: Schedule { start: 16.0, end: 32.0 },
            },
            steps: student_steps,
            ent_weight: 0.2,
            init_from: Some("llada-teacher".into()),
            teacher: Some("llada-teacher".into()),
            ..base(name)
        });
    }

    // ---- Table 7 window-schedule sweep (full model uses 16 -> 32)
    // "fixed k=32" with the noise curriculum is exactly `ablate-pt-noise`;
    // Table 7 reuses that checkpoint instead of retraining it.
    for (name, k0, k1) in [
        ("win-00-32", 1.0, 32.0),
        ("win-24-32", 24.0, 32.0),
    ] {
        out.push(TrainCfg {
            recipe: Recipe::PseudoTraj,
            curriculum: Curriculum {
                noise: Schedule { start: 0.0, end: 0.8 },
                window: Schedule { start: k0, end: k1 },
            },
            steps: student_steps,
            ent_weight: 0.2,
            init_from: Some("llada-teacher".into()),
            teacher: Some("llada-teacher".into()),
            ..base(name)
        });
    }

    out
}

/// Look up one preset by name.
pub fn by_name(name: &str, fast: bool) -> Option<TrainCfg> {
    plan(fast).into_iter().find(|c| c.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_dependency_ordered() {
        let plan = plan(false);
        let mut seen = std::collections::HashSet::new();
        for cfg in &plan {
            if let Some(dep) = &cfg.init_from {
                assert!(seen.contains(dep.as_str()), "{} before {dep}",
                        cfg.name);
            }
            if let Some(dep) = &cfg.teacher {
                assert!(seen.contains(dep.as_str()), "{} before {dep}",
                        cfg.name);
            }
            seen.insert(cfg.name.clone());
        }
    }

    #[test]
    fn names_unique_and_complete() {
        let plan = plan(false);
        let names: Vec<&str> = plan.iter().map(|c| c.name.as_str()).collect();
        let set: std::collections::HashSet<&&str> = names.iter().collect();
        assert_eq!(set.len(), names.len());
        for required in ["ar-sim", "draft", "llada-teacher", "dream-teacher",
                         "coder-teacher", "d3llm-llada", "d3llm-dream",
                         "d3llm-coder", "dparallel-llada", "dparallel-dream",
                         "fastdllm-v2", "ablate-pt", "ablate-pt-noise",
                         "noise-fixed-05", "win-00-32"] {
            assert!(names.contains(&required), "{required}");
        }
    }

    #[test]
    fn fast_mode_scales_steps_down() {
        let slow = plan(false);
        let fast = plan(true);
        for (a, b) in slow.iter().zip(&fast) {
            assert!(b.steps < a.steps);
        }
    }
}
