//! `repro` — CLI driver for the d3LLM reproduction.
//!
//! Subcommands:
//!   info                               manifest / platform summary
//!   gen-data  --family F --n N        inspect synthetic task samples
//!   train     --preset NAME [--fast]  run one training preset
//!   train-all [--fast]                run the full checkpoint plan
//!   eval      --ckpt NAME --strategy S --task T [--n N] [--threshold X]
//!   serve     --ckpt NAME [--port P]  JSON-line TCP serving coordinator
//!   bench     --exp EXP               regenerate a paper table/figure
//!
//! Everything reads artifacts/ (run `make artifacts` first) and writes
//! checkpoints/ and results/.

use std::path::Path;

use anyhow::{anyhow, Result};

use d3llm::bench;
use d3llm::coordinator;
use d3llm::data::{self, Family};
use d3llm::decode::{DecodeCfg, Strategy};
use d3llm::eval::evaluate;
use d3llm::model::ParamStore;
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::train::{self, presets, TrainCfg};
use d3llm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if let Err(e) = dispatch(cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => info(args),
        "gen-data" => gen_data(args),
        "train" => cmd_train(args),
        "train-all" => cmd_train_all(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "repro — d3LLM reproduction (see README.md)\n\
         \n\
         usage: repro <command> [flags]\n\
         \n\
         commands:\n\
           info                                  show manifest + platform\n\
           gen-data --family F [--n N]           print synthetic samples\n\
           train --preset NAME [--fast]          run one training preset\n\
           train-all [--fast]                    run the full plan\n\
           eval --ckpt C --strategy S --task T   evaluate a checkpoint\n\
                [--n N] [--threshold X] [--strict] [--variant xla|pallas]\n\
           serve --ckpt C [--port 7070]          start the serving coordinator\n\
                [--max-sessions N] [--max-queue N] [--config svc.json]\n\
                [--draft D] [--kv-budget-mb MB (0 = dense caches)]\n\
                [--workers N (replica fleet)] [--round-width N]\n\
                [--spill-after N (paused rounds before KV spill, 0 = off)]\n\
                [--adaptive off|load] [--adaptive-conf-floor X]\n\
                [--adaptive-entropy-ceiling X]\n\
           bench --exp EXP [--n N] [--fast]      regenerate a table/figure\n\
                 (table1..table11, curves, radar, figure1, perf, all)"
    );
}

fn engine() -> Result<Engine> {
    Engine::load("artifacts")
}

fn ckpt_dir() -> &'static Path {
    Path::new("checkpoints")
}

fn load_ckpt(name: &str) -> Result<ParamStore> {
    ParamStore::load(TrainCfg::ckpt_path(ckpt_dir(), name))
}

/// Run one training job and persist its loss curve — the single entry
/// point shared by `train` (single run) and `train-all` (preset plan),
/// with uniform completion logging.
fn run_training(eng: &Engine, cfg: &TrainCfg) -> Result<()> {
    let t0 = std::time::Instant::now();
    let out = train::train(eng, cfg, ckpt_dir())?;
    let log_path = format!("results/loss_{}.csv", cfg.name);
    train::save_log(&out.log, &log_path)?;
    let last = out.log.last().map(|l| l.loss).unwrap_or(f32::NAN);
    eprintln!(
        "[train:{}] {} steps, final loss {last:.4} ({:.1}s); curve -> \
         {log_path}",
        cfg.name,
        cfg.steps,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn info(_args: &Args) -> Result<()> {
    let eng = engine()?;
    let c = &eng.manifest.constants;
    println!("platform: {}", eng.platform());
    println!(
        "constants: vocab={} s_max={} window={} block={} gen_max={}",
        c.vocab, c.s_max, c.window, c.block, c.gen_max
    );
    for (name, m) in &eng.manifest.models {
        println!(
            "model `{name}`: d={} L={} H={} ff={} params={}",
            m.d_model, m.n_layers, m.n_heads, m.d_ff, m.total_params
        );
    }
    println!("executables:");
    for name in eng.manifest.executables.keys() {
        println!("  {name}");
    }
    Ok(())
}

fn gen_data(args: &Args) -> Result<()> {
    let fam = Family::parse(&args.str_or("family", "gsm8k"))
        .ok_or_else(|| anyhow!("unknown family"))?;
    let n = args.usize_or("n", 5);
    let tk = Tokenizer::new(128)?;
    let mut rng = d3llm::util::rng::Rng::new(args.u64_or("seed", 1));
    for _ in 0..n {
        let s = data::generate(&tk, fam, &mut rng);
        println!("{}", data::tasks::to_text(&tk, &s)?);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args
        .get("preset")
        .ok_or_else(|| anyhow!("--preset required (see train-all plan)"))?;
    let fast = args.has("fast");
    let mut cfg = presets::by_name(name, fast)
        .ok_or_else(|| anyhow!("unknown preset `{name}`"))?;
    if let Some(lr) = args.get("lr") {
        cfg.lr = lr.parse()?;
    }
    if let Some(steps) = args.get("steps") {
        cfg.steps = steps.parse()?;
    }
    if let Some(cs) = args.get("corpus") {
        cfg.corpus_size = cs.parse()?;
    }
    if let Some(suffix) = args.get("tag") {
        cfg.name = format!("{}-{suffix}", cfg.name);
        cfg.init_from = None;
        cfg.teacher = None;
    }
    let eng = engine()?;
    run_training(&eng, &cfg)
}

fn cmd_train_all(args: &Args) -> Result<()> {
    let fast = args.has("fast");
    let skip_existing = !args.has("force");
    let eng = engine()?;
    for cfg in presets::plan(fast) {
        let path = TrainCfg::ckpt_path(ckpt_dir(), &cfg.name);
        if skip_existing && path.exists() {
            eprintln!("[train-all] skip `{}` (exists)", cfg.name);
            continue;
        }
        run_training(&eng, &cfg)?;
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let eng = engine()?;
    let ckpt = args.str_or("ckpt", "d3llm-llada");
    let params = load_ckpt(&ckpt)?;
    let strategy = Strategy::parse(&args.str_or("strategy", "d3llm"))
        .ok_or_else(|| anyhow!("unknown strategy"))?;
    let fam = Family::parse(&args.str_or("task", "gsm8k"))
        .ok_or_else(|| anyhow!("unknown family"))?;
    let n = args.usize_or("n", 20);
    let mut cfg = DecodeCfg::preset(strategy);
    cfg.variant = args.str_or("variant", "xla");
    if let Some(t) = args.get("threshold") {
        cfg = cfg.with_threshold(t.parse()?);
    }
    let draft = if strategy == Strategy::Spec {
        Some(load_ckpt(&args.str_or("draft", "draft"))?)
    } else {
        None
    };
    let tk = Tokenizer::new(eng.manifest.constants.vocab)?;
    let samples = data::eval_set(&tk, fam, n, args.u64_or("seed", 42));
    if args.has("show") {
        for s in samples.iter().take(5) {
            let gen_len = d3llm::eval::gen_len_for(
                s.family, eng.manifest.constants.block,
                eng.manifest.constants.gen_max);
            let r = d3llm::decode::generate(&eng, &cfg, &params.data, None,
                                            &s.prompt, gen_len)?;
            println!("----\nprompt:   {}", tk.decode(&s.prompt));
            println!("expected: {}", tk.decode(&s.response));
            println!("got:      {}", tk.decode(&r.tokens));
            println!("ok={} tpf={:.2}", data::check(&tk, s, &r.tokens, false),
                     r.tpf());
        }
        return Ok(());
    }
    let out = evaluate(&eng, &cfg, &params.data,
                       draft.as_ref().map(|d| d.data.as_slice()), &tk,
                       &samples, args.has("strict"))?;
    let m = &out.metrics;
    println!(
        "ckpt={ckpt} strategy={} task={} n={}\n\
         accuracy {:.1}%  TPF {:.2}  TPS(cpu) {:.1}  forwards {}  tokens {}",
        strategy.name(),
        fam.name(),
        m.samples,
        m.accuracy(),
        m.tpf(),
        m.tps(),
        m.forwards,
        m.gen_tokens
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let strategy = Strategy::parse(&args.str_or("strategy", "d3llm"))
        .ok_or_else(|| anyhow!("unknown strategy"))?;
    // flags override the --config file, which overrides the defaults
    let svc = match args.get("config") {
        Some(path) => Some(d3llm::config::ServiceConfig::load(path)?),
        None => None,
    };
    // adaptive parallelism controller: flags override the config file's
    // adaptive block, which overrides the off-by-default preset
    let adaptive = {
        let mut a = svc
            .as_ref()
            .map(|s| s.adaptive.clone())
            .unwrap_or_default();
        if let Some(m) = args.get("adaptive") {
            a.mode = d3llm::decode::AdaptiveMode::parse(m).ok_or_else(
                || anyhow!("unknown adaptive mode `{m}` (off|load)"))?;
        }
        if let Some(v) = args.get("adaptive-conf-floor") {
            a.conf_floor = v.parse()?;
        }
        if let Some(v) = args.get("adaptive-entropy-ceiling") {
            a.entropy_ceiling = v.parse()?;
        }
        d3llm::config::validate_adaptive(&a)?;
        a
    };
    let cfg = coordinator::ServerCfg {
        host: args.str_or(
            "host",
            svc.as_ref().map(|s| s.host.as_str()).unwrap_or("127.0.0.1"),
        ),
        port: args.usize_or(
            "port",
            svc.as_ref().map(|s| s.port as usize).unwrap_or(7070),
        ) as u16,
        ckpt: args.str_or(
            "ckpt",
            svc.as_ref().map(|s| s.ckpt.as_str()).unwrap_or("d3llm-llada"),
        ),
        strategy,
        variant: args.str_or("variant", "xla"),
        max_queue: args.usize_or(
            "max-queue",
            svc.as_ref().map(|s| s.max_queue).unwrap_or(256),
        ),
        max_concurrent_sessions: args.usize_or(
            "max-sessions",
            svc.as_ref().map(|s| s.max_concurrent_sessions).unwrap_or(4),
        ),
        // draft checkpoint enables speculative (`spec`) serving
        draft: args
            .get("draft")
            .map(|s| s.to_string())
            .or_else(|| svc.as_ref().and_then(|s| s.draft_ckpt.clone())),
        kv_budget_mb: args.usize_or(
            "kv-budget-mb",
            svc.as_ref().map(|s| s.kv_budget_mb).unwrap_or(256),
        ),
        // EDF round width: sessions stepped per round under deadline
        // pressure (0 = unlimited, the pre-SLO behavior)
        slo_round_width: args.usize_or(
            "round-width",
            svc.as_ref().map(|s| s.slo_round_width).unwrap_or(0),
        ),
        // replica fleet behind the prefix-affinity router (1 = classic
        // single-worker topology)
        workers: args.usize_or(
            "workers",
            svc.as_ref().map(|s| s.workers).unwrap_or(1),
        ),
        // paused rounds before a preempted session spills its paged KV
        // back to the pool (0 = never spill)
        spill_after_rounds: args.usize_or(
            "spill-after",
            svc.as_ref().map(|s| s.spill_after_rounds).unwrap_or(0),
        ),
        adaptive,
        // an explicit --strategy flag wins over the config file's decode
        // block; without the flag the config's tuned decode applies
        decode: if args.get("strategy").is_some() {
            None
        } else {
            svc.map(|s| s.decode)
        },
    };
    d3llm::config::validate_service_limits(cfg.max_queue,
                                           cfg.max_concurrent_sessions)?;
    d3llm::config::validate_workers(cfg.workers)?;
    coordinator::serve(cfg)
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args.str_or("exp", "all");
    let n = args.usize_or("n", 0); // 0 = experiment default
    let fast = args.has("fast");
    let seeds = args.usize_or("seeds", 0);
    bench::run(&exp, bench::BenchOpts { n, fast, seeds })
}
