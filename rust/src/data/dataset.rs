//! Corpus assembly: distillation corpora and held-out eval sets.
//!
//! Mirrors the paper's setup (§4.1): the distillation corpus is math-heavy
//! with a code slice (PRM12K + GSM8K + Numina + AceCode analog); the coder
//! corpus is code-only; eval sets are held out by seed-space separation
//! (generator seeds for eval sets never overlap the train stream).

use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

use super::tasks::{generate, Family, Sample};

/// Seed offsets guaranteeing train/eval separation.
const TRAIN_STREAM: u64 = 0x7261_494E;
const EVAL_STREAM: u64 = 0xE7A1_0000;

/// The standard distillation mixture (Gsm8k-heavy, math + code slices).
pub fn main_mixture() -> Vec<(Family, f64)> {
    vec![
        (Family::Gsm8k, 0.40),
        (Family::Math, 0.30),
        (Family::HumanEval, 0.15),
        (Family::Mbpp, 0.15),
    ]
}

/// Code-only mixture for the coder family (Dream-Coder analog).
pub fn coder_mixture() -> Vec<(Family, f64)> {
    vec![(Family::CoderHumanEval, 0.5), (Family::CoderMbpp, 0.5)]
}

/// Draw `n` training samples from a mixture.
pub fn train_corpus(tk: &Tokenizer, mixture: &[(Family, f64)], n: usize,
                    seed: u64) -> Vec<Sample> {
    let mut rng = Rng::new(seed ^ TRAIN_STREAM);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut r = rng.f64();
        let mut fam = mixture[0].0;
        for &(f, w) in mixture {
            if r < w {
                fam = f;
                break;
            }
            r -= w;
        }
        out.push(generate(tk, fam, &mut rng));
    }
    out
}

/// Held-out eval set for one family.
pub fn eval_set(tk: &Tokenizer, family: Family, n: usize, seed: u64)
                -> Vec<Sample> {
    let mut rng = Rng::new(seed ^ EVAL_STREAM ^ (family as u64) << 32);
    (0..n).map(|_| generate(tk, family, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_respects_mixture_roughly() {
        let tk = Tokenizer::new(128).unwrap();
        let corpus = train_corpus(&tk, &main_mixture(), 2000, 1);
        let gsm = corpus.iter().filter(|s| s.family == Family::Gsm8k).count();
        let frac = gsm as f64 / 2000.0;
        assert!((0.33..0.47).contains(&frac), "gsm frac {frac}");
    }

    #[test]
    fn eval_train_disjoint_streams() {
        let tk = Tokenizer::new(128).unwrap();
        let train = train_corpus(&tk, &[(Family::Gsm8k, 1.0)], 50, 7);
        let eval = eval_set(&tk, Family::Gsm8k, 50, 7);
        // prompts should not collide (probabilistic but deterministic here)
        let overlap = eval
            .iter()
            .filter(|e| train.iter().any(|t| t.prompt == e.prompt))
            .count();
        assert!(overlap <= 2, "{overlap} overlapping prompts");
    }

    #[test]
    fn eval_set_deterministic() {
        let tk = Tokenizer::new(128).unwrap();
        let a = eval_set(&tk, Family::Math, 10, 3);
        let b = eval_set(&tk, Family::Math, 10, 3);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
