//! Synthetic workloads: task families, corpora, eval sets.

pub mod dataset;
pub mod tasks;

pub use dataset::{coder_mixture, eval_set, main_mixture, train_corpus};
pub use tasks::{check, full_sequence, generate, Answer, Family, Sample};
