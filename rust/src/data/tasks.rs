//! Synthetic task families — the benchmark-analog workloads (DESIGN.md §1).
//!
//! Each family mirrors the *shape* of its paper counterpart: a prompt, a
//! chain-of-thought response whose token-level structure makes decoding
//! order meaningful (so pseudo-trajectory distillation has signal), and an
//! exactly-checkable answer (so accuracy is measurable):
//!
//!   * Gsm8k      — left-to-right CoT arithmetic, small operands
//!   * Math       — longer chains, MOD/larger values (harder)
//!   * HumanEval  — per-element list transformation with STEP lines
//!   * Mbpp       — list programs: REV / SORT / FILTER with YES/NO steps
//!   * LongGsm8k  — 5-shot Gsm8k (long prompt, eval-only)
//!   * Coder*     — HumanEval/Mbpp restricted to the coder teacher's
//!                  domain; "+" variants additionally verify STEP lines
//!                  (the stricter extended test sets of HumanEval+/MBPP+).

use anyhow::Result;

use crate::tokenizer::{Tokenizer, EOS, SEP};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Gsm8k,
    Math,
    HumanEval,
    Mbpp,
    LongGsm8k,
    CoderHumanEval,
    CoderMbpp,
}

impl Family {
    pub fn all_eval() -> &'static [Family] {
        &[Family::Gsm8k, Family::Math, Family::HumanEval, Family::Mbpp,
          Family::LongGsm8k]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Family::Gsm8k => "gsm8k",
            Family::Math => "math",
            Family::HumanEval => "humaneval",
            Family::Mbpp => "mbpp",
            Family::LongGsm8k => "long-gsm8k",
            Family::CoderHumanEval => "coder-humaneval",
            Family::CoderMbpp => "coder-mbpp",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        Some(match s {
            "gsm8k" => Family::Gsm8k,
            "math" => Family::Math,
            "humaneval" => Family::HumanEval,
            "mbpp" => Family::Mbpp,
            "long-gsm8k" => Family::LongGsm8k,
            "coder-humaneval" => Family::CoderHumanEval,
            "coder-mbpp" => Family::CoderMbpp,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Answer {
    Num(i64),
    List(Vec<i64>),
}

/// One generated task instance.
#[derive(Debug, Clone)]
pub struct Sample {
    pub family: Family,
    pub prompt: Vec<i32>,
    /// Ground-truth response (ends with EOS).
    pub response: Vec<i32>,
    pub answer: Answer,
    /// Expected STEP intermediate values ("+" checkers verify these).
    pub steps: Vec<i64>,
}

// ------------------------------------------------------------- arithmetic

struct ArithSpec {
    n_ops: (usize, usize),
    operand: (i64, i64),
    use_mod: bool,
    /// clamp every intermediate result to [lo, hi]: the tasks probe
    /// decoding order and parallelism, not model arithmetic capacity
    /// (the paper's 7-8B models vs our 0.4M — see DESIGN.md §1)
    result: (i64, i64),
}

fn gen_arith(tk: &Tokenizer, rng: &mut Rng, spec: &ArithSpec,
             family: Family) -> Sample {
    let n_ops = rng.range(spec.n_ops.0 as i64, spec.n_ops.1 as i64 + 1) as usize;
    let mut cur = rng.range(spec.operand.0, spec.operand.1 + 1);
    let mut prompt = tk.encode("Q EVAL").unwrap();
    tk.push_number(&mut prompt, cur);

    let mut steps = Vec::new();
    let mut resp: Vec<i32> = Vec::new();
    for _ in 0..n_ops {
        let in_range = |v: i64| v >= spec.result.0 && v <= spec.result.1;
        // rejection-sample an (op, x) keeping the chain inside the result
        // range; x = 0 with "-" is the always-valid fallback
        let mut op = "-";
        let mut x = 0i64;
        for _ in 0..16 {
            let cand = rng.range(spec.operand.0.max(0), spec.operand.1 + 1);
            let mut ops = Vec::new();
            if in_range(cur + cand) {
                ops.push("+");
            }
            if in_range(cur - cand) {
                ops.push("-");
            }
            if cand != 0 && in_range(cur * cand) {
                ops.push("*");
            }
            if spec.use_mod && cand > 1 {
                ops.push("%");
            }
            if !ops.is_empty() {
                op = *rng.choice(&ops);
                x = cand;
                break;
            }
        }
        let next = match op {
            "+" => cur + x,
            "-" => cur - x,
            "*" => cur * x,
            _ => cur.rem_euclid(x),
        };
        prompt.extend(tk.encode(op).unwrap());
        tk.push_number(&mut prompt, x);

        resp.extend(tk.encode("STEP").unwrap());
        tk.push_number(&mut resp, cur);
        resp.extend(tk.encode(op).unwrap());
        tk.push_number(&mut resp, x);
        resp.extend(tk.encode("=").unwrap());
        tk.push_number(&mut resp, next);
        resp.extend(tk.encode(";").unwrap());
        steps.push(next);
        cur = next;
    }
    resp.extend(tk.encode("ANS").unwrap());
    tk.push_number(&mut resp, cur);
    resp.push(EOS);
    Sample { family, prompt, response: resp, answer: Answer::Num(cur), steps }
}

// ------------------------------------------------------------- list tasks

fn push_list(tk: &Tokenizer, out: &mut Vec<i32>, xs: &[i64]) {
    out.extend(tk.encode("[").unwrap());
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            out.extend(tk.encode(",").unwrap());
        }
        tk.push_number(out, x);
    }
    out.extend(tk.encode("]").unwrap());
}

/// MAP-style per-element transform with STEP lines (HumanEval analog).
fn gen_map(tk: &Tokenizer, rng: &mut Rng, family: Family) -> Sample {
    let n = rng.range(3, 6) as usize;
    let xs: Vec<i64> = (0..n).map(|_| rng.range(0, 10)).collect();
    let (opname, k, f): (&str, i64, fn(i64, i64) -> i64) =
        match rng.usize(4) {
            0 => ("ADD", rng.range(1, 4), |x, k| x + k),
            1 => ("SUB", rng.range(1, 4), |x, k| x - k),
            2 => ("MUL", 2, |x, k| x * k),
            _ => ("INC", 1, |x, _| x + 1),
        };
    let mut prompt = tk.encode("PROG MAP").unwrap();
    prompt.extend(tk.encode(opname).unwrap());
    if opname != "INC" {
        tk.push_number(&mut prompt, k);
    }
    push_list(tk, &mut prompt, &xs);

    let ys: Vec<i64> = xs.iter().map(|&x| f(x, k)).collect();
    let mut resp = Vec::new();
    for (&x, &y) in xs.iter().zip(&ys) {
        resp.extend(tk.encode("STEP").unwrap());
        tk.push_number(&mut resp, x);
        resp.extend(tk.encode("->").unwrap());
        tk.push_number(&mut resp, y);
        resp.extend(tk.encode(";").unwrap());
    }
    resp.extend(tk.encode("OUT").unwrap());
    push_list(tk, &mut resp, &ys);
    resp.push(EOS);
    Sample {
        family,
        prompt,
        response: resp,
        answer: Answer::List(ys.clone()),
        steps: ys,
    }
}

/// REV / SORT / FILTER list programs (MBPP analog).
fn gen_listprog(tk: &Tokenizer, rng: &mut Rng, family: Family) -> Sample {
    let n = rng.range(3, 7) as usize;
    let xs: Vec<i64> = (0..n).map(|_| rng.range(0, 20)).collect();
    let kind = rng.usize(3);
    let mut prompt = tk.encode("PROG").unwrap();
    let (ys, steps): (Vec<i64>, Vec<i64>) = match kind {
        0 => {
            prompt.extend(tk.encode("REV").unwrap());
            let mut ys = xs.clone();
            ys.reverse();
            (ys, vec![])
        }
        1 => {
            prompt.extend(tk.encode("SORT").unwrap());
            let mut ys = xs.clone();
            ys.sort();
            (ys, vec![])
        }
        _ => {
            let keep_odd = rng.bool(0.5);
            prompt.extend(
                tk.encode(if keep_odd { "FILTER ODD" } else { "FILTER EVEN" })
                    .unwrap(),
            );
            let ys: Vec<i64> = xs
                .iter()
                .copied()
                .filter(|x| (x % 2 != 0) == keep_odd)
                .collect();
            let marks: Vec<i64> = xs
                .iter()
                .map(|x| ((x % 2 != 0) == keep_odd) as i64)
                .collect();
            (ys, marks)
        }
    };
    push_list(tk, &mut prompt, &xs);

    let mut resp = Vec::new();
    if kind == 2 {
        for (&x, &m) in xs.iter().zip(&steps) {
            resp.extend(tk.encode("STEP").unwrap());
            tk.push_number(&mut resp, x);
            resp.extend(tk.encode(if m == 1 { "YES" } else { "NO" }).unwrap());
            resp.extend(tk.encode(";").unwrap());
        }
    }
    resp.extend(tk.encode("OUT").unwrap());
    push_list(tk, &mut resp, &ys);
    resp.push(EOS);
    Sample { family, prompt, response: resp, answer: Answer::List(ys), steps }
}

// ------------------------------------------------------------- generation

/// Generate one sample of a family.
pub fn generate(tk: &Tokenizer, family: Family, rng: &mut Rng) -> Sample {
    match family {
        Family::Gsm8k => gen_arith(
            tk, rng,
            &ArithSpec { n_ops: (2, 3), operand: (0, 9), use_mod: false,
                         result: (0, 12) },
            family),
        Family::Math => gen_arith(
            tk, rng,
            &ArithSpec { n_ops: (3, 5), operand: (0, 12), use_mod: true,
                         result: (-9, 20) },
            family),
        Family::HumanEval | Family::CoderHumanEval => gen_map(tk, rng, family),
        Family::Mbpp | Family::CoderMbpp => gen_listprog(tk, rng, family),
        Family::LongGsm8k => {
            // 5-shot: exemplars (prompt + full CoT answer + SEP) x5, then
            // the actual question.
            let mut prompt = Vec::new();
            for _ in 0..5 {
                let ex = gen_arith(
                    tk, rng,
                    &ArithSpec { n_ops: (2, 3), operand: (0, 9),
                                 use_mod: false, result: (0, 12) },
                    Family::Gsm8k);
                prompt.extend(&ex.prompt);
                prompt.extend(tk.encode("A").unwrap());
                prompt.extend(&ex.response[..ex.response.len() - 1]); // no EOS
                prompt.push(SEP);
            }
            let q = gen_arith(
                tk, rng,
                &ArithSpec { n_ops: (2, 3), operand: (0, 9),
                             use_mod: false, result: (0, 12) },
                Family::LongGsm8k);
            prompt.extend(&q.prompt);
            prompt.extend(tk.encode("A").unwrap());
            Sample { prompt, ..q }
        }
    }
}

// --------------------------------------------------------------- checking

/// Verify a generated output (token ids of the generation region).
/// `strict` additionally verifies the STEP intermediate values — the
/// HumanEval+/MBPP+ analog.
pub fn check(tk: &Tokenizer, sample: &Sample, output: &[i32],
             strict: bool) -> bool {
    let ok = match &sample.answer {
        Answer::Num(n) => tk.extract_answer(output) == Some(*n),
        Answer::List(xs) => {
            tk.extract_out_list(output).as_deref() == Some(xs.as_slice())
        }
    };
    if !ok || !strict {
        return ok;
    }
    // strict: every expected STEP value must appear in order
    let step_id = match tk.id("STEP") {
        Ok(id) => id,
        Err(_) => return false,
    };
    let mut found = Vec::new();
    let mut i = 0;
    while i < output.len() {
        if output[i] == EOS {
            break;
        }
        if output[i] == step_id {
            // last number before the next `;` is the step value
            let semi = tk.id(";").unwrap();
            let mut j = i + 1;
            let mut last = None;
            while j < output.len() && output[j] != semi && output[j] != EOS {
                if let Some((v, next)) = tk.parse_number(output, j) {
                    last = Some(v);
                    j = next;
                } else {
                    j += 1;
                }
            }
            if let Some(v) = last {
                found.push(v);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    // For FILTER tasks steps are YES/NO marks, not numbers; strict mode
    // then only checks the final list (already done above).
    if sample.steps.is_empty()
        || matches!(sample.family, Family::Mbpp | Family::CoderMbpp)
    {
        return true;
    }
    found == sample.steps
}

/// Render the full training sequence: prompt ++ response.
pub fn full_sequence(sample: &Sample) -> Vec<i32> {
    let mut seq = sample.prompt.clone();
    seq.extend(&sample.response);
    seq
}

pub fn to_text(tk: &Tokenizer, sample: &Sample) -> Result<String> {
    Ok(format!("{} | {}", tk.decode(&sample.prompt),
               tk.decode(&sample.response)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tk() -> Tokenizer {
        Tokenizer::new(128).unwrap()
    }

    #[test]
    fn ground_truth_passes_its_own_checker() {
        let tk = tk();
        let mut rng = Rng::new(1);
        for &fam in &[Family::Gsm8k, Family::Math, Family::HumanEval,
                      Family::Mbpp, Family::LongGsm8k,
                      Family::CoderHumanEval, Family::CoderMbpp] {
            for _ in 0..200 {
                let s = generate(&tk, fam, &mut rng);
                assert!(check(&tk, &s, &s.response, false),
                        "{fam:?}: {}", to_text(&tk, &s).unwrap());
                assert!(check(&tk, &s, &s.response, true),
                        "strict {fam:?}: {}", to_text(&tk, &s).unwrap());
            }
        }
    }

    #[test]
    fn wrong_answer_fails_checker() {
        let tk = tk();
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = generate(&tk, Family::Gsm8k, &mut rng);
            let mut bad = s.response.clone();
            // corrupt the final answer digit
            let n = bad.len();
            let pos = n - 2; // last token before EOS is a digit
            bad[pos] = if bad[pos] == 5 { 6 } else { 5 };
            assert!(!check(&tk, &s, &bad, false));
        }
    }

    #[test]
    fn strict_catches_bad_steps() {
        let tk = tk();
        let mut rng = Rng::new(3);
        let mut tried = 0;
        for _ in 0..100 {
            let s = generate(&tk, Family::CoderHumanEval, &mut rng);
            // corrupt a STEP result but keep OUT list correct
            let arrow = tk.id("->").unwrap();
            let mut bad = s.response.clone();
            if let Some(pos) = bad.iter().position(|&t| t == arrow) {
                // digit after the arrow
                let d = bad[pos + 1];
                bad[pos + 1] = if d == 5 { 6 } else { 5 };
                // only counts when value actually changed numerically
                if check(&tk, &s, &bad, false) {
                    tried += 1;
                    assert!(!check(&tk, &s, &bad, true));
                }
            }
        }
        assert!(tried > 10);
    }

    #[test]
    fn sequence_lengths_fit_training_budget() {
        let tk = tk();
        let mut rng = Rng::new(4);
        for &fam in &[Family::Gsm8k, Family::Math, Family::HumanEval,
                      Family::Mbpp] {
            for _ in 0..500 {
                let s = generate(&tk, fam, &mut rng);
                assert!(s.prompt.len() <= 96,
                        "{fam:?} prompt {}", s.prompt.len());
                assert!(s.response.len() <= 96,
                        "{fam:?} resp {}", s.response.len());
            }
        }
        // long variant must still fit serving capacity
        for _ in 0..100 {
            let s = generate(&tk, Family::LongGsm8k, &mut rng);
            assert!(s.prompt.len() <= 256, "long prompt {}", s.prompt.len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tk = tk();
        let a = generate(&tk, Family::Math, &mut Rng::new(9));
        let b = generate(&tk, Family::Math, &mut Rng::new(9));
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.response, b.response);
    }
}
