//! AUP — Accuracy Under Parallelism (paper §2).
//!
//! Given parallelism/accuracy pairs S = {(rho_i, y_i)} with
//! rho_1 < ... < rho_m, accuracy in percent:
//!
//!   AUP = rho_1*y_1 + sum_{i>=2} (rho_i - rho_{i-1}) *
//!                     (y_i W(y_i) + y_{i-1} W(y_{i-1})) / 2
//!
//! with W(y) = min(e^{-alpha (1 - y/y_max)}, 1), y_max the best accuracy
//! achieved on the task, and points below y_min = y_1 - 5 discarded
//! (no credit for regimes of significant accuracy collapse).

pub const DEFAULT_ALPHA: f64 = 3.0;

/// One parallelism/accuracy observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// parallelism (TPF)
    pub rho: f64,
    /// accuracy in percent [0, 100]
    pub acc: f64,
}

fn weight(y: f64, y_max: f64, alpha: f64) -> f64 {
    if y_max <= 0.0 {
        return 1.0;
    }
    (-alpha * (1.0 - y / y_max)).exp().min(1.0)
}

/// AUP over a raw point set. Points are sorted by rho; `y_max` defaults to
/// the best accuracy observed on the task (pass the best across *all*
/// methods when comparing methods, per the paper's definition).
pub fn aup_from_points(points: &[Point], alpha: f64, y_max: Option<f64>)
                       -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let mut pts = points.to_vec();
    pts.sort_by(|a, b| a.rho.partial_cmp(&b.rho).unwrap());
    // dedupe identical rho (keep best accuracy — one decode run per knob)
    let mut uniq: Vec<Point> = Vec::with_capacity(pts.len());
    for p in pts {
        match uniq.last_mut() {
            Some(last) if (last.rho - p.rho).abs() < 1e-12 => {
                last.acc = last.acc.max(p.acc);
            }
            _ => uniq.push(p),
        }
    }
    let y1 = uniq[0].acc;
    let y_min = y1 - 5.0;
    let y_max = y_max
        .unwrap_or_else(|| uniq.iter().map(|p| p.acc).fold(0.0, f64::max));
    let kept: Vec<Point> =
        uniq.into_iter().filter(|p| p.acc >= y_min).collect();
    if kept.is_empty() {
        return 0.0;
    }
    let mut total = kept[0].rho * kept[0].acc;
    for i in 1..kept.len() {
        let (a, b) = (kept[i - 1], kept[i]);
        let wa = b.acc * weight(b.acc, y_max, alpha)
            + a.acc * weight(a.acc, y_max, alpha);
        total += (b.rho - a.rho) * wa / 2.0;
    }
    total
}

/// AUP with the default alpha and task-local y_max.
pub fn aup(points: &[Point]) -> f64 {
    aup_from_points(points, DEFAULT_ALPHA, None)
}

/// Fractional AUP regression of a candidate operating point versus a
/// baseline, both scored as single-point AUPs (rho * acc). Positive means
/// the candidate lost AUP, negative that it gained; 0 when the baseline
/// has no AUP to lose. The adaptive-parallelism bench pins its accuracy
/// floor on this: the controller's point must stay within a fixed
/// fraction of the static baseline's AUP.
pub fn aup_delta_frac(baseline: Point, candidate: Point) -> f64 {
    let base = aup(&[baseline]);
    if base <= 0.0 {
        return 0.0;
    }
    (base - aup(&[candidate])) / base
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_is_rho_times_acc() {
        let p = [Point { rho: 1.0, acc: 72.6 }];
        assert!((aup(&p) - 72.6).abs() < 1e-9);
        let p = [Point { rho: 2.0, acc: 50.0 }];
        assert!((aup(&p) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn flat_curve_reduces_to_auc() {
        // no accuracy loss => W == 1 everywhere => plain area
        let pts = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 3.0, acc: 80.0 },
            Point { rho: 5.0, acc: 80.0 },
        ];
        let expect = 1.0 * 80.0 + 2.0 * 80.0 + 2.0 * 80.0;
        assert!((aup(&pts) - expect).abs() < 1e-9);
    }

    #[test]
    fn accuracy_collapse_is_penalized() {
        let flat = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 5.0, acc: 80.0 },
        ];
        let droop = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 5.0, acc: 76.0 },
        ];
        assert!(aup(&droop) < aup(&flat));
        // but still rewards the parallelism some
        assert!(aup(&droop) > 80.0);
    }

    #[test]
    fn below_ymin_points_are_dropped() {
        let pts = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 3.0, acc: 79.0 },
            Point { rho: 50.0, acc: 10.0 }, // collapsed regime
        ];
        let without = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 3.0, acc: 79.0 },
        ];
        assert!((aup(&pts) - aup(&without)).abs() < 1e-9);
    }

    #[test]
    fn alpha_monotonicity() {
        let pts = [
            Point { rho: 1.0, acc: 80.0 },
            Point { rho: 4.0, acc: 77.0 },
            Point { rho: 6.0, acc: 76.0 },
        ];
        let a1 = aup_from_points(&pts, 1.0, None);
        let a3 = aup_from_points(&pts, 3.0, None);
        let a10 = aup_from_points(&pts, 10.0, None);
        assert!(a1 > a3 && a3 > a10, "{a1} {a3} {a10}");
    }

    #[test]
    fn unsorted_input_is_sorted() {
        let a = [
            Point { rho: 4.0, acc: 70.0 },
            Point { rho: 1.0, acc: 72.0 },
        ];
        let b = [
            Point { rho: 1.0, acc: 72.0 },
            Point { rho: 4.0, acc: 70.0 },
        ];
        assert_eq!(aup(&a), aup(&b));
    }

    #[test]
    fn delta_frac_tracks_single_point_aup() {
        let base = Point { rho: 2.0, acc: 80.0 }; // AUP 160
        // faster but less accurate: 3.0 * 48.0 = 144 => lost 10%
        let cand = Point { rho: 3.0, acc: 48.0 };
        assert!((aup_delta_frac(base, cand) - 0.10).abs() < 1e-9);
        // strictly better point => negative regression
        let better = Point { rho: 3.0, acc: 80.0 };
        assert!(aup_delta_frac(base, better) < 0.0);
        // degenerate baseline never divides by zero
        let zero = Point { rho: 0.0, acc: 0.0 };
        assert_eq!(aup_delta_frac(zero, cand), 0.0);
    }

    #[test]
    fn global_ymax_penalizes_weak_methods() {
        // same curve, but judged against a stronger best-achievable
        let pts = [
            Point { rho: 1.0, acc: 60.0 },
            Point { rho: 4.0, acc: 60.0 },
        ];
        let local = aup_from_points(&pts, 3.0, None);
        let global = aup_from_points(&pts, 3.0, Some(80.0));
        assert!(global < local);
    }
}
