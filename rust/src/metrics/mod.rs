//! Evaluation metrics: TPF, TPS, and the paper's AUP score (§2).

pub mod aup;

pub use aup::{aup, aup_from_points, Point, DEFAULT_ALPHA};

/// Aggregate decode statistics over an eval run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub samples: usize,
    pub correct: usize,
    pub gen_tokens: usize,
    pub forwards: usize,
    pub draft_forwards: usize,
    pub wall_secs: f64,
}

impl RunMetrics {
    pub fn accuracy(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.samples as f64
        }
    }

    /// Tokens per forward pass (paper's parallelism measure). Counts
    /// decode-phase forwards of the *target* model: window forwards,
    /// no-cache forwards, stabilizing and refresh forwards. The initial
    /// prompt prefill is excluded for every method alike.
    pub fn tpf(&self) -> f64 {
        if self.forwards == 0 {
            0.0
        } else {
            self.gen_tokens as f64 / self.forwards as f64
        }
    }

    /// Measured tokens per second on this testbed.
    pub fn tps(&self) -> f64 {
        if self.wall_secs == 0.0 {
            0.0
        } else {
            self.gen_tokens as f64 / self.wall_secs
        }
    }

    pub fn merge(&mut self, other: &RunMetrics) {
        self.samples += other.samples;
        self.correct += other.correct;
        self.gen_tokens += other.gen_tokens;
        self.forwards += other.forwards;
        self.draft_forwards += other.draft_forwards;
        self.wall_secs += other.wall_secs;
    }
}

/// Modeled wall-clock for the paper's GPU regimes (Tables 3-4).
///
/// On 7-8B models every forward is weight-bandwidth-bound, so per-forward
/// latency is roughly constant per hardware; the paper's own vanilla/AR
/// rows calibrate it (H100: LLaDA 27.9 TPS at TPF=1 => 35.8 ms/forward,
/// Qwen 57.3 TPS => 17.5 ms/AR-step; A100: 52.1 and 19.8 ms). Our testbed
/// is compute-bound (0.4M params), so measured CPU TPS is reported next to
/// this calibrated model; see DESIGN.md §1 and EXPERIMENTS.md.
#[derive(Debug, Clone, Copy)]
pub struct GpuCostModel {
    pub name: &'static str,
    /// full-sequence dLLM forward (prefill / no-cache / refresh), seconds
    pub t_full: f64,
    /// windowed dLLM forward against cache, seconds
    pub t_window: f64,
    /// AR step with exact cache, seconds
    pub t_ar: f64,
}

pub const H100: GpuCostModel = GpuCostModel {
    name: "h100-sim",
    t_full: 0.0358,
    t_window: 0.0304, // 0.85x full: cache skips recomputing cached rows
    t_ar: 0.0175,
};

pub const A100: GpuCostModel = GpuCostModel {
    name: "a100-sim",
    t_full: 0.0521,
    t_window: 0.0443,
    t_ar: 0.0198,
};

/// Marginal batching share for a weight-bandwidth-bound forward.
///
/// On 7-8B models a batch=1 forward is dominated by streaming the weights
/// (see the calibration above), so batching B concurrent sequences into
/// one forward costs roughly `1 + beta * (B - 1)` batch=1 forwards, where
/// `beta` is the compute/activation marginal share. 0.2 is conservative
/// for H100/A100-class hardware at B <= 16; `beta = 1.0` degenerates to
/// fully serialized execution (this testbed's CPU PJRT reality).
pub const DEFAULT_BATCH_BETA: f64 = 0.2;

/// Modeled cost multiplier of a batched forward relative to batch=1:
/// `batch_factor(0, _) = 0`, `batch_factor(1, _) = 1`.
pub fn batch_factor(b: usize, beta: f64) -> f64 {
    if b == 0 {
        0.0
    } else {
        1.0 + beta * (b as f64 - 1.0)
    }
}

/// Per-sample forward mix for the cost model.
#[derive(Debug, Clone, Default)]
pub struct ForwardMix {
    pub full_forwards: usize,
    pub window_forwards: usize,
    pub ar_steps: usize,
    pub gen_tokens: usize,
}

impl ForwardMix {
    pub fn modeled_tps(&self, m: &GpuCostModel) -> f64 {
        let secs = self.full_forwards as f64 * m.t_full
            + self.window_forwards as f64 * m.t_window
            + self.ar_steps as f64 * m.t_ar;
        if secs == 0.0 {
            0.0
        } else {
            self.gen_tokens as f64 / secs
        }
    }

    pub fn merge(&mut self, o: &ForwardMix) {
        self.full_forwards += o.full_forwards;
        self.window_forwards += o.window_forwards;
        self.ar_steps += o.ar_steps;
        self.gen_tokens += o.gen_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpf_and_accuracy() {
        let m = RunMetrics {
            samples: 10,
            correct: 7,
            gen_tokens: 300,
            forwards: 60,
            draft_forwards: 0,
            wall_secs: 3.0,
        };
        assert!((m.accuracy() - 70.0).abs() < 1e-9);
        assert!((m.tpf() - 5.0).abs() < 1e-9);
        assert!((m.tps() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn cost_model_vanilla_matches_calibration() {
        // vanilla dLLM: 1 token per full forward => paper's 27.9 TPS on H100
        let mix = ForwardMix {
            full_forwards: 100,
            window_forwards: 0,
            ar_steps: 0,
            gen_tokens: 100,
        };
        let tps = mix.modeled_tps(&H100);
        assert!((tps - 27.9).abs() < 0.2, "{tps}");
    }

    #[test]
    fn batch_factor_shape() {
        assert_eq!(batch_factor(0, 0.2), 0.0);
        assert_eq!(batch_factor(1, 0.2), 1.0);
        assert!((batch_factor(8, 0.2) - 2.4).abs() < 1e-12);
        // beta = 1 is fully serialized
        assert!((batch_factor(8, 1.0) - 8.0).abs() < 1e-12);
        // batching must never cost more than serializing
        for b in 1..32 {
            assert!(batch_factor(b, 0.2) <= b as f64 + 1e-12);
        }
    }

    #[test]
    fn cost_model_ar_matches_calibration() {
        let mix = ForwardMix {
            full_forwards: 0,
            window_forwards: 0,
            ar_steps: 50,
            gen_tokens: 50,
        };
        assert!((mix.modeled_tps(&H100) - 57.1).abs() < 0.5);
        assert!((mix.modeled_tps(&A100) - 50.5).abs() < 0.5);
    }
}
