//! Word-level tokenizer over the 128-token vocabulary the models were
//! AOT-compiled against.
//!
//! The synthetic task families (DESIGN.md §1) use a constrained token
//! grammar: digits are encoded digit-by-digit, everything else is a word
//! token. Ids 0..=4 are the specials the executables were compiled with
//! (PAD/MASK/EOS/BOS/SEP); the rest of the table is fixed here and checked
//! against the manifest's vocab size.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const EOS: i32 = 2;
pub const BOS: i32 = 3;
pub const SEP: i32 = 4;

/// Non-special word list. Order is ABI: changing it invalidates every
/// trained checkpoint.
const WORDS: &[&str] = &[
    // 5..14: digits
    "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
    // 15..: arithmetic / structure
    "+", "-", "*", "/", "%", "=", "(", ")", "[", "]", ";", ":", ",", "->",
    // task keywords
    "EVAL", "STEP", "ANS", "MAP", "FILTER", "FOLD", "REV", "SORT", "MIN",
    "MAX", "SUM", "LEN", "OUT", "IN", "PROG", "RUN", "GT", "LT", "EQ", "ODD",
    "EVEN", "ADD", "MUL", "SUB", "NEG", "ABS", "HEAD", "TAIL", "LAST",
    "TAKE", "DROP", "IF", "THEN", "ELSE", "DEF", "RET", "CALL", "VAR",
    "SET", "GET", "LIST", "NUM", "BEGIN", "END", "Q", "A", "X", "Y", "Z",
    "COUNT", "ZIP", "CONCAT", "PAIR", "FST", "SND", "INC", "DEC", "DUP",
    "SWAP", "POP", "PUSH", "NIL", "TRUE", "FALSE", "NOT", "AND", "OR",
    "XOR", "SHL", "SHR", "MOD", "POW", "SQ", "ROOT", "FLOOR", "CEIL",
    "ROUND", "SIGN", "GCD", "LCM", "FIB", "FACT", "PRIME", "DIV", "REM",
    "LOOP", "DONE", "SKIP", "STOP", "GO", "AT", "BY", "TO", "OF", "NO",
    "YES",
];

pub struct Tokenizer {
    vocab: usize,
    word_to_id: HashMap<&'static str, i32>,
    id_to_word: Vec<String>,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Result<Tokenizer> {
        let needed = 5 + WORDS.len();
        if needed > vocab {
            bail!("vocab {vocab} too small for {needed} tokens");
        }
        let mut word_to_id = HashMap::new();
        let mut id_to_word =
            vec!["<pad>", "<mask>", "<eos>", "<bos>", "<sep>"]
                .into_iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>();
        for (i, w) in WORDS.iter().enumerate() {
            word_to_id.insert(*w, (5 + i) as i32);
            id_to_word.push(w.to_string());
        }
        // pad table to vocab with unused slots
        while id_to_word.len() < vocab {
            id_to_word.push(format!("<unused{}>", id_to_word.len()));
        }
        Ok(Tokenizer { vocab, word_to_id, id_to_word })
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn id(&self, word: &str) -> Result<i32> {
        self.word_to_id
            .get(word)
            .copied()
            .ok_or_else(|| anyhow!("unknown token `{word}`"))
    }

    /// Encode a whitespace-separated string. Multi-digit numbers must
    /// already be split (use `push_number`).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    /// Append the digit tokens of a non-negative number.
    pub fn push_number(&self, out: &mut Vec<i32>, n: i64) {
        if n < 0 {
            out.push(self.id("-").unwrap());
            self.push_number(out, -n);
            return;
        }
        let s = n.to_string();
        for ch in s.chars() {
            let d = ch.to_digit(10).unwrap() as i32;
            out.push(5 + d);
        }
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut parts = Vec::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id == PAD {
                continue;
            }
            parts.push(
                self.id_to_word
                    .get(id as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("<bad{id}>")),
            );
        }
        parts.join(" ")
    }

    /// Parse a (possibly multi-digit, possibly negative) number from token
    /// ids starting at `i`; returns (value, next index).
    pub fn parse_number(&self, ids: &[i32], mut i: usize) -> Option<(i64, usize)> {
        let mut neg = false;
        if i < ids.len() && ids[i] == self.id("-").ok()? {
            neg = true;
            i += 1;
        }
        let mut val: i64 = 0;
        let mut digits = 0;
        while i < ids.len() {
            let d = ids[i] - 5;
            if !(0..=9).contains(&d) {
                break;
            }
            val = val * 10 + d as i64;
            digits += 1;
            i += 1;
        }
        if digits == 0 {
            return None;
        }
        Some((if neg { -val } else { val }, i))
    }

    /// Extract the final answer: the number following the last `ANS` token.
    pub fn extract_answer(&self, ids: &[i32]) -> Option<i64> {
        let ans = self.id("ANS").ok()?;
        let mut result = None;
        let mut i = 0;
        while i < ids.len() {
            if ids[i] == EOS {
                break;
            }
            if ids[i] == ans {
                if let Some((v, next)) = self.parse_number(ids, i + 1) {
                    result = Some(v);
                    i = next;
                    continue;
                }
            }
            i += 1;
        }
        result
    }

    /// Extract the list following the last `OUT [ ... ]`.
    pub fn extract_out_list(&self, ids: &[i32]) -> Option<Vec<i64>> {
        let out_id = self.id("OUT").ok()?;
        let lb = self.id("[").ok()?;
        let rb = self.id("]").ok()?;
        let mut result = None;
        let mut i = 0;
        while i < ids.len() {
            if ids[i] == EOS {
                break;
            }
            if ids[i] == out_id && i + 1 < ids.len() && ids[i + 1] == lb {
                let comma = self.id(",").ok()?;
                let mut xs = Vec::new();
                let mut j = i + 2;
                let mut ok = false;
                while j < ids.len() {
                    if ids[j] == rb {
                        ok = true;
                        break;
                    }
                    if ids[j] == comma {
                        j += 1;
                        continue;
                    }
                    match self.parse_number(ids, j) {
                        Some((v, next)) => {
                            xs.push(v);
                            j = next;
                        }
                        None => break,
                    }
                }
                if ok {
                    result = Some(xs);
                    i = j;
                }
            }
            i += 1;
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tk() -> Tokenizer {
        Tokenizer::new(128).unwrap()
    }

    #[test]
    fn vocab_fits() {
        let t = tk();
        assert!(t.vocab() == 128);
        assert_eq!(t.id("0").unwrap(), 5);
        assert_eq!(t.id("9").unwrap(), 14);
    }

    #[test]
    fn number_roundtrip() {
        let t = tk();
        for n in [0i64, 7, 10, 99, 123, -5, -40] {
            let mut ids = Vec::new();
            t.push_number(&mut ids, n);
            let (v, next) = t.parse_number(&ids, 0).unwrap();
            assert_eq!(v, n);
            assert_eq!(next, ids.len());
        }
    }

    #[test]
    fn encode_decode() {
        let t = tk();
        let ids = t.encode("EVAL 3 + 5 = ANS 8").unwrap();
        assert_eq!(t.decode(&ids), "EVAL 3 + 5 = ANS 8");
    }

    #[test]
    fn extract_answer_takes_last() {
        let t = tk();
        let mut ids = t.encode("STEP ANS 3 ; ANS").unwrap();
        t.push_number(&mut ids, 42);
        ids.push(EOS);
        // garbage after EOS must be ignored
        ids.extend(t.encode("ANS 9 9").unwrap());
        assert_eq!(t.extract_answer(&ids), Some(42));
    }

    #[test]
    fn extract_out_list_works() {
        let t = tk();
        let mut ids = t.encode("OUT [").unwrap();
        t.push_number(&mut ids, 12);
        t.push_number(&mut ids, 3);
        ids.extend(t.encode("]").unwrap());
        // digits are greedy: without separators `12 3` reads as 123 —
        // which is why the list grammar uses `,` separators.
        assert_eq!(t.extract_out_list(&ids), Some(vec![123]));
        let mut ids2 = t.encode("OUT [").unwrap();
        t.push_number(&mut ids2, 12);
        ids2.extend(t.encode(",").unwrap());
        t.push_number(&mut ids2, 3);
        ids2.extend(t.encode("]").unwrap());
        assert_eq!(t.extract_out_list(&ids2), Some(vec![12, 3]));
    }

    #[test]
    fn unknown_token_rejected() {
        assert!(tk().encode("FOOBARBAZ").is_err());
    }
}
