//! Forward-provider abstraction for the resumable decode session.
//!
//! `DecodeSession` (and therefore the serving scheduler) only needs two
//! forwards — the full no-cache forward and the windowed cached forward —
//! plus the compile-time geometry they were lowered with. Abstracting
//! those behind `Backend` lets the same state machine run against:
//!
//!   * the real PJRT `Engine` (production serving), and
//!   * the deterministic `SimBackend` (`decode::sim`) for scheduler and
//!     state-machine tests/benches that must not depend on artifacts.
//!
//! `&Engine` coerces to `&dyn Backend` at every existing call site, so the
//! engine-facing code is unchanged apart from the signatures.

use anyhow::Result;

use crate::model::exec::{self, DecodeOut, PrefillOut};
use crate::model::KvCache;
use crate::runtime::manifest::{Constants, ModelSpec};
use crate::runtime::Engine;

pub trait Backend {
    /// Compile-time constants the executables were lowered with.
    fn constants(&self) -> &Constants;

    /// Geometry of the main serving model (cache layout).
    fn model_spec(&self) -> Result<&ModelSpec>;

    /// Full-sequence bidirectional forward (prompt prefill, KV refresh,
    /// stabilizing rounds). Output vectors are `s_max`-sized.
    fn prefill(&self, exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut>;

    /// Windowed forward against the approximate KV cache (the hot path).
    /// Output vectors are `window`-sized.
    fn decode_window(&self, exec: &str, params: &[f32], win_tokens: &[i32],
                     win_pos: &[i32], win_valid: &[f32], cache: &KvCache)
                     -> Result<DecodeOut>;
}

impl Backend for Engine {
    fn constants(&self) -> &Constants {
        &self.manifest.constants
    }

    fn model_spec(&self) -> Result<&ModelSpec> {
        self.manifest.model("main")
    }

    fn prefill(&self, exec_name: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
        exec::prefill(self, exec_name, params, tokens, valid)
    }

    fn decode_window(&self, exec_name: &str, params: &[f32],
                     win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
                     cache: &KvCache) -> Result<DecodeOut> {
        exec::decode_window(self, exec_name, params, win_tokens, win_pos,
                            win_valid, cache)
    }
}
