//! Forward-provider abstraction for the decode policies and sessions.
//!
//! Every decode strategy needs exactly two forwards — the full no-cache
//! forward and the windowed cached forward — plus the compile-time
//! geometry they were lowered with. Abstracting those behind `Backend`
//! lets the same policies and the same session driver run against:
//!
//!   * the real PJRT `Engine` (production serving), and
//!   * the deterministic `SimBackend` (`decode::sim`) for scheduler and
//!     state-machine tests/benches that must not depend on artifacts.
//!
//! `&Engine` coerces to `&dyn Backend` at every existing call site, so the
//! engine-facing code is unchanged apart from the signatures.
//!
//! ## Cache views
//!
//! The windowed forwards take the session cache as `&dyn KvView`, so a
//! session backed by the dense `KvCache` and one backed by a `PagedKv`
//! view into the shared `SharedKvPool` run through identical code. Both
//! backends read the cache paged-natively (`KvView::page_args` /
//! `for_each_page`): `SimBackend` fingerprints the page table in place
//! (O(live-pages) per step), and the PJRT engine packs the live pages
//! into the page-table arguments of a paged executable
//! (`exec::pack_page_table` — bytes copied scale with valid rows) when
//! the manifest ships one, staging through its reusable scratch
//! (`Engine::kv_stage`) only on the v1 fallback path. Dense caches are
//! handed over borrow-only (or sliced into page entries for the paged
//! executables), and no path re-gathers `[L, S_max, d_kv]` per forward.
//!
//! ## Batched forwards
//!
//! `prefill_batch` / `decode_window_batch` run B same-shape forwards in
//! one backend call. The serving scheduler (`SessionPool::step_round`)
//! coalesces the per-round forwards of sessions whose rounds share a
//! shape — (executable, sequence/window length) — into one such call.
//! The default implementations loop over `prefill` / `decode_window`;
//! `SimBackend` overrides them with a genuinely batched single-pass
//! implementation whose per-item outputs are bit-identical to the B=1
//! path, and `Engine` routes eligible groups through the lowered B>1
//! executables (manifest format_version >= 2), falling back to the loop
//! for v1 artifact dirs.

use anyhow::Result;

use crate::model::exec::{self, DecodeOut, PrefillOut, TrainFusedOut,
                         TrainOut, TrajectoryOut};
use crate::model::KvView;
use crate::runtime::manifest::{Constants, ModelSpec};
use crate::runtime::Engine;

/// One full-sequence forward of a batched `prefill_batch` call.
pub struct PrefillItem<'a> {
    pub exec: &'a str,
    pub tokens: &'a [i32],
    pub valid: &'a [f32],
}

/// One windowed cached forward of a batched `decode_window_batch` call.
/// Each item carries its own session's cache view (per-request state):
/// a coalesced round hands the backend B per-session page tables, not B
/// dense cache copies — the backend reads each view paged-natively.
pub struct WindowItem<'a> {
    pub exec: &'a str,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub valid: &'a [f32],
    pub cache: &'a dyn KvView,
}

pub trait Backend {
    /// Compile-time constants the executables were lowered with.
    fn constants(&self) -> &Constants;

    /// Geometry of a serving model ("main", "draft", ...): cache layout.
    fn model_spec(&self, name: &str) -> Result<&ModelSpec>;

    /// Full-sequence bidirectional forward (prompt prefill, KV refresh,
    /// stabilizing rounds). Output vectors are `s_max`-sized.
    fn prefill(&self, exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut>;

    /// Windowed forward against the approximate KV cache (the hot path).
    /// Output vectors match the executable's window length.
    fn decode_window(&self, exec: &str, params: &[f32], win_tokens: &[i32],
                     win_pos: &[i32], win_valid: &[f32], cache: &dyn KvView)
                     -> Result<DecodeOut>;

    /// B same-shape full forwards in one call. Default: loop over
    /// `prefill` (correct everywhere, batched nowhere).
    fn prefill_batch(&self, params: &[f32], items: &[PrefillItem<'_>])
                     -> Result<Vec<PrefillOut>> {
        items
            .iter()
            .map(|it| self.prefill(it.exec, params, it.tokens, it.valid))
            .collect()
    }

    /// B same-shape windowed forwards (each against its own cache) in one
    /// call. Default: loop over `decode_window`.
    fn decode_window_batch(&self, params: &[f32], items: &[WindowItem<'_>])
                           -> Result<Vec<DecodeOut>> {
        items
            .iter()
            .map(|it| {
                self.decode_window(it.exec, params, it.tokens, it.pos,
                                   it.valid, it.cache)
            })
            .collect()
    }

    // ---- training-side forwards -----------------------------------------
    //
    // The full paper pipeline (teacher pretraining, pseudo-trajectory
    // extraction, distillation) runs through these, so training and eval
    // are backend-agnostic just like decoding: the PJRT `Engine` executes
    // the fused AOT graphs, `SimBackend` a deterministic closed-form
    // update (tests/distill_e2e.rs pins the end-to-end pipeline on it).

    /// Fused fwd+bwd+AdamW step over a `[B, s_train]` batch
    /// (`train_diff` / `train_ar` / `draft_train_ar`). Returns updated
    /// parameters, optimiser moments and the scalar loss.
    #[allow(clippy::too_many_arguments)]
    fn train_step(&self, exec: &str, params: &[f32], m: &[f32], v: &[f32],
                  step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut>;

    /// Chunk size K of a fused multi-step train executable serving
    /// `exec`, `None` when each step must be its own call (the default —
    /// and what v1 artifact dirs report, so the training driver keeps
    /// its per-step loop there).
    fn fused_train_chunk(&self, _exec: &str) -> Option<usize> {
        None
    }

    /// K sequential fused train steps over batches stacked `[K, B,
    /// s_train]`, inner step counter advancing `step0 .. step0 + K`.
    /// Default: K looped `train_step` calls — arithmetically the fused
    /// scan, fused nowhere. Callers pass the `k` they got from
    /// [`Backend::fused_train_chunk`].
    #[allow(clippy::too_many_arguments)]
    fn train_step_fused(&self, exec: &str, k: usize, params: &[f32],
                        m: &[f32], v: &[f32], step0: i32, tokens: &[i32],
                        labels: &[i32], loss_mask: &[f32],
                        attn_valid: &[f32], lr: f32, ent_weight: f32)
                        -> Result<TrainFusedOut> {
        if k == 0 || tokens.len() % k != 0 {
            anyhow::bail!("train_step_fused: bad chunk {k} for {} tokens",
                          tokens.len());
        }
        let per = tokens.len() / k;
        let mut p = params.to_vec();
        let mut mm = m.to_vec();
        let mut vv = v.to_vec();
        let mut loss = Vec::with_capacity(k);
        for i in 0..k {
            let r = i * per..(i + 1) * per;
            let out = self.train_step(
                exec, &p, &mm, &vv, step0 + i as i32, &tokens[r.clone()],
                &labels[r.clone()], &loss_mask[r.clone()],
                &attn_valid[r], lr, ent_weight)?;
            p = out.params;
            mm = out.m;
            vv = out.v;
            loss.push(out.loss);
        }
        Ok(TrainFusedOut { params: p, m: mm, v: vv, loss })
    }

    /// Batched whole-scan teacher decoding-order extraction over
    /// `[B, s_train]` rows: unmask exactly one token per step (earliest
    /// incomplete block, highest confidence) and record each position's
    /// unmask step. This is the exact on-device reference; the default
    /// extraction path (`trajectory::extract_all`) instead runs teacher
    /// sessions through the serving scheduler so extraction batches and
    /// shares prefix KV like any other workload.
    fn trajectory(&self, params: &[f32], tokens: &[i32], attn_valid: &[f32],
                  gen_mask: &[f32]) -> Result<TrajectoryOut>;
}

impl Backend for Engine {
    fn constants(&self) -> &Constants {
        &self.manifest.constants
    }

    fn model_spec(&self, name: &str) -> Result<&ModelSpec> {
        self.manifest.model(name)
    }

    fn prefill(&self, exec_name: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
        exec::prefill(self, exec_name, params, tokens, valid)
    }

    fn decode_window(&self, exec_name: &str, params: &[f32],
                     win_tokens: &[i32], win_pos: &[i32], win_valid: &[f32],
                     cache: &dyn KvView) -> Result<DecodeOut> {
        exec::decode_window(self, exec_name, params, win_tokens, win_pos,
                            win_valid, cache)
    }

    // Batched forwards: route a same-exec group through the B>1
    // executables (`prefill_batch` / `decode_paged_batch`) when the
    // manifest ships them. `exec::*_batch` returns `Ok(None)` whenever
    // the lowering cannot serve the group — v1 artifacts, the AR/draft
    // executables, or a cache-geometry mismatch — and the loop default
    // runs instead, so old artifact dirs batch exactly as before
    // (B sequential forwards with identical outputs).

    fn prefill_batch(&self, params: &[f32], items: &[PrefillItem<'_>])
                     -> Result<Vec<PrefillOut>> {
        if items.len() >= 2
            && items.iter().all(|it| it.exec == items[0].exec)
        {
            let group: Vec<exec::PrefillBatchItem<'_>> = items
                .iter()
                .map(|it| exec::PrefillBatchItem {
                    tokens: it.tokens,
                    valid: it.valid,
                })
                .collect();
            if let Some(outs) =
                exec::prefill_batch(self, items[0].exec, params, &group)?
            {
                return Ok(outs);
            }
        }
        items
            .iter()
            .map(|it| self.prefill(it.exec, params, it.tokens, it.valid))
            .collect()
    }

    fn decode_window_batch(&self, params: &[f32],
                           items: &[WindowItem<'_>])
                           -> Result<Vec<DecodeOut>> {
        if items.len() >= 2
            && items.iter().all(|it| it.exec == items[0].exec)
        {
            let group: Vec<exec::WindowBatchItem<'_>> = items
                .iter()
                .map(|it| exec::WindowBatchItem {
                    tokens: it.tokens,
                    pos: it.pos,
                    valid: it.valid,
                    cache: it.cache,
                })
                .collect();
            if let Some(outs) = exec::decode_window_batch(
                self, items[0].exec, params, &group)?
            {
                return Ok(outs);
            }
        }
        items
            .iter()
            .map(|it| {
                self.decode_window(it.exec, params, it.tokens, it.pos,
                                   it.valid, it.cache)
            })
            .collect()
    }

    fn train_step(&self, exec_name: &str, params: &[f32], m: &[f32],
                  v: &[f32], step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut> {
        exec::train_step(self, exec_name, params, m, v, step, tokens,
                         labels, loss_mask, attn_valid, lr, ent_weight)
    }

    /// The fused multi-step lowering exists for the diffusion objective
    /// only (`train_diff_fused`, manifest format_version >= 2); AR and
    /// draft training keep the per-step path everywhere.
    fn fused_train_chunk(&self, exec: &str) -> Option<usize> {
        if exec != "train_diff" {
            return None;
        }
        self.manifest.executables.get("train_diff_fused")?.batch
    }

    fn train_step_fused(&self, exec: &str, k: usize, params: &[f32],
                        m: &[f32], v: &[f32], step0: i32, tokens: &[i32],
                        labels: &[i32], loss_mask: &[f32],
                        attn_valid: &[f32], lr: f32, ent_weight: f32)
                        -> Result<TrainFusedOut> {
        if self.fused_train_chunk(exec) != Some(k) {
            anyhow::bail!("train_step_fused: no fused lowering for \
                           `{exec}` with chunk {k}");
        }
        exec::train_step_fused(self, params, m, v, step0, tokens, labels,
                               loss_mask, attn_valid, lr, ent_weight)
    }

    fn trajectory(&self, params: &[f32], tokens: &[i32], attn_valid: &[f32],
                  gen_mask: &[f32]) -> Result<TrajectoryOut> {
        // prefer the paged on-device scan when the artifact set ships it
        // (manifest format_version >= 2) with the same [B, S] geometry
        // and signature — identical outputs, paged window reads inside
        if let (Ok(dense), Some(paged)) = (
            self.manifest.exec("trajectory"),
            self.manifest.executables.get("trajectory_paged"),
        ) {
            if paged.inputs.len() == dense.inputs.len()
                && paged.inputs[1].shape == dense.inputs[1].shape
            {
                return exec::trajectory_paged(self, params, tokens,
                                              attn_valid, gen_mask);
            }
        }
        exec::trajectory(self, params, tokens, attn_valid, gen_mask)
    }
}
