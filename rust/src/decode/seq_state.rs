//! Per-request sequence state shared by every decode strategy: prompt +
//! generation region geometry, block bookkeeping, EOS/early-stop logic.

use crate::tokenizer::{EOS, MASK, PAD};

#[derive(Clone)]
pub struct SeqState {
    /// Full padded sequence (length s_max): prompt, generation region
    /// (MASK until decoded), PAD tail.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Generation capacity (multiple of block size).
    pub gen_len: usize,
    pub block: usize,
    pub s_max: usize,
}

impl SeqState {
    pub fn new(prompt: &[i32], gen_len: usize, block: usize, s_max: usize)
               -> SeqState {
        assert!(gen_len % block == 0, "gen_len must be a block multiple");
        assert!(prompt.len() + gen_len <= s_max,
                "prompt {} + gen {} > s_max {}", prompt.len(), gen_len, s_max);
        let mut tokens = vec![PAD; s_max];
        tokens[..prompt.len()].copy_from_slice(prompt);
        for t in tokens.iter_mut().skip(prompt.len()).take(gen_len) {
            *t = MASK;
        }
        SeqState {
            tokens,
            prompt_len: prompt.len(),
            gen_len,
            block,
            s_max,
        }
    }

    #[inline]
    pub fn gen_start(&self) -> usize {
        self.prompt_len
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.gen_len / self.block
    }

    /// Absolute position range of generation block `b`.
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        let lo = self.prompt_len + b * self.block;
        (lo, lo + self.block)
    }

    /// Attention validity over the full sequence: prompt + gen region
    /// (mask tokens are visible in masked diffusion), PAD excluded.
    pub fn full_valid(&self) -> Vec<f32> {
        let end = self.prompt_len + self.gen_len;
        (0..self.s_max)
            .map(|i| if i < end { 1.0 } else { 0.0 })
            .collect()
    }

    /// Attention validity covering only the prompt — the prefill view
    /// shared by every strategy's prompt prefill.
    pub fn prompt_valid(&self) -> Vec<f32> {
        (0..self.s_max)
            .map(|i| if i < self.prompt_len { 1.0 } else { 0.0 })
            .collect()
    }

    /// Full-length token buffer holding only the prompt prefix (PAD
    /// elsewhere): the AR-family prefill view, which must not see the
    /// MASK placeholders of the generation region.
    pub fn prompt_prefix_tokens(&self) -> Vec<i32> {
        let mut tokens = vec![PAD; self.s_max];
        tokens[..self.prompt_len]
            .copy_from_slice(&self.tokens[..self.prompt_len]);
        tokens
    }

    /// Number of already-decoded tokens in block `b`.
    pub fn decoded_in_block(&self, b: usize) -> usize {
        let (lo, hi) = self.block_range(b);
        self.tokens[lo..hi].iter().filter(|&&t| t != MASK).count()
    }

    pub fn completion(&self, b: usize) -> f64 {
        self.decoded_in_block(b) as f64 / self.block as f64
    }

    pub fn block_complete(&self, b: usize) -> bool {
        self.decoded_in_block(b) == self.block
    }

    /// Index of the first block still containing a MASK, if any.
    pub fn first_incomplete_block(&self) -> Option<usize> {
        (0..self.n_blocks()).find(|&b| !self.block_complete(b))
    }

    pub fn all_decoded(&self) -> bool {
        self.first_incomplete_block().is_none()
    }

    /// Position of the first decoded EOS in the generation region.
    pub fn first_eos(&self) -> Option<usize> {
        let (lo, hi) = (self.gen_start(), self.gen_start() + self.gen_len);
        (lo..hi).find(|&i| self.tokens[i] == EOS)
    }

    /// Early-stop condition (paper §3.2): an EOS has been decoded and no
    /// masked position remains before it.
    pub fn eos_settled(&self) -> bool {
        match self.first_eos() {
            None => false,
            Some(e) => {
                !self.tokens[self.gen_start()..e].iter().any(|&t| t == MASK)
            }
        }
    }

    /// Generated output: tokens up to and including the first EOS (or the
    /// full region). Remaining MASKs (when stopped early) are dropped.
    pub fn output(&self) -> Vec<i32> {
        let lo = self.gen_start();
        let hi = match self.first_eos() {
            Some(e) => e + 1,
            None => lo + self.gen_len,
        };
        self.tokens[lo..hi].iter().copied().filter(|&t| t != MASK).collect()
    }

    /// Token count credited to the decode (up to & incl. EOS).
    pub fn gen_token_count(&self) -> usize {
        self.output().len()
    }

    /// Number of generation positions decoded so far (TPF numerator).
    pub fn unmasked_count(&self) -> usize {
        let lo = self.gen_start();
        self.tokens[lo..lo + self.gen_len]
            .iter()
            .filter(|&&t| t != MASK)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st() -> SeqState {
        SeqState::new(&[10, 11, 12], 64, 32, 128)
    }

    #[test]
    fn geometry() {
        let s = st();
        assert_eq!(s.gen_start(), 3);
        assert_eq!(s.n_blocks(), 2);
        assert_eq!(s.block_range(1), (35, 67));
        assert_eq!(s.full_valid().iter().filter(|&&v| v > 0.0).count(), 67);
    }

    #[test]
    fn completion_tracking() {
        let mut s = st();
        assert_eq!(s.completion(0), 0.0);
        for i in 3..3 + 16 {
            s.tokens[i] = 9;
        }
        assert!((s.completion(0) - 0.5).abs() < 1e-12);
        assert_eq!(s.first_incomplete_block(), Some(0));
        for i in 3..35 {
            s.tokens[i] = 9;
        }
        assert!(s.block_complete(0));
        assert_eq!(s.first_incomplete_block(), Some(1));
    }

    #[test]
    fn eos_and_early_stop() {
        let mut s = st();
        s.tokens[5] = EOS;
        assert_eq!(s.first_eos(), Some(5));
        assert!(!s.eos_settled()); // masks at 3,4
        s.tokens[3] = 9;
        s.tokens[4] = 9;
        assert!(s.eos_settled());
        assert_eq!(s.output(), vec![9, 9, EOS]);
        assert_eq!(s.gen_token_count(), 3);
    }

    #[test]
    fn output_without_eos_is_full_region() {
        let mut s = st();
        for i in 3..67 {
            s.tokens[i] = 7;
        }
        assert_eq!(s.output().len(), 64);
    }

    #[test]
    #[should_panic]
    fn rejects_non_block_multiple() {
        SeqState::new(&[1], 33, 32, 128);
    }
}
