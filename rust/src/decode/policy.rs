//! The unified decode-strategy interface: every contender of the paper's
//! §4.1 comparison (AR, vanilla, Fast-dLLM, dParallel, D2F, d3LLM, spec)
//! is a `DecodePolicy` — a resumable state machine that advances one
//! *round* at a time over the shared per-request state (`SeqState` +
//! primary `KvCache` + `GenResult`).
//!
//! A round is split in two so the serving scheduler can batch across
//! sessions:
//!
//!   1. `plan` decides the round's *main forward* and returns it in
//!      backend-call form (`RoundPlan`). Inherently sequential auxiliary
//!      forwards — speculative draft proposals, a second model's prompt
//!      prefill — are issued directly against the backend inside `plan`.
//!   2. `apply` consumes the executed forward's output (`RoundOut`):
//!      unmask decisions, cache commits, accounting. It returns `true`
//!      when the request is finished.
//!
//! The generic driver (`DecodeSession`) owns phase/step/round/wall-time
//! accounting and runs `plan` → execute → `apply`; with one session the
//! forward runs inline (B=1), while `SessionPool::step_round` coalesces
//! the same-shape plans of many runnable sessions into one batched
//! backend call (`Backend::prefill_batch` / `decode_window_batch`).
//! Because a plan is a pure description of a forward, batching cannot
//! change any session's trajectory — per-session outputs are bit-identical
//! to the B=1 path (asserted in `tests/scheduler_determinism.rs`).

use anyhow::{anyhow, Result};

use crate::model::exec::{DecodeOut, PrefillOut};
use crate::model::KvView;

use super::adaptive::RoundBudget;
use super::ar::ArPolicy;
use super::backend::Backend;
use super::multi_block::{BlockState, MultiBlockPolicy};
use super::single_block::{SingleBlockCachedPolicy, SingleBlockNoCachePolicy};
use super::spec::SpecPolicy;
use super::{DecodeCfg, GenResult, SelMetric, SeqState, Strategy};

/// Mutable view of the session-owned state a policy operates on. The
/// session (not the policy) owns these, so phase/progress introspection
/// and result extraction are uniform across strategies.
pub struct PolicyCtx<'a> {
    pub cfg: &'a DecodeCfg,
    pub st: &'a mut SeqState,
    /// Primary (target-model) KV cache view: the dense baseline or a
    /// paged view into the shared pool — policies cannot tell them
    /// apart. Strategy-private caches (e.g. the speculative draft cache)
    /// live inside the policy.
    pub cache: &'a mut dyn KvView,
    pub res: &'a mut GenResult,
    /// This round's adaptive budget, if a controller set one on the
    /// session (`decode::adaptive`). `None` — the common case — is the
    /// static path, bit-identical to the pre-controller behavior.
    pub budget: Option<RoundBudget>,
}

impl PolicyCtx<'_> {
    /// The selection metric this round: the static config metric, with
    /// the budget's threshold substituted when a budget is present.
    pub fn metric(&self) -> SelMetric {
        match self.budget {
            Some(b) => self.cfg.metric.with_threshold(b.entropy_threshold),
            None => self.cfg.metric,
        }
    }

    /// This round's commit cap (`usize::MAX` without a budget).
    pub fn max_unmask(&self) -> usize {
        self.budget.map_or(usize::MAX, |b| b.max_unmask.max(1))
    }

    /// This round's block-span clamp (`usize::MAX` without a budget).
    pub fn block_width(&self) -> usize {
        self.budget.map_or(usize::MAX, |b| b.block_width.max(1))
    }
}

/// The main forward one decode round wants, as owned backend-call
/// buffers (owned so the scheduler can collect plans from many sessions
/// and coalesce the same-shape ones into one batched call).
pub enum RoundPlan {
    /// Full-sequence forward (`Backend::prefill`): prompt prefill, KV
    /// refresh, stabilizing and no-cache decode rounds.
    Full { exec: String, tokens: Vec<i32>, valid: Vec<f32> },
    /// Windowed forward (`Backend::decode_window`) against the session's
    /// primary cache.
    Window {
        exec: String,
        tokens: Vec<i32>,
        pos: Vec<i32>,
        valid: Vec<f32>,
    },
    /// Pure bookkeeping round — no forward; `apply` runs with
    /// `RoundOut::None`.
    Bookkeeping,
    /// The request is finished; `apply` is not called.
    Finished,
}

/// Output of the executed plan, handed back to `DecodePolicy::apply`.
pub enum RoundOut {
    Full(PrefillOut),
    Window(DecodeOut),
    None,
}

pub trait DecodePolicy {
    /// Plan the next round's main forward (see module docs). `ctx.res`
    /// accounting for auxiliary forwards (e.g. `draft_forwards`) happens
    /// here; the main forward is accounted in `apply`.
    fn plan(&mut self, backend: &dyn Backend, params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan>;

    /// Apply the executed forward. Returns `true` when the request is
    /// finished.
    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool>;

    /// Whether the prompt prefill has run. Policies without a distinct
    /// prefill phase (vanilla's no-cache decode) report `true` from the
    /// start. Drives `SessionPhase` and round counting: rounds are the
    /// post-prefill `plan` calls.
    fn prefilled(&self) -> bool {
        true
    }

    /// Prefix-cache hook, called by the session once per round while the
    /// prompt prefill is still pending. When the session cache already
    /// holds every row the prefill forward would install (a paged view
    /// that adopted the whole prompt prefix from the shared pool), the
    /// policy completes its prefill bookkeeping *without* the forward and
    /// returns `true`; the session then proceeds straight into decode
    /// rounds with the exact accounting the post-prefill path would have
    /// had. Sound for every strategy because prefill outputs are used
    /// only to install those rows. Default: never skip (dense caches and
    /// cold pools report `prefix_ready == false`).
    fn try_skip_prefill(&mut self, _backend: &dyn Backend,
                        _ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        Ok(false)
    }

    /// Multi-block policies expose their block states for tests and
    /// introspection; other strategies have none.
    fn block_states(&self) -> Option<&[BlockState]> {
        None
    }

    /// Teacher-extraction policies (`trajectory::TeacherTrajectoryPolicy`)
    /// report the scan step at which each generation offset was unmasked;
    /// the session moves them into `GenResult::unmask_ranks` at `finish`.
    /// Decode strategies have no ranks and return `None`.
    fn take_unmask_ranks(&mut self) -> Option<Vec<i32>> {
        None
    }

    /// Token-at-a-time policies (AR, spec) report how many generation
    /// positions they emitted so the session returns them *verbatim* —
    /// including a model that legitimately argmaxes the MASK id — exactly
    /// like the pre-refactor free functions. Diffusion policies return
    /// `None` and keep the `SeqState::output()` semantics (truncate at
    /// EOS, drop undecoded MASK placeholders).
    fn emitted_len(&self) -> Option<usize> {
        None
    }
}

/// Error message shared by every policy's plan/apply mismatch arm.
pub(crate) fn mismatch(strategy: &'static str) -> anyhow::Error {
    anyhow!("{strategy} policy: applied output does not match the plan")
}

/// Build the policy for `cfg.strategy`. `st` is the freshly initialised
/// sequence state (for block-geometry-dependent setup); `draft_params`
/// is required by `Strategy::Spec` and ignored by everything else.
pub fn make_policy(backend: &dyn Backend, cfg: &DecodeCfg, st: &SeqState,
                   draft_params: Option<&[f32]>)
                   -> Result<Box<dyn DecodePolicy>> {
    Ok(match cfg.strategy {
        Strategy::Ar => Box::new(ArPolicy::new()),
        Strategy::Spec => {
            let draft = draft_params.ok_or_else(|| {
                anyhow!("spec decoding needs --draft checkpoint")
            })?;
            Box::new(SpecPolicy::new(backend, cfg, st, draft)?)
        }
        Strategy::Vanilla | Strategy::FastDllm | Strategy::DParallel => {
            if cfg.use_cache {
                Box::new(SingleBlockCachedPolicy::new(backend, cfg))
            } else {
                Box::new(SingleBlockNoCachePolicy::new(cfg))
            }
        }
        Strategy::D2f | Strategy::D3llm => {
            Box::new(MultiBlockPolicy::new(backend, cfg, st))
        }
    })
}
