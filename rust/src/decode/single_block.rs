//! Single-block decoding policies: vanilla (LLaDA/Dream) and
//! Fast-dLLM-style confidence-threshold parallel decoding with the
//! block-approximate KV cache. dParallel uses the same mechanics with a
//! distilled checkpoint. Selected by `DecodeCfg::use_cache`:
//!
//!   * `SingleBlockNoCachePolicy` — one full no-cache forward per round,
//!     threshold selection restricted to the first incomplete block
//!     (semi-AR block diffusion); with the vanilla preset's unreachable
//!     threshold this is exactly 1 token/step.
//!   * `SingleBlockCachedPolicy` — prompt prefill into the approximate
//!     cache, then per-block windowed forwards; a block's KV rows are
//!     committed when it completes.

use anyhow::Result;

use crate::tokenizer::MASK;

use super::backend::Backend;
use super::policy::{mismatch, DecodePolicy, PolicyCtx, RoundOut, RoundPlan};
use super::{exec_names, DecodeCfg, SelMetric};

/// Threshold-select within `lo..hi` (offsets into `conf`/`entropy` via
/// `base`): always at least the best-scoring masked position. `metric`
/// and `cap` come from the round context, so an adaptive budget
/// substitutes its threshold / commit cap here; without a budget they are
/// the static metric and `usize::MAX` (bit-identical selection).
fn select_in_block(metric: SelMetric, cap: usize, tokens: &[i32],
                   lo: usize, hi: usize, base: usize, conf: &[f32],
                   entropy: &[f32]) -> Vec<usize> {
    let mut best: Option<(usize, f32)> = None;
    let mut selected: Vec<(usize, f32)> = Vec::new();
    for p in lo..hi {
        if tokens[p] != MASK {
            continue;
        }
        let i = p - base;
        let sc = metric.score(conf[i], entropy[i]);
        if best.map(|(_, s)| sc > s).unwrap_or(true) {
            best = Some((p, sc));
        }
        if metric.selects(conf[i], entropy[i]) {
            selected.push((p, sc));
        }
    }
    if selected.is_empty() {
        selected.push(best.expect("incomplete block has masks"));
    }
    if selected.len() > cap.max(1) {
        selected.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        selected.truncate(cap.max(1));
        selected.sort_by_key(|e| e.0);
    }
    selected.into_iter().map(|(p, _)| p).collect()
}

// --------------------------------------------------------------- no-cache

pub struct SingleBlockNoCachePolicy {
    prefill_exec: String,
}

impl SingleBlockNoCachePolicy {
    pub fn new(cfg: &DecodeCfg) -> SingleBlockNoCachePolicy {
        let (prefill_exec, _) = exec_names(&cfg.variant);
        SingleBlockNoCachePolicy { prefill_exec }
    }
}

impl DecodePolicy for SingleBlockNoCachePolicy {
    fn plan(&mut self, _backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if ctx.st.first_incomplete_block().is_none() {
            return Ok(RoundPlan::Finished);
        }
        Ok(RoundPlan::Full {
            exec: self.prefill_exec.clone(),
            tokens: ctx.st.tokens.clone(),
            valid: ctx.st.full_valid(),
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        let RoundOut::Full(out) = out else {
            return Err(mismatch("vanilla"));
        };
        ctx.res.forwards += 1;
        ctx.res.mix.full_forwards += 1;
        let b = ctx.st.first_incomplete_block().expect("planned round");
        let (lo, hi) = ctx.st.block_range(b);
        for p in select_in_block(ctx.metric(), ctx.max_unmask(),
                                 &ctx.st.tokens, lo, hi, 0, &out.conf,
                                 &out.entropy) {
            ctx.res.entropy_sum += out.entropy[p] as f64;
            ctx.res.conf_sum += out.conf[p] as f64;
            ctx.res.quality_commits += 1;
            ctx.st.tokens[p] = out.argmax[p];
        }
        if ctx.cfg.early_stop && ctx.st.eos_settled() {
            return Ok(true);
        }
        Ok(ctx.st.first_incomplete_block().is_none())
    }
}

// ----------------------------------------------------------------- cached

pub struct SingleBlockCachedPolicy {
    prefilled: bool,
    window: usize,
    prefill_exec: String,
    decode_exec: String,
    /// Block planned this round: (index, lo, hi).
    pending: Option<(usize, usize, usize)>,
}

impl SingleBlockCachedPolicy {
    pub fn new(backend: &dyn Backend, cfg: &DecodeCfg)
               -> SingleBlockCachedPolicy {
        let (prefill_exec, decode_exec) = exec_names(&cfg.variant);
        SingleBlockCachedPolicy {
            prefilled: false,
            window: backend.constants().window,
            prefill_exec,
            decode_exec,
            pending: None,
        }
    }
}

impl DecodePolicy for SingleBlockCachedPolicy {
    fn plan(&mut self, _backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if !self.prefilled {
            // prompt prefill (excluded from TPF for every method alike)
            return Ok(RoundPlan::Full {
                exec: self.prefill_exec.clone(),
                tokens: ctx.st.tokens.clone(),
                valid: ctx.st.prompt_valid(),
            });
        }
        let Some(b) = ctx.st.first_incomplete_block() else {
            return Ok(RoundPlan::Finished);
        };
        // window = current block in slots 0..block, rest invalid
        let (lo, hi) = ctx.st.block_range(b);
        let mut win_tokens = vec![0i32; self.window];
        let mut win_pos = vec![0i32; self.window];
        let mut win_valid = vec![0.0f32; self.window];
        for (off, p) in (lo..hi).enumerate() {
            win_tokens[off] = ctx.st.tokens[p];
            win_pos[off] = p as i32;
            win_valid[off] = 1.0;
        }
        self.pending = Some((b, lo, hi));
        Ok(RoundPlan::Window {
            exec: self.decode_exec.clone(),
            tokens: win_tokens,
            pos: win_pos,
            valid: win_valid,
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        match out {
            RoundOut::Full(pre) => {
                ctx.cache.install_full(&pre.kcache, &pre.vcache, 0,
                                       ctx.st.prompt_len)?;
                self.prefilled = true;
                Ok(false)
            }
            RoundOut::Window(out) => {
                let (b, lo, hi) =
                    self.pending.take().ok_or_else(|| mismatch("fast-dllm"))?;
                ctx.res.forwards += 1;
                ctx.res.mix.window_forwards += 1;
                for p in select_in_block(ctx.metric(), ctx.max_unmask(),
                                         &ctx.st.tokens, lo, hi, lo,
                                         &out.conf, &out.entropy) {
                    ctx.res.entropy_sum += out.entropy[p - lo] as f64;
                    ctx.res.conf_sum += out.conf[p - lo] as f64;
                    ctx.res.quality_commits += 1;
                    ctx.st.tokens[p] = out.argmax[p - lo];
                }
                if ctx.st.block_complete(b) {
                    // approximate commit: KV rows from this (last) forward
                    let pairs: Vec<(usize, usize)> =
                        (0..(hi - lo)).map(|off| (off, lo + off)).collect();
                    ctx.cache.commit_window_rows(&out.k_win, &out.v_win,
                                                 self.window, &pairs)?;
                    if ctx.cfg.early_stop && ctx.st.eos_settled() {
                        return Ok(true);
                    }
                    return Ok(ctx.st.first_incomplete_block().is_none());
                }
                if ctx.cfg.early_stop && ctx.st.eos_settled() {
                    return Ok(true);
                }
                Ok(false)
            }
            RoundOut::None => Err(mismatch("fast-dllm")),
        }
    }

    fn prefilled(&self) -> bool {
        self.prefilled
    }

    /// Full-prefix pool hit: skip the prompt-prefill forward (see the
    /// multi-block twin).
    fn try_skip_prefill(&mut self, _backend: &dyn Backend,
                        ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        if self.prefilled || !ctx.cache.prefix_ready(ctx.st.prompt_len) {
            return Ok(false);
        }
        self.prefilled = true;
        Ok(true)
    }
}
