//! Single-block decoding: vanilla (LLaDA/Dream) and Fast-dLLM-style
//! confidence-threshold parallel decoding with the block-approximate
//! KV cache. dParallel uses the same mechanics with a distilled
//! checkpoint.

use anyhow::Result;

use crate::model::{exec, KvCache};
use crate::runtime::Engine;
use crate::tokenizer::MASK;

use super::{exec_names, DecodeCfg, GenResult, SeqState};

pub fn decode_single_block(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                           prompt: &[i32], gen_len: usize)
                           -> Result<GenResult> {
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main")?.clone();
    let (prefill_exec, decode_exec) = exec_names(&cfg.variant);
    let mut st = SeqState::new(prompt, gen_len, c.block, c.s_max);
    let mut res = GenResult::default();

    if cfg.use_cache {
        decode_cached(eng, cfg, params, &mut st, &mut res, &spec,
                      &prefill_exec, &decode_exec, c.window)?;
    } else {
        decode_nocache(eng, cfg, params, &mut st, &mut res, &prefill_exec)?;
    }

    res.tokens = st.output();
    res.unmasked = st.unmasked_count();
    res.mix.gen_tokens = res.unmasked;
    Ok(res)
}

/// Vanilla decoding: one full no-cache forward per unmasked token,
/// restricted to the first incomplete block (semi-AR block diffusion).
fn decode_nocache(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                  st: &mut SeqState, res: &mut GenResult,
                  prefill_exec: &str) -> Result<()> {
    let valid = st.full_valid();
    while let Some(b) = st.first_incomplete_block() {
        let out = exec::prefill(eng, prefill_exec, params, &st.tokens,
                                &valid)?;
        res.forwards += 1;
        res.mix.full_forwards += 1;
        res.rounds += 1;

        let (lo, hi) = st.block_range(b);
        // threshold-select within the block; always unmask at least the best
        let mut best: Option<(usize, f32)> = None;
        let mut selected = Vec::new();
        for i in lo..hi {
            if st.tokens[i] != MASK {
                continue;
            }
            let sc = cfg.metric.score(out.conf[i], out.entropy[i]);
            if best.map(|(_, s)| sc > s).unwrap_or(true) {
                best = Some((i, sc));
            }
            if cfg.metric.selects(out.conf[i], out.entropy[i]) {
                selected.push(i);
            }
        }
        if selected.is_empty() {
            selected.push(best.expect("incomplete block has masks").0);
        }
        for i in selected {
            st.tokens[i] = out.argmax[i];
        }
        if cfg.early_stop && st.eos_settled() {
            break;
        }
    }
    Ok(())
}

/// Fast-dLLM-style: prefill the prompt once into the approximate cache,
/// then per block decode through the windowed executable; the block's KV
/// rows are committed when it completes.
#[allow(clippy::too_many_arguments)]
fn decode_cached(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                 st: &mut SeqState, res: &mut GenResult, spec: &crate::runtime::manifest::ModelSpec,
                 prefill_exec: &str, decode_exec: &str, window: usize)
                 -> Result<()> {
    let mut cache = KvCache::new(spec.n_layers, st.s_max, spec.d_kv);
    // prompt prefill (excluded from TPF for every method alike)
    let mut pv = vec![0.0f32; st.s_max];
    for v in pv.iter_mut().take(st.prompt_len) {
        *v = 1.0;
    }
    let pre = exec::prefill(eng, prefill_exec, params, &st.tokens, &pv)?;
    cache.install_full(&pre.kcache, &pre.vcache, 0, st.prompt_len);

    'blocks: while let Some(b) = st.first_incomplete_block() {
        let (lo, hi) = st.block_range(b);
        loop {
            // window = current block in slots 0..block, rest invalid
            let mut win_tokens = vec![0i32; window];
            let mut win_pos = vec![0i32; window];
            let mut win_valid = vec![0.0f32; window];
            for (off, p) in (lo..hi).enumerate() {
                win_tokens[off] = st.tokens[p];
                win_pos[off] = p as i32;
                win_valid[off] = 1.0;
            }
            let out = exec::decode_window(eng, decode_exec, params,
                                          &win_tokens, &win_pos, &win_valid,
                                          &cache)?;
            res.forwards += 1;
            res.mix.window_forwards += 1;
            res.rounds += 1;

            let mut best: Option<(usize, f32)> = None;
            let mut selected = Vec::new();
            for off in 0..(hi - lo) {
                let p = lo + off;
                if st.tokens[p] != MASK {
                    continue;
                }
                let sc = cfg.metric.score(out.conf[off], out.entropy[off]);
                if best.map(|(_, s)| sc > s).unwrap_or(true) {
                    best = Some((off, sc));
                }
                if cfg.metric.selects(out.conf[off], out.entropy[off]) {
                    selected.push(off);
                }
            }
            if selected.is_empty() {
                selected.push(best.expect("block has masks").0);
            }
            for off in selected {
                st.tokens[lo + off] = out.argmax[off];
            }

            if st.block_complete(b) {
                // approximate commit: KV rows from this (last) forward
                let pairs: Vec<(usize, usize)> =
                    (0..(hi - lo)).map(|off| (off, lo + off)).collect();
                cache.commit_window_rows(&out.k_win, &out.v_win, window,
                                         &pairs);
                if cfg.early_stop && st.eos_settled() {
                    break 'blocks;
                }
                break;
            }
            if cfg.early_stop && st.eos_settled() {
                break 'blocks;
            }
        }
    }
    Ok(())
}
