//! Draft-model speculative decoding (EAGLE-3 analog, paper §A.8).
//!
//! A small AR draft proposes gamma tokens; the AR target verifies them in
//! one windowed causal forward (`ar_verify`). Greedy acceptance: the
//! longest proposal prefix matching the target's own argmax chain is kept,
//! plus the target's token at the first mismatch (the "bonus" token), so
//! every verify round yields >= 1 token and the output is exactly the
//! target's greedy decode — lossless parallelism, the property that lets
//! speculative methods escape the accuracy-parallelism trade-off (§A.8).
//!
//! TPF counts target forwards only (the paper's convention for EAGLE-3);
//! draft forwards are reported separately.

use anyhow::Result;

use crate::model::{exec, KvCache};
use crate::runtime::Engine;
use crate::tokenizer::EOS;

use super::GenResult;

pub fn decode_spec(eng: &Engine, params: &[f32], draft_params: &[f32],
                   prompt: &[i32], gen_len: usize, gamma: usize)
                   -> Result<GenResult> {
    let c = eng.manifest.constants.clone();
    let spec_t = eng.manifest.model("main")?.clone();
    let spec_d = eng.manifest.model("draft")?.clone();
    let w = c.verify_w;
    let gamma = gamma.min(w - 1).max(1);
    let p = prompt.len();
    assert!(p + gen_len <= c.s_max);

    let mut res = GenResult::default();
    let mut t_cache = KvCache::new(spec_t.n_layers, c.s_max, spec_t.d_kv);
    let mut d_cache = KvCache::new(spec_d.n_layers, c.s_max, spec_d.d_kv);

    // exact prefix caches for rows 0..p-2 (the last prompt token flows
    // through the first windowed forward of each model)
    let mut tokens = vec![0i32; c.s_max];
    tokens[..p].copy_from_slice(prompt);
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < p { 1.0 } else { 0.0 }).collect();
    let pre_t = exec::prefill(eng, "ar_prefill", params, &tokens, &valid)?;
    t_cache.install_full(&pre_t.kcache, &pre_t.vcache, 0, p - 1);
    let pre_d =
        exec::prefill(eng, "draft_ar_prefill", draft_params, &tokens, &valid)?;
    d_cache.install_full(&pre_d.kcache, &pre_d.vcache, 0, p - 1);

    // `pending`: last token whose KV row is not yet cached anywhere.
    let mut pending = prompt[p - 1];
    let mut pending_pos = p - 1;
    let mut generated: Vec<i32> = Vec::with_capacity(gen_len);

    'outer: while generated.len() < gen_len {
        // ---- draft proposes gamma tokens (committing its own exact rows)
        let mut proposals = Vec::with_capacity(gamma);
        let mut d_tok = pending;
        let mut d_pos = pending_pos;
        for _ in 0..gamma {
            let out = exec::decode_window(eng, "draft_ar_step", draft_params,
                                          &[d_tok], &[d_pos as i32], &[1.0],
                                          &d_cache)?;
            res.draft_forwards += 1;
            d_cache.commit_window_rows(&out.k_win, &out.v_win, 1,
                                       &[(0, d_pos)]);
            let t = out.argmax[0];
            proposals.push(t);
            d_pos += 1;
            d_tok = t;
        }

        // ---- target verifies in one windowed causal forward
        // window = [pending, d1..dgamma], slot i predicts window[i+1]'s
        // position; slot gamma-? produces the bonus/correction token.
        let mut win_tokens = vec![0i32; w];
        let mut win_pos = vec![0i32; w];
        let mut win_valid = vec![0.0f32; w];
        win_tokens[0] = pending;
        win_pos[0] = pending_pos as i32;
        win_valid[0] = 1.0;
        for (j, &d) in proposals.iter().enumerate() {
            win_tokens[j + 1] = d;
            win_pos[j + 1] = (pending_pos + 1 + j) as i32;
            win_valid[j + 1] = 1.0;
        }
        let out = exec::decode_window(eng, "ar_verify", params, &win_tokens,
                                      &win_pos, &win_valid, &t_cache)?;
        res.forwards += 1;
        res.mix.window_forwards += 1;
        res.rounds += 1;

        // ---- greedy acceptance
        let mut accepted = 0usize;
        while accepted < gamma && out.argmax[accepted] == proposals[accepted] {
            accepted += 1;
        }
        // target rows become exact cache entries for every consumed slot
        let commit: Vec<(usize, usize)> = (0..=accepted)
            .map(|j| (j, pending_pos + j))
            .collect();
        t_cache.commit_window_rows(&out.k_win, &out.v_win, w, &commit);

        // accepted proposals stream out...
        for &d in proposals.iter().take(accepted) {
            generated.push(d);
            if d == EOS || generated.len() >= gen_len {
                break 'outer;
            }
        }
        // ...plus the target's own token at the first mismatch (bonus)
        let bonus = out.argmax[accepted];
        generated.push(bonus);
        if bonus == EOS {
            break;
        }

        // draft cache: rows beyond the accepted prefix are stale
        d_cache.invalidate_from(pending_pos + accepted + 1);
        pending = bonus;
        pending_pos += accepted + 1;
    }

    res.unmasked = generated.len();
    res.tokens = generated;
    res.mix.gen_tokens = res.unmasked;
    Ok(res)
}
