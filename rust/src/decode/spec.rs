//! Draft-model speculative decoding (EAGLE-3 analog, paper §A.8).
//!
//! A small AR draft proposes gamma tokens; the AR target verifies them in
//! one windowed causal forward (`ar_verify`). Greedy acceptance: the
//! longest proposal prefix matching the target's own argmax chain is kept,
//! plus the target's token at the first mismatch (the "bonus" token), so
//! every verify round yields >= 1 token and the output is exactly the
//! target's greedy decode — lossless parallelism, the property that lets
//! speculative methods escape the accuracy-parallelism trade-off (§A.8).
//!
//! As a `DecodePolicy`: the gamma draft proposals are inherently
//! sequential per-session work, so `plan` issues them directly against
//! the backend (with the policy-owned draft cache and parameters) and
//! returns the verify window as the round's batchable main forward — the
//! scheduler can then verify several speculative sessions in one B>1
//! `decode_window_batch` call.
//!
//! TPF counts target forwards only (the paper's convention for EAGLE-3);
//! draft forwards are reported separately.

use anyhow::Result;

use crate::model::KvCache;
use crate::tokenizer::EOS;

use super::backend::Backend;
use super::policy::{mismatch, DecodePolicy, PolicyCtx, RoundOut, RoundPlan};
use super::{DecodeCfg, SeqState};

pub struct SpecPolicy {
    draft_params: Vec<f32>,
    d_cache: KvCache,
    gamma: usize,
    /// Verify window width (`Constants::verify_w`).
    w: usize,
    prefilled: bool,
    /// Last token whose KV row is not yet cached anywhere.
    pending_tok: i32,
    pending_pos: usize,
    /// Generation positions written so far (== tokens emitted).
    produced: usize,
    /// This round's draft proposals (set by `plan`, read by `apply`).
    proposals: Vec<i32>,
}

impl SpecPolicy {
    pub fn new(backend: &dyn Backend, cfg: &DecodeCfg, st: &SeqState,
               draft_params: &[f32]) -> Result<SpecPolicy> {
        let c = backend.constants();
        let spec_d = backend.model_spec("draft")?.clone();
        let w = c.verify_w;
        Ok(SpecPolicy {
            // owned copy per session: acceptable while draft checkpoints
            // are test-sized; the ROADMAP `--draft` serving item should
            // switch this (and `with_draft`) to a shared Arc before real
            // draft models are loaded
            draft_params: draft_params.to_vec(),
            d_cache: KvCache::new(spec_d.n_layers, c.s_max, spec_d.d_kv),
            gamma: cfg.gamma.min(w - 1).max(1),
            w,
            prefilled: false,
            pending_tok: st.tokens[st.prompt_len - 1],
            pending_pos: st.prompt_len - 1,
            produced: 0,
            proposals: Vec::new(),
        })
    }
}

impl DecodePolicy for SpecPolicy {
    fn plan(&mut self, backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if !self.prefilled {
            // exact prefix caches for rows 0..p-2 (the last prompt token
            // flows through the first windowed forward of each model);
            // the draft prefill is auxiliary, the target prefill is the
            // round's main forward
            let p = ctx.st.prompt_len;
            let tokens = ctx.st.prompt_prefix_tokens();
            let valid = ctx.st.prompt_valid();
            let pre_d = backend.prefill("draft_ar_prefill",
                                        &self.draft_params, &tokens, &valid)?;
            self.d_cache.install_full(&pre_d.kcache, &pre_d.vcache, 0, p - 1);
            return Ok(RoundPlan::Full {
                exec: "ar_prefill".to_string(),
                tokens,
                valid,
            });
        }
        if self.produced >= ctx.st.gen_len {
            return Ok(RoundPlan::Finished);
        }

        // ---- draft proposes gamma tokens (committing its own exact rows)
        self.proposals.clear();
        let mut d_tok = self.pending_tok;
        let mut d_pos = self.pending_pos;
        for _ in 0..self.gamma {
            let out = backend.decode_window("draft_ar_step",
                                            &self.draft_params, &[d_tok],
                                            &[d_pos as i32], &[1.0],
                                            &self.d_cache)?;
            ctx.res.draft_forwards += 1;
            self.d_cache.commit_window_rows(&out.k_win, &out.v_win, 1,
                                            &[(0, d_pos)]);
            let t = out.argmax[0];
            self.proposals.push(t);
            d_pos += 1;
            d_tok = t;
        }

        // ---- the target verify window is the batchable main forward:
        // window = [pending, d1..dgamma], slot i predicts window[i+1]'s
        // position; slot `accepted` produces the bonus/correction token.
        let mut win_tokens = vec![0i32; self.w];
        let mut win_pos = vec![0i32; self.w];
        let mut win_valid = vec![0.0f32; self.w];
        win_tokens[0] = self.pending_tok;
        win_pos[0] = self.pending_pos as i32;
        win_valid[0] = 1.0;
        for (j, &d) in self.proposals.iter().enumerate() {
            win_tokens[j + 1] = d;
            win_pos[j + 1] = (self.pending_pos + 1 + j) as i32;
            win_valid[j + 1] = 1.0;
        }
        Ok(RoundPlan::Window {
            exec: "ar_verify".to_string(),
            tokens: win_tokens,
            pos: win_pos,
            valid: win_valid,
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        match out {
            RoundOut::Full(pre_t) => {
                ctx.cache.install_full(&pre_t.kcache, &pre_t.vcache, 0,
                                       ctx.st.prompt_len - 1)?;
                self.prefilled = true;
                Ok(false)
            }
            RoundOut::Window(out) => {
                ctx.res.forwards += 1;
                ctx.res.mix.window_forwards += 1;

                // ---- greedy acceptance
                let proposals = std::mem::take(&mut self.proposals);
                let mut accepted = 0usize;
                while accepted < proposals.len()
                    && out.argmax[accepted] == proposals[accepted]
                {
                    accepted += 1;
                }
                // target rows become exact cache entries for every
                // consumed slot
                let commit: Vec<(usize, usize)> = (0..=accepted)
                    .map(|j| (j, self.pending_pos + j))
                    .collect();
                ctx.cache.commit_window_rows(&out.k_win, &out.v_win, self.w,
                                             &commit)?;

                // accepted proposals stream out...
                let g0 = ctx.st.gen_start();
                for &d in proposals.iter().take(accepted) {
                    ctx.st.tokens[g0 + self.produced] = d;
                    self.produced += 1;
                    if d == EOS || self.produced >= ctx.st.gen_len {
                        return Ok(true);
                    }
                }
                // ...plus the target's own token at the first mismatch
                let bonus = out.argmax[accepted];
                ctx.st.tokens[g0 + self.produced] = bonus;
                self.produced += 1;
                if bonus == EOS {
                    return Ok(true);
                }

                // draft cache: rows beyond the accepted prefix are stale
                self.d_cache
                    .invalidate_from(self.pending_pos + accepted + 1);
                self.pending_tok = bonus;
                self.pending_pos += accepted + 1;
                Ok(self.produced >= ctx.st.gen_len)
            }
            RoundOut::None => Err(mismatch("spec")),
        }
    }

    fn prefilled(&self) -> bool {
        self.prefilled
    }

    /// Full-prefix pool hit on the *target* cache: skip the target
    /// prefill forward. The draft cache is session-private, so its
    /// prefill still runs here as the same auxiliary forward `plan`
    /// would have issued.
    fn try_skip_prefill(&mut self, backend: &dyn Backend,
                        ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        let p = ctx.st.prompt_len;
        if self.prefilled || p < 2 || !ctx.cache.prefix_ready(p - 1) {
            return Ok(false);
        }
        let tokens = ctx.st.prompt_prefix_tokens();
        let valid = ctx.st.prompt_valid();
        let pre_d = backend.prefill("draft_ar_prefill", &self.draft_params,
                                    &tokens, &valid)?;
        self.d_cache.install_full(&pre_d.kcache, &pre_d.vcache, 0, p - 1);
        self.prefilled = true;
        Ok(true)
    }

    fn emitted_len(&self) -> Option<usize> {
        Some(self.produced)
    }
}
