//! Autoregressive baseline (Qwen-2.5 analog): greedy decoding with an
//! exact KV cache, one token per forward — the TPF = 1 reference point for
//! the paper's speedup ratios.

use anyhow::Result;

use crate::model::{exec, KvCache};
use crate::runtime::Engine;
use crate::tokenizer::EOS;

use super::GenResult;

/// Greedy AR decode. `prefix` selects the model family: "" for the main
/// AR checkpoint, "draft_" for the draft model.
pub fn decode_ar_with(eng: &Engine, prefix: &str, params: &[f32],
                      prompt: &[i32], gen_len: usize) -> Result<GenResult> {
    let c = eng.manifest.constants.clone();
    let model_name = if prefix.is_empty() { "main" } else { "draft" };
    let spec = eng.manifest.model(model_name)?.clone();
    let prefill_exec = format!("{prefix}ar_prefill");
    let step_exec = format!("{prefix}ar_step");
    assert!(prompt.len() + gen_len <= c.s_max);

    let mut res = GenResult::default();
    let mut cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);

    // Exact prefix cache for prompt rows 0..p-2; the last prompt token is
    // fed through the first ar_step so its row is computed exactly once.
    let p = prompt.len();
    let mut tokens = vec![0i32; c.s_max];
    tokens[..p].copy_from_slice(prompt);
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < p { 1.0 } else { 0.0 }).collect();
    let pre = exec::prefill(eng, &prefill_exec, params, &tokens, &valid)?;
    cache.install_full(&pre.kcache, &pre.vcache, 0, p - 1);

    let mut generated = Vec::with_capacity(gen_len);
    let mut cur_tok = prompt[p - 1];
    let mut cur_pos = p - 1;
    for _ in 0..gen_len {
        let out = exec::decode_window(eng, &step_exec, params, &[cur_tok],
                                      &[cur_pos as i32], &[1.0], &cache)?;
        res.forwards += 1;
        res.mix.ar_steps += 1;
        // freeze the exact KV row of the token just consumed
        cache.commit_window_rows(&out.k_win, &out.v_win, 1, &[(0, cur_pos)]);
        let next = out.argmax[0];
        generated.push(next);
        if next == EOS {
            break;
        }
        cur_pos += 1;
        cur_tok = next;
    }

    res.unmasked = generated.len();
    res.tokens = generated;
    res.mix.gen_tokens = res.unmasked;
    Ok(res)
}

pub fn decode_ar(eng: &Engine, params: &[f32], prompt: &[i32],
                 gen_len: usize) -> Result<GenResult> {
    decode_ar_with(eng, "", params, prompt, gen_len)
}
