//! Autoregressive baseline (Qwen-2.5 analog): greedy decoding with an
//! exact KV cache, one token per forward — the TPF = 1 reference point for
//! the paper's speedup ratios.
//!
//! Expressed as a `DecodePolicy`: the prompt prefill is the first round's
//! `Full` plan (excluded from TPF, like every strategy's prefill), and
//! each subsequent round plans one `ar_step` window of width 1. Because
//! the plan is just `(exec, [cur_tok], [cur_pos])`, the serving scheduler
//! can coalesce the AR steps of several interleaved sessions into one
//! B>1 `decode_window_batch` call.

use anyhow::Result;

use crate::tokenizer::EOS;

use super::backend::Backend;
use super::policy::{mismatch, DecodePolicy, PolicyCtx, RoundOut, RoundPlan};

pub struct ArPolicy {
    prefilled: bool,
    finished: bool,
    cur_tok: i32,
    cur_pos: usize,
    /// Generation positions written so far (== tokens emitted).
    produced: usize,
}

impl ArPolicy {
    pub fn new() -> ArPolicy {
        ArPolicy {
            prefilled: false,
            finished: false,
            cur_tok: 0,
            cur_pos: 0,
            produced: 0,
        }
    }
}

impl Default for ArPolicy {
    fn default() -> Self {
        ArPolicy::new()
    }
}

impl DecodePolicy for ArPolicy {
    fn plan(&mut self, _backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if !self.prefilled {
            // Exact prefix cache for prompt rows 0..p-2; the last prompt
            // token is fed through the first ar_step so its row is
            // computed exactly once.
            return Ok(RoundPlan::Full {
                exec: "ar_prefill".to_string(),
                tokens: ctx.st.prompt_prefix_tokens(),
                valid: ctx.st.prompt_valid(),
            });
        }
        if self.finished || self.produced >= ctx.st.gen_len {
            return Ok(RoundPlan::Finished);
        }
        Ok(RoundPlan::Window {
            exec: "ar_step".to_string(),
            tokens: vec![self.cur_tok],
            pos: vec![self.cur_pos as i32],
            valid: vec![1.0],
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        match out {
            RoundOut::Full(pre) => {
                let p = ctx.st.prompt_len;
                ctx.cache.install_full(&pre.kcache, &pre.vcache, 0, p - 1)?;
                self.cur_tok = ctx.st.tokens[p - 1];
                self.cur_pos = p - 1;
                self.prefilled = true;
                Ok(false)
            }
            RoundOut::Window(out) => {
                ctx.res.forwards += 1;
                ctx.res.mix.ar_steps += 1;
                // freeze the exact KV row of the token just consumed
                ctx.cache.commit_window_rows(&out.k_win, &out.v_win, 1,
                                             &[(0, self.cur_pos)])?;
                let next = out.argmax[0];
                ctx.st.tokens[ctx.st.gen_start() + self.produced] = next;
                self.produced += 1;
                if next == EOS || self.produced >= ctx.st.gen_len {
                    self.finished = true;
                    return Ok(true);
                }
                self.cur_pos += 1;
                self.cur_tok = next;
                Ok(false)
            }
            RoundOut::None => Err(mismatch("ar")),
        }
    }

    fn prefilled(&self) -> bool {
        self.prefilled
    }

    /// Full-prefix pool hit: rows 0..p-1 are already cached (from another
    /// session's `ar_prefill`), so skip the forward and seed the stepping
    /// state exactly as the prefill apply would have.
    fn try_skip_prefill(&mut self, _backend: &dyn Backend,
                        ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        let p = ctx.st.prompt_len;
        if self.prefilled || p < 2 || !ctx.cache.prefix_ready(p - 1) {
            return Ok(false);
        }
        self.cur_tok = ctx.st.tokens[p - 1];
        self.cur_pos = p - 1;
        self.prefilled = true;
        Ok(true)
    }

    fn emitted_len(&self) -> Option<usize> {
        Some(self.produced)
    }
}
