//! Adaptive parallelism controller: the runtime closed loop that turns
//! the static threshold/width knobs into load-coupled per-round budgets.
//!
//! The accuracy–parallelism dial the paper exposes per *request* (a fixed
//! `SelMetric` threshold and block schedule) becomes a per-*round* control
//! loop spanning the decode and coordinator layers:
//!
//! ```text
//!   Batcher backlog / EWMA wait ──┐
//!   SessionPool runnable width ───┼──> pressure (EWMA, [0,1])
//!                                 │         │
//!   per-session commit entropy ───┘         v
//!   (GenResult.entropy_sum)        RoundBudget { threshold,
//!                                               max_unmask,
//!                                               block_width }
//!                                            │
//!                    DecodePolicy::plan/apply (multi/single block)
//! ```
//!
//! Two modes:
//!
//!   * `off`  — the controller emits no budgets; every decode path is
//!              bit-identical to the static configuration (the serving
//!              determinism pins stay green by construction).
//!   * `load` — thresholds and block widths interpolate between the
//!              session's static operating point (idle) and a calibrated
//!              aggressive bound (saturated), so a backlogged fleet buys
//!              throughput and an idle one buys accuracy.
//!
//! The **accuracy floor is hard**: whatever the load signal does, the
//! emitted threshold never crosses the calibrated per-metric bound
//! (`conf_floor` for confidence metrics, `entropy_ceiling` for entropy
//! metrics — entropy is aggressive-high, so its floor is a ceiling). The
//! floor is enforced by construction in [`AdaptiveController::budget_for`]
//! and validated by a property test plus the AUP regression gate in
//! `benches/adaptive.rs`.
//!
//! The controller is deterministic and threadless — a pure function of the
//! observed load trace — so budget sequences are reproducible run-to-run
//! and pinned in `tests/adaptive.rs`.

use super::{SelMetric, DEFAULT_ENTROPY_THRESHOLD};

/// Width-histogram buckets exported through the stats protocol: emitted
/// block widths land in bucket `min(width, N-1)`.
pub const WIDTH_HIST_BUCKETS: usize = 8;

/// Per-session, per-round decode budget. Policies treat an absent budget
/// as the static path (bit-identical); a present budget substitutes the
/// effective threshold, caps tokens committed per round, and clamps the
/// windowed block span.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundBudget {
    /// Effective selection threshold, on the session metric's own scale
    /// (confidence or entropy).
    pub entropy_threshold: f32,
    /// Cap on tokens committed in one round (`usize::MAX` = uncapped; the
    /// per-block progress guarantees still commit at least one token).
    pub max_unmask: usize,
    /// Cap on active blocks in a windowed multi-block round
    /// (`usize::MAX` = the static geometry cap).
    pub block_width: usize,
}

/// Controller mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveMode {
    /// No budgets: preserve every static pin (default).
    Off,
    /// Load-coupled budgets: aggressive under backlog, conservative idle.
    Load,
}

impl AdaptiveMode {
    pub fn name(&self) -> &'static str {
        match self {
            AdaptiveMode::Off => "off",
            AdaptiveMode::Load => "load",
        }
    }

    pub fn parse(s: &str) -> Option<AdaptiveMode> {
        Some(match s {
            "off" => AdaptiveMode::Off,
            "load" => AdaptiveMode::Load,
            _ => return None,
        })
    }
}

/// Controller configuration: mode, the hard accuracy floor, and the load
/// signal's normalization knobs.
#[derive(Debug, Clone)]
pub struct AdaptiveCfg {
    pub mode: AdaptiveMode,
    /// Accuracy floor for confidence metrics: the emitted confidence
    /// threshold never drops below this (lower confidence threshold =
    /// more aggressive).
    pub conf_floor: f32,
    /// Accuracy floor for entropy metrics: the emitted entropy threshold
    /// never rises above this (higher entropy threshold = more
    /// aggressive). Calibrated to the top of the sweep grid, where the
    /// AUP cost is measured and bounded.
    pub entropy_ceiling: f32,
    /// Widest windowed span (blocks) granted under full pressure; the
    /// geometry cap (`window / block`) still applies downstream.
    pub max_block_width: usize,
    /// Per-round commit cap at full pressure (0 = uncapped).
    pub max_unmask_cap: usize,
    /// Queue depth treated as full pressure.
    pub backlog_full: usize,
    /// Live-session count treated as full pressure (0 disables the
    /// occupancy term). A full pool is load even once the queue has
    /// drained — without this term the controller relaxes mid-drain
    /// while every round is still contended. The serving replica loop
    /// fills in its `max_concurrent_sessions` when left at 0.
    pub pool_full: usize,
    /// Estimated queue wait (ms) treated as full pressure (0 disables the
    /// wait term; pressure then follows queue depth alone).
    pub wait_full_ms: f64,
    /// Per-round latency (ms) treated as full pressure (0 disables the
    /// term). Couples the controller to device-side slowness: a latency
    /// spike raises pressure even when the queue depth is flat, so the
    /// budgets widen before the backlog ever builds.
    pub round_full_ms: f64,
    /// EWMA smoothing factor for the pressure signal, in (0, 1].
    pub alpha: f64,
}

impl Default for AdaptiveCfg {
    fn default() -> AdaptiveCfg {
        AdaptiveCfg {
            mode: AdaptiveMode::Off,
            // bottom of the confidence sweep grid in bench/sweep.rs
            conf_floor: 0.55,
            // top of the entropy sweep grid in bench/sweep.rs
            entropy_ceiling: 1.3,
            max_block_width: 3,
            max_unmask_cap: 0,
            backlog_full: 4,
            pool_full: 0,
            wait_full_ms: 0.0,
            round_full_ms: 0.0,
            alpha: 0.5,
        }
    }
}

/// One observation of coordinator load, taken just before a scheduling
/// round: queue state from `Batcher`, width from `SessionPool`.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadSignal {
    /// Jobs waiting in the batcher queue.
    pub queue_depth: usize,
    /// Sessions currently live in the pool.
    pub active_sessions: usize,
    /// Batcher drain estimate (queue depth x EWMA round time, ms).
    pub est_wait_ms: f64,
    /// Batcher round-time EWMA (ms): how long one scheduling round has
    /// been taking lately, independent of how many jobs are queued.
    pub round_ms: f64,
}

/// Counters and gauges the controller exports through `{"cmd":"stats"}`.
#[derive(Debug, Clone, Default)]
pub struct AdaptiveGauges {
    /// Last emitted threshold x1000 (on the emitting session's metric
    /// scale; 0 until the first budget).
    pub threshold_milli: u64,
    /// Histogram of emitted block widths (bucket = `min(width, 7)`).
    pub width_hist: [u64; WIDTH_HIST_BUCKETS],
    /// Rounds where the pressure-mapped width widened vs. the previous
    /// observation (budget adjusted toward throughput).
    pub adjust_up: u64,
    /// Rounds where it narrowed (budget adjusted toward accuracy).
    pub adjust_down: u64,
}

/// The controller proper: deterministic, threadless, owned by whoever
/// owns the scheduling loop (one per replica in serving; benches and
/// tests drive it directly on a virtual clock).
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    pub cfg: AdaptiveCfg,
    /// Smoothed load pressure in [0, 1].
    pressure: f64,
    /// Width implied by the previous observation (adjust up/down gauges).
    last_width: usize,
    pub gauges: AdaptiveGauges,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveCfg) -> AdaptiveController {
        AdaptiveController {
            cfg,
            pressure: 0.0,
            last_width: 0,
            gauges: AdaptiveGauges::default(),
        }
    }

    /// Whether the controller emits budgets at all.
    pub fn enabled(&self) -> bool {
        self.cfg.mode != AdaptiveMode::Off
    }

    /// Current smoothed pressure in [0, 1].
    pub fn pressure(&self) -> f64 {
        self.pressure
    }

    /// Feed one load observation (call once per scheduling round, before
    /// handing budgets to the pool).
    pub fn observe(&mut self, load: &LoadSignal) {
        if !self.enabled() {
            return;
        }
        let backlog_frac = if self.cfg.backlog_full == 0 {
            0.0
        } else {
            (load.queue_depth as f64 / self.cfg.backlog_full as f64).min(1.0)
        };
        let wait_frac = if self.cfg.wait_full_ms > 0.0 {
            (load.est_wait_ms / self.cfg.wait_full_ms).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let occupancy_frac = if self.cfg.pool_full == 0 {
            0.0
        } else {
            (load.active_sessions as f64 / self.cfg.pool_full as f64)
                .min(1.0)
        };
        let round_frac = if self.cfg.round_full_ms > 0.0 {
            (load.round_ms / self.cfg.round_full_ms).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let raw = backlog_frac
            .max(wait_frac)
            .max(occupancy_frac)
            .max(round_frac);
        let alpha = self.cfg.alpha.clamp(f64::MIN_POSITIVE, 1.0);
        self.pressure = (self.pressure + alpha * (raw - self.pressure))
            .clamp(0.0, 1.0);
        let width = self.width_at_pressure();
        if self.last_width != 0 {
            if width > self.last_width {
                self.gauges.adjust_up += 1;
            } else if width < self.last_width {
                self.gauges.adjust_down += 1;
            }
        }
        self.last_width = width;
    }

    /// Block width the current pressure maps to (>= 1).
    fn width_at_pressure(&self) -> usize {
        let top = self.cfg.max_block_width.max(1);
        1 + (self.pressure * (top - 1) as f64).round() as usize
    }

    /// Effective threshold for a session's metric at the current
    /// pressure. Interpolates from the static base (idle) toward the
    /// calibrated bound (saturated); the bound is a **hard clamp** — a
    /// misconfigured floor tighter than the base pins the output at the
    /// floor rather than ever crossing it.
    fn threshold_for(&self, metric: SelMetric) -> f32 {
        let p = self.pressure as f32;
        match metric {
            SelMetric::Entropy(base) => {
                // aggressive-high: floor is a ceiling
                let hi = self.cfg.entropy_ceiling;
                let lo = base.min(hi);
                lo + p * (hi - lo)
            }
            SelMetric::Conf(base) => {
                // aggressive-low: floor is a floor
                let lo = self.cfg.conf_floor;
                let hi = base.max(lo);
                hi - p * (hi - lo)
            }
        }
    }

    /// Emit the budget for one session this round. `mean_commit_entropy`
    /// is the session's running commit-quality signal
    /// (`GenResult::mean_commit_entropy`): when a session's committed
    /// entropy already runs past the midpoint of its allowed band —
    /// fallback commits dominating selection — the controller halves its
    /// aggressiveness for that session (never the other way, so the floor
    /// clamp is unaffected). Returns `None` in `off` mode.
    pub fn budget_for(&mut self, metric: SelMetric,
                      mean_commit_entropy: f64) -> Option<RoundBudget> {
        if !self.enabled() {
            return None;
        }
        let mut threshold = self.threshold_for(metric);
        if let SelMetric::Entropy(base) = metric {
            let lo = base.min(self.cfg.entropy_ceiling);
            let mid = (lo + self.cfg.entropy_ceiling) * 0.5;
            if mean_commit_entropy > mid as f64 {
                // back off halfway toward the static base
                threshold = lo + (threshold - lo) * 0.5;
            }
        }
        let width = self.width_at_pressure();
        let max_unmask = if self.cfg.max_unmask_cap == 0 {
            usize::MAX
        } else {
            self.cfg.max_unmask_cap.max(1)
        };
        self.gauges.threshold_milli =
            (threshold.max(0.0) * 1000.0).round() as u64;
        self.gauges.width_hist[width.min(WIDTH_HIST_BUCKETS - 1)] += 1;
        Some(RoundBudget {
            entropy_threshold: threshold,
            max_unmask,
            block_width: width,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_cfg() -> AdaptiveCfg {
        AdaptiveCfg { mode: AdaptiveMode::Load, ..AdaptiveCfg::default() }
    }

    #[test]
    fn off_mode_emits_nothing() {
        let mut c = AdaptiveController::new(AdaptiveCfg::default());
        c.observe(&LoadSignal { queue_depth: 99, active_sessions: 9,
                                est_wait_ms: 1e6,
                                ..Default::default() });
        assert!(!c.enabled());
        assert_eq!(c.budget_for(SelMetric::Entropy(0.45), 0.0), None);
        assert_eq!(c.pressure(), 0.0);
        assert_eq!(c.gauges.adjust_up + c.gauges.adjust_down, 0);
    }

    #[test]
    fn idle_load_mode_sits_at_the_static_base() {
        let mut c = AdaptiveController::new(load_cfg());
        c.observe(&LoadSignal::default());
        let b = c
            .budget_for(SelMetric::Entropy(DEFAULT_ENTROPY_THRESHOLD), 0.0)
            .unwrap();
        assert!((b.entropy_threshold - DEFAULT_ENTROPY_THRESHOLD).abs()
                    < 1e-6);
        assert_eq!(b.block_width, 1);
        assert_eq!(b.max_unmask, usize::MAX);
    }

    #[test]
    fn pressure_moves_threshold_toward_the_bound() {
        let mut c = AdaptiveController::new(load_cfg());
        let mut last = 0.0f32;
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 16, active_sessions: 4,
                                    ..Default::default() });
            let b = c.budget_for(SelMetric::Entropy(0.45), 0.0).unwrap();
            assert!(b.entropy_threshold >= last);
            last = b.entropy_threshold;
        }
        // saturated: at the ceiling, widest width, and never past it
        assert!((last - 1.3).abs() < 1e-3, "got {last}");
        let b = c.budget_for(SelMetric::Entropy(0.45), 0.0).unwrap();
        assert_eq!(b.block_width, 3);
        assert!(b.entropy_threshold <= 1.3 + 1e-6);
        // confidence metric moves down toward its floor, never below
        let b = c.budget_for(SelMetric::Conf(0.85), 0.0).unwrap();
        assert!(b.entropy_threshold >= 0.55 - 1e-6);
        assert!(b.entropy_threshold < 0.85);
    }

    #[test]
    fn commit_entropy_feedback_only_backs_off() {
        let mut c = AdaptiveController::new(load_cfg());
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 16, ..Default::default() });
        }
        let hot = c.budget_for(SelMetric::Entropy(0.45), 0.0).unwrap();
        let cooled = c.budget_for(SelMetric::Entropy(0.45), 1.2).unwrap();
        assert!(cooled.entropy_threshold < hot.entropy_threshold);
        assert!(cooled.entropy_threshold >= 0.45 - 1e-6);
    }

    #[test]
    fn misconfigured_floor_pins_at_the_floor() {
        let mut cfg = load_cfg();
        cfg.entropy_ceiling = 0.2; // tighter than the 0.45 base
        cfg.conf_floor = 0.95; // tighter than the 0.85 base
        let mut c = AdaptiveController::new(cfg);
        for q in [0usize, 16, 0, 16] {
            c.observe(&LoadSignal { queue_depth: q, ..Default::default() });
            let e = c.budget_for(SelMetric::Entropy(0.45), 0.0).unwrap();
            assert!(e.entropy_threshold <= 0.2 + 1e-6);
            let f = c.budget_for(SelMetric::Conf(0.85), 0.0).unwrap();
            assert!(f.entropy_threshold >= 0.95 - 1e-6);
        }
    }

    #[test]
    fn gauges_track_adjustments_and_widths() {
        let mut c = AdaptiveController::new(load_cfg());
        for q in [0usize, 16, 16, 16, 0, 0, 0, 16] {
            c.observe(&LoadSignal { queue_depth: q, ..Default::default() });
            c.budget_for(SelMetric::Entropy(0.45), 0.0);
        }
        assert!(c.gauges.adjust_up > 0);
        assert!(c.gauges.adjust_down > 0);
        assert_eq!(c.gauges.width_hist.iter().sum::<u64>(), 8);
        assert!(c.gauges.threshold_milli > 0);
    }

    #[test]
    fn pool_occupancy_holds_pressure_through_a_drain() {
        // queue empty, pool full: the occupancy term keeps pressure up
        let mut cfg = load_cfg();
        cfg.pool_full = 4;
        let mut c = AdaptiveController::new(cfg);
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 0, active_sessions: 4,
                                    ..Default::default() });
        }
        assert!(c.pressure() > 0.99, "got {}", c.pressure());
        // with the term disabled (default), the same trace stays idle
        let mut c = AdaptiveController::new(load_cfg());
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 0, active_sessions: 4,
                                    ..Default::default() });
        }
        assert_eq!(c.pressure(), 0.0);
    }

    #[test]
    fn latency_spike_raises_pressure_at_constant_queue_depth() {
        // the batcher's round-time EWMA is a pressure term of its own:
        // rounds slowing down must raise pressure even while queue depth
        // (and hence the backlog term) stays flat
        let mut cfg = load_cfg();
        cfg.backlog_full = 100; // depth 2 ~ no backlog pressure
        cfg.round_full_ms = 50.0;
        let mut c = AdaptiveController::new(cfg.clone());
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 2, round_ms: 5.0,
                                    ..Default::default() });
        }
        let calm = c.pressure();
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 2, round_ms: 80.0,
                                    ..Default::default() });
        }
        assert!(calm < 0.1, "fast rounds read as load: {calm}");
        assert!(c.pressure() > 0.99,
                "latency spike did not saturate pressure: {}", c.pressure());
        // with the term disabled (default 0), the same spike is invisible
        cfg.round_full_ms = 0.0;
        let mut c = AdaptiveController::new(cfg);
        for _ in 0..12 {
            c.observe(&LoadSignal { queue_depth: 2, round_ms: 80.0,
                                    ..Default::default() });
        }
        assert!(c.pressure() < 0.1, "got {}", c.pressure());
    }

    #[test]
    fn unmask_cap_is_forwarded() {
        let mut cfg = load_cfg();
        cfg.max_unmask_cap = 5;
        let mut c = AdaptiveController::new(cfg);
        c.observe(&LoadSignal::default());
        let b = c.budget_for(SelMetric::Entropy(0.45), 0.0).unwrap();
        assert_eq!(b.max_unmask, 5);
    }
}
