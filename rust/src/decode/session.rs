//! Resumable decode session: the generic driver that advances *any*
//! decode strategy one round at a time, so the coordinator can interleave
//! several in-flight requests on one engine (round-robin continuous
//! serving) and stream partial tokens.
//!
//! The session owns the per-request state every strategy shares — the
//! sequence (`SeqState`), the primary KV cache view, the `GenResult`
//! accounting (steps, rounds, forwards, wall time) — and delegates the
//! strategy mechanics to a `DecodePolicy` (`decode::policy`). One
//! `step()` = plan the round's forward, execute it, apply the unmask
//! decisions. The scheduler (`coordinator::scheduler::SessionPool`)
//! drives `plan_round` / `apply_round` directly instead, so it can
//! coalesce the same-shape forwards of many sessions into one batched
//! backend call; both drivers produce bit-identical per-session results.
//!
//! The primary cache is a `KvView`: `new`/`with_draft` build the dense
//! baseline, `with_pool` builds a `PagedKv` page-table view into a shared
//! `SharedKvPool` — memory scales with live tokens, same-prefix sessions
//! adopt already-prefilled pages (skipping the prompt-prefill forward on
//! a full-prefix hit via `DecodePolicy::try_skip_prefill`), and decode
//! output stays bit-identical to the dense baseline on the deterministic
//! `SimBackend`.
//!
//! The session is generic over the forward provider (`decode::Backend`),
//! so the identical state machine runs against the real PJRT engine or
//! the deterministic `SimBackend` used by scheduler tests and benches.

use std::time::Instant;

use anyhow::Result;

use crate::model::kv_pool::{is_pool_exhausted, PagedKv, SharedKvPool};
use crate::model::{KvCache, KvView};
use crate::runtime::manifest::Constants;

use super::adaptive::RoundBudget;
use super::backend::Backend;
use super::multi_block::BlockState;
use super::policy::{make_policy, DecodePolicy, PolicyCtx, RoundOut,
                    RoundPlan};
use super::{exec_names, DecodeCfg, GenResult, SeqState, Strategy};

/// Coarse lifecycle phase, for scheduler accounting / introspection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionPhase {
    /// Next step performs the prompt prefill.
    Prefill,
    /// Next step performs a decode round.
    Decoding,
    /// Finished; `step` is a no-op and `finish` may be called.
    Done,
}

/// Cheap per-session progress snapshot (the coordinator exports these
/// through the stats protocol).
#[derive(Debug, Clone, Default)]
pub struct SessionProgress {
    /// Generation positions decoded so far.
    pub unmasked: usize,
    /// Generation capacity.
    pub gen_len: usize,
    /// `step()` calls that did work (prefill included).
    pub steps: usize,
    /// Decode rounds completed (prefill excluded).
    pub rounds: usize,
    /// Model forwards issued so far.
    pub forwards: usize,
    /// Full no-cache forwards (refresh / stabilizing) so far.
    pub full_forwards: usize,
    /// Windowed cached forwards so far.
    pub window_forwards: usize,
    /// Rounds the scheduler paused this session (EDF preemption).
    pub paused_rounds: usize,
}

/// KV-pool admission geometry of one request: how many prompt rows its
/// prefill installs (the prefix-sharing domain), under which executable
/// family, and how many sequence rows the session can ever touch. The
/// single source of truth shared by session construction and the serving
/// coordinator's admission budget check.
pub struct KvAdmissionGeometry {
    /// Rows `0..prefix_rows` are installed by the prompt prefill.
    pub prefix_rows: usize,
    /// Prefill executable family the rows come from (`ar_prefill` rows
    /// are causal, `prefill_{variant}` rows bidirectional — they must
    /// never share pages).
    pub prefix_tag: String,
    /// Upper bound on rows this session writes (page reservation).
    pub span_rows: usize,
    /// Causal prefill family: prefix pages are individually adoptable;
    /// bidirectional families adopt all-or-nothing (see `kv_pool`).
    pub causal_prefix: bool,
}

/// Compute the admission geometry for one request.
pub fn kv_admission_geometry(cfg: &DecodeCfg, c: &Constants,
                             prompt_len: usize, gen_len: usize)
                             -> KvAdmissionGeometry {
    match cfg.strategy {
        // AR-family prefills install rows 0..p-1 (the last prompt token
        // flows through the first windowed forward); the speculative
        // verify window can commit target rows a few positions past the
        // generation region
        Strategy::Ar | Strategy::Spec => {
            let extra =
                if cfg.strategy == Strategy::Spec { c.verify_w } else { 0 };
            KvAdmissionGeometry {
                prefix_rows: prompt_len.saturating_sub(1),
                prefix_tag: "ar_prefill".to_string(),
                span_rows: (prompt_len + gen_len + extra).min(c.s_max),
                causal_prefix: true,
            }
        }
        // no-cache decoding never touches the cache: reserve nothing
        Strategy::Vanilla | Strategy::FastDllm | Strategy::DParallel
            if !cfg.use_cache =>
        {
            KvAdmissionGeometry {
                prefix_rows: 0,
                prefix_tag: String::new(),
                span_rows: 0,
                causal_prefix: false,
            }
        }
        _ => KvAdmissionGeometry {
            prefix_rows: prompt_len,
            prefix_tag: exec_names(&cfg.variant).0,
            span_rows: (prompt_len + gen_len).min(c.s_max),
            causal_prefix: false,
        },
    }
}

pub struct DecodeSession {
    pub cfg: DecodeCfg,
    pub st: SeqState,
    /// Primary (target-model) cache view — dense baseline or paged pool
    /// view; strategy-private caches live in the policy.
    pub cache: Box<dyn KvView>,
    pub res: GenResult,
    policy: Box<dyn DecodePolicy>,
    steps: usize,
    /// Rounds a width-pressured scheduler skipped this session
    /// (preemption-by-pausing bookkeeping; never advanced by decoding).
    paused_rounds: usize,
    /// Consecutive paused rounds since the session last planned a round
    /// — the preemption-spill trigger (`SessionPool::spill_after_rounds`).
    paused_streak: usize,
    /// Prefill executable family of the admission geometry: the forward
    /// a spill-restore uses to rebuild rows adoption did not bring back.
    /// Empty for dense / no-cache sessions (they never spill).
    restore_exec: String,
    /// Adaptive budget for the next round(s), set by the coordinator's
    /// controller before each scheduler round. `None` (the default) is
    /// the static path — bit-identical to pre-controller decoding.
    round_budget: Option<RoundBudget>,
    done: bool,
}

impl DecodeSession {
    /// Build a dense-cache session for any strategy except `Spec` (which
    /// needs draft parameters — see `with_draft`).
    pub fn new(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
               gen_len: usize) -> Result<DecodeSession> {
        DecodeSession::with_draft(backend, cfg, prompt, gen_len, None)
    }

    /// Build a dense-cache session for any strategy. `draft_params` is
    /// required by `Strategy::Spec` and ignored by everything else.
    pub fn with_draft(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
                      gen_len: usize, draft_params: Option<&[f32]>)
                      -> Result<DecodeSession> {
        DecodeSession::build(backend, cfg, prompt, gen_len, draft_params,
                             None)
    }

    /// Build a session whose primary cache is a page-table view into the
    /// shared pool: the prompt prefix is probed against the pool's prefix
    /// index (a full hit will skip the prompt-prefill forward) and the
    /// session's page span is reserved against the budget. Fails with a
    /// `kv_pool::is_pool_exhausted` error when the budget cannot cover
    /// the reservation.
    pub fn with_pool(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
                     gen_len: usize, draft_params: Option<&[f32]>,
                     pool: &SharedKvPool) -> Result<DecodeSession> {
        DecodeSession::build(backend, cfg, prompt, gen_len, draft_params,
                             Some(pool))
    }

    fn build(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
             gen_len: usize, draft_params: Option<&[f32]>,
             pool: Option<&SharedKvPool>) -> Result<DecodeSession> {
        let c = backend.constants().clone();
        let block = cfg.strategy.block_granularity(&c);
        let st = SeqState::new(prompt, gen_len, block, c.s_max);
        let policy = make_policy(backend, &cfg, &st, draft_params)?;
        DecodeSession::assemble(backend, cfg, st, policy, pool, None)
    }

    /// Build a session driven by a caller-supplied policy — the hook the
    /// pooled teacher-trajectory extraction uses to run through the same
    /// scheduler as serving decodes. `geo` overrides the strategy-derived
    /// KV admission geometry when a pool is given (a custom policy's
    /// cache footprint is not derivable from `cfg.strategy`).
    pub fn with_policy(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
                       gen_len: usize, policy: Box<dyn DecodePolicy>,
                       pool: Option<&SharedKvPool>,
                       geo: Option<KvAdmissionGeometry>)
                       -> Result<DecodeSession> {
        let c = backend.constants().clone();
        let block = cfg.strategy.block_granularity(&c);
        let st = SeqState::new(prompt, gen_len, block, c.s_max);
        DecodeSession::assemble(backend, cfg, st, policy, pool, geo)
    }

    /// Shared tail of every constructor: bind the cache (dense, or a
    /// paged view admitted under `geo` / the strategy-derived geometry)
    /// and assemble the session around the prepared state + policy.
    fn assemble(backend: &dyn Backend, cfg: DecodeCfg, st: SeqState,
                policy: Box<dyn DecodePolicy>, pool: Option<&SharedKvPool>,
                geo: Option<KvAdmissionGeometry>) -> Result<DecodeSession> {
        let c = backend.constants().clone();
        let spec = backend.model_spec("main")?.clone();
        let mut restore_exec = String::new();
        let cache: Box<dyn KvView> = match pool {
            None => {
                Box::new(KvCache::new(spec.n_layers, st.s_max, spec.d_kv))
            }
            Some(pool) => {
                let geo = geo.unwrap_or_else(|| {
                    kv_admission_geometry(&cfg, &c, st.prompt_len,
                                          st.gen_len)
                });
                restore_exec = geo.prefix_tag.clone();
                Box::new(PagedKv::admit(pool,
                                        &st.tokens[..st.prompt_len],
                                        &geo.prefix_tag, geo.prefix_rows,
                                        geo.span_rows,
                                        geo.causal_prefix)?)
            }
        };
        Ok(DecodeSession {
            cache,
            st,
            cfg,
            res: GenResult::default(),
            policy,
            steps: 0,
            paused_rounds: 0,
            paused_streak: 0,
            restore_exec,
            round_budget: None,
            done: false,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Runnable probe for the scheduler: a session on a single shared
    /// engine is runnable exactly until it finishes. (Kept as a method so
    /// future backends with async forwards can report "blocked".)
    pub fn is_runnable(&self) -> bool {
        !self.done
    }

    pub fn phase(&self) -> SessionPhase {
        if self.done {
            SessionPhase::Done
        } else if !self.policy.prefilled() {
            SessionPhase::Prefill
        } else {
            SessionPhase::Decoding
        }
    }

    /// Stable per-step accounting: how many `step()` calls did work.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Decode rounds completed so far (prefill excluded).
    pub fn rounds(&self) -> usize {
        self.res.rounds
    }

    /// Record one scheduler round that skipped this (runnable) session —
    /// EDF preemption-by-pausing. Pure bookkeeping: pausing never touches
    /// decode state, so a paused session resumes bit-identically.
    pub fn note_paused(&mut self) {
        self.paused_rounds += 1;
        self.paused_streak += 1;
    }

    /// Rounds the scheduler paused this session so far.
    pub fn paused_rounds(&self) -> usize {
        self.paused_rounds
    }

    /// Consecutive paused rounds since the session last planned a round.
    pub fn paused_streak(&self) -> usize {
        self.paused_streak
    }

    /// Install (or clear) the adaptive budget applied to subsequent
    /// rounds. The coordinator's `AdaptiveController` calls this through
    /// `SessionPool::set_budgets` before each scheduler round; `None`
    /// restores the static decode path.
    pub fn set_round_budget(&mut self, budget: Option<RoundBudget>) {
        self.round_budget = budget;
    }

    /// The currently installed adaptive budget, if any.
    pub fn round_budget(&self) -> Option<RoundBudget> {
        self.round_budget
    }

    /// Preemption spill (the SLO follow-on): release the session's paged
    /// KV back to the pool so a long pause frees memory, not just its
    /// round slot. Prefix-indexed pages land in the pool's reclaimable
    /// set — still adoptable, by anyone including this session's own
    /// resume. Returns pages released; `None` when there is nothing to
    /// spill (dense cache, finished, or already spilled).
    pub fn spill_kv(&mut self) -> Option<usize> {
        if self.done {
            return None;
        }
        self.cache.spill()
    }

    /// True while the session's KV is spilled — it must be restored (via
    /// `ensure_kv`, or implicitly by `plan_round`) before decoding.
    pub fn kv_spilled(&self) -> bool {
        self.cache.spilled()
    }

    /// Restore a spilled KV view: re-admit against the pool (prompt
    /// pages usually come back by prefix adoption from the reclaimable
    /// set) and rebuild whatever previously-valid rows did not with one
    /// full forward over the current sequence. Returns `Ok(false)` when
    /// the pool is currently exhausted — the session stays spilled and
    /// the scheduler keeps it paused to retry later. Other errors are
    /// fatal.
    pub fn ensure_kv(&mut self, backend: &dyn Backend, params: &[f32])
                     -> Result<bool> {
        if !self.cache.spilled() {
            return Ok(true);
        }
        match self.restore_spilled_kv(backend, params) {
            Ok(()) => Ok(true),
            Err(e) if is_pool_exhausted(&e) => Ok(false),
            Err(e) => Err(e),
        }
    }

    fn restore_spilled_kv(&mut self, backend: &dyn Backend, params: &[f32])
                          -> Result<()> {
        self.cache.readmit(&self.st.tokens[..self.st.prompt_len])?;
        let runs = self.cache.take_spill_restore_runs();
        if runs.is_empty() {
            return Ok(());
        }
        // One full forward over the current sequence re-derives the rows
        // adoption did not bring back. On the sim backend KV rows are
        // pure functions of (layer, position, token), so the restored
        // content is bit-identical to what was spilled; on a real engine
        // this is the same approximation the KV-refresh path makes.
        let out = backend.prefill(&self.restore_exec, params,
                                  &self.st.tokens, &self.st.full_valid())?;
        for (lo, hi) in runs {
            self.cache.install_full(&out.kcache, &out.vcache, lo, hi)?;
        }
        self.res.forwards += 1;
        self.res.mix.full_forwards += 1;
        Ok(())
    }

    /// Block states of a multi-block session (`None` for strategies
    /// without block structure).
    pub fn block_states(&self) -> Option<&[BlockState]> {
        self.policy.block_states()
    }

    /// Cheap progress snapshot for stats/streaming.
    pub fn progress(&self) -> SessionProgress {
        SessionProgress {
            unmasked: self.st.unmasked_count(),
            gen_len: self.st.gen_len,
            steps: self.steps,
            rounds: self.res.rounds,
            forwards: self.res.forwards,
            full_forwards: self.res.mix.full_forwards,
            window_forwards: self.res.mix.window_forwards,
            paused_rounds: self.paused_rounds,
        }
    }

    /// Tokens decoded so far (snapshot for streaming).
    pub fn snapshot(&self) -> Vec<i32> {
        self.st.output()
    }

    /// Plan this round's main forward (scheduler entry point; `step` is
    /// the inline single-session driver). Advances step/round accounting;
    /// a `Finished` plan retires the session without an `apply_round`.
    pub fn plan_round(&mut self, backend: &dyn Backend, params: &[f32])
                      -> Result<RoundPlan> {
        if self.done {
            return Ok(RoundPlan::Finished);
        }
        if self.cache.spilled() {
            // standalone-driver path; the scheduler restores via
            // `ensure_kv` *before* planning so pool exhaustion keeps the
            // session paused instead of erroring here
            self.restore_spilled_kv(backend, params)?;
        }
        self.paused_streak = 0;
        let t0 = Instant::now();
        self.steps += 1;
        if !self.policy.prefilled() {
            // paged prefix hit: adopt the shared prompt pages' rows
            // instead of planning the prefill forward (no-op on dense
            // caches and cold pools)
            let skipped = {
                let mut ctx = PolicyCtx {
                    cfg: &self.cfg,
                    st: &mut self.st,
                    cache: &mut *self.cache,
                    res: &mut self.res,
                    budget: self.round_budget,
                };
                self.policy.try_skip_prefill(backend, &mut ctx)
            };
            match skipped {
                Ok(true) => self.cache.note_prefill_skipped(),
                Ok(false) => {}
                Err(e) => {
                    self.done = true;
                    self.res.wall_secs += t0.elapsed().as_secs_f64();
                    return Err(e);
                }
            }
        }
        if self.policy.prefilled() {
            self.res.rounds += 1;
        }
        let plan = {
            let mut ctx = PolicyCtx {
                cfg: &self.cfg,
                st: &mut self.st,
                cache: &mut *self.cache,
                res: &mut self.res,
                budget: self.round_budget,
            };
            self.policy.plan(backend, params, &mut ctx)
        };
        self.res.wall_secs += t0.elapsed().as_secs_f64();
        match plan {
            Ok(RoundPlan::Finished) => {
                self.done = true;
                Ok(RoundPlan::Finished)
            }
            Ok(other) => Ok(other),
            Err(e) => {
                self.done = true;
                Err(e)
            }
        }
    }

    /// Apply the executed forward for the round planned by `plan_round`.
    /// Returns true when the request is finished.
    pub fn apply_round(&mut self, out: RoundOut) -> Result<bool> {
        let t0 = Instant::now();
        let finished = {
            let mut ctx = PolicyCtx {
                cfg: &self.cfg,
                st: &mut self.st,
                cache: &mut *self.cache,
                res: &mut self.res,
                budget: self.round_budget,
            };
            self.policy.apply(&mut ctx, out)
        };
        self.res.wall_secs += t0.elapsed().as_secs_f64();
        match finished {
            Ok(true) => {
                self.done = true;
                Ok(true)
            }
            Ok(false) => Ok(false),
            Err(e) => {
                self.done = true;
                Err(e)
            }
        }
    }

    /// Credit engine time spent on this session's share of a (possibly
    /// batched) forward to its wall-time accounting.
    pub fn credit_forward(&mut self, secs: f64) {
        self.res.wall_secs += secs;
    }

    /// Run one decode round inline (B=1). Returns true when the request
    /// is finished. The first call performs the prompt prefill (not
    /// counted in TPF) unless a prefix-cache hit makes it unnecessary.
    pub fn step(&mut self, backend: &dyn Backend, params: &[f32])
                -> Result<bool> {
        if self.done {
            return Ok(true);
        }
        match self.plan_round(backend, params)? {
            RoundPlan::Finished => Ok(true),
            RoundPlan::Bookkeeping => self.apply_round(RoundOut::None),
            RoundPlan::Full { exec, tokens, valid } => {
                let t0 = Instant::now();
                let out =
                    match backend.prefill(&exec, params, &tokens, &valid) {
                        Ok(out) => out,
                        Err(e) => {
                            self.done = true;
                            return Err(e);
                        }
                    };
                self.credit_forward(t0.elapsed().as_secs_f64());
                self.apply_round(RoundOut::Full(out))
            }
            RoundPlan::Window { exec, tokens, pos, valid } => {
                let t0 = Instant::now();
                let out = match backend.decode_window(&exec, params, &tokens,
                                                      &pos, &valid,
                                                      &*self.cache) {
                    Ok(out) => out,
                    Err(e) => {
                        self.done = true;
                        return Err(e);
                    }
                };
                self.credit_forward(t0.elapsed().as_secs_f64());
                self.apply_round(RoundOut::Window(out))
            }
        }
    }

    /// Consume the session into its final result. Token-at-a-time
    /// policies report their emitted count so the generated tokens are
    /// returned verbatim (a model may legitimately argmax the MASK id);
    /// diffusion policies use the `SeqState::output()` semantics.
    pub fn finish(mut self) -> GenResult {
        self.res.unmask_ranks = self.policy.take_unmask_ranks();
        self.res.paused_rounds = self.paused_rounds;
        match self.policy.emitted_len() {
            Some(n) => {
                let lo = self.st.gen_start();
                self.res.tokens = self.st.tokens[lo..lo + n].to_vec();
                self.res.unmasked = n;
            }
            None => {
                self.res.tokens = self.st.output();
                self.res.unmasked = self.st.unmasked_count();
            }
        }
        self.res.mix.gen_tokens = self.res.unmasked;
        self.res
    }
}
