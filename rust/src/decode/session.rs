//! Resumable decode session: the multi-block engine exposed one round at a
//! time, so the coordinator can interleave several in-flight requests on
//! one engine (round-robin continuous serving) and stream partial tokens.
//!
//! `decode_multi_block` is a thin driver over this type; the serving
//! interleaver (`coordinator::scheduler::SessionPool`) is another. The
//! session is generic over the forward provider (`decode::Backend`), so
//! the identical state machine runs against the real PJRT engine or the
//! deterministic `SimBackend` used by scheduler tests and benches.

use anyhow::Result;

use crate::model::KvCache;

use super::backend::Backend;
use super::multi_block::{unmask_round, BlockState, RoundStatsOwned};
use super::{exec_names, DecodeCfg, GenResult, SeqState};

/// Coarse lifecycle phase, for scheduler accounting / introspection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionPhase {
    /// Next step performs the prompt prefill.
    Prefill,
    /// Next step performs a decode round.
    Decoding,
    /// Finished; `step` is a no-op and `finish` may be called.
    Done,
}

/// Cheap per-session progress snapshot (the coordinator exports these
/// through the stats protocol).
#[derive(Debug, Clone, Default)]
pub struct SessionProgress {
    /// Generation positions decoded so far.
    pub unmasked: usize,
    /// Generation capacity.
    pub gen_len: usize,
    /// `step()` calls that did work (prefill included).
    pub steps: usize,
    /// Decode rounds completed (prefill excluded).
    pub rounds: usize,
    /// Model forwards issued so far.
    pub forwards: usize,
    /// Full no-cache forwards (refresh / stabilizing) so far.
    pub full_forwards: usize,
    /// Windowed cached forwards so far.
    pub window_forwards: usize,
}

pub struct DecodeSession {
    pub cfg: DecodeCfg,
    pub st: SeqState,
    pub states: Vec<BlockState>,
    pub cache: KvCache,
    pub res: GenResult,
    round: usize,
    steps: usize,
    prefilled: bool,
    done: bool,
    prefill_exec: String,
    decode_exec: String,
    max_active_blocks: usize,
    window: usize,
}

impl DecodeSession {
    pub fn new(backend: &dyn Backend, cfg: DecodeCfg, prompt: &[i32],
               gen_len: usize) -> Result<DecodeSession> {
        let c = backend.constants().clone();
        let spec = backend.model_spec()?.clone();
        let (prefill_exec, decode_exec) = exec_names(&cfg.variant);
        let st = SeqState::new(prompt, gen_len, c.block, c.s_max);
        let nb = st.n_blocks();
        let mut states = vec![BlockState::Inactive; nb];
        states[0] = BlockState::FullyActivated; // prompt is "complete"
        Ok(DecodeSession {
            cfg,
            cache: KvCache::new(spec.n_layers, st.s_max, spec.d_kv),
            st,
            states,
            res: GenResult::default(),
            round: 0,
            steps: 0,
            prefilled: false,
            done: false,
            prefill_exec,
            decode_exec,
            max_active_blocks: c.window / c.block,
            window: c.window,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Runnable probe for the scheduler: a session on a single shared
    /// engine is runnable exactly until it finishes. (Kept as a method so
    /// future backends with async forwards can report "blocked".)
    pub fn is_runnable(&self) -> bool {
        !self.done
    }

    pub fn phase(&self) -> SessionPhase {
        if self.done {
            SessionPhase::Done
        } else if !self.prefilled {
            SessionPhase::Prefill
        } else {
            SessionPhase::Decoding
        }
    }

    /// Stable per-step accounting: how many `step()` calls did work.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Decode rounds completed so far (prefill excluded).
    pub fn rounds(&self) -> usize {
        self.round
    }

    /// Cheap progress snapshot for stats/streaming.
    pub fn progress(&self) -> SessionProgress {
        SessionProgress {
            unmasked: self.st.unmasked_count(),
            gen_len: self.st.gen_len,
            steps: self.steps,
            rounds: self.round,
            forwards: self.res.forwards,
            full_forwards: self.res.mix.full_forwards,
            window_forwards: self.res.mix.window_forwards,
        }
    }

    /// Tokens decoded so far (snapshot for streaming).
    pub fn snapshot(&self) -> Vec<i32> {
        self.st.output()
    }

    /// Run one decode round. Returns true when the request is finished.
    /// The first call performs the prompt prefill (not counted in TPF).
    pub fn step(&mut self, backend: &dyn Backend, params: &[f32])
                -> Result<bool> {
        if self.done {
            return Ok(true);
        }
        self.steps += 1;
        if !self.prefilled {
            let mut pv = vec![0.0f32; self.st.s_max];
            for v in pv.iter_mut().take(self.st.prompt_len) {
                *v = 1.0;
            }
            let pre = backend.prefill(&self.prefill_exec, params,
                                      &self.st.tokens, &pv)?;
            self.cache.install_full(&pre.kcache, &pre.vcache, 0,
                                    self.st.prompt_len);
            self.prefilled = true;
            return Ok(false);
        }

        let cfg = self.cfg.clone();
        let nb = self.st.n_blocks();
        self.round += 1;
        self.res.rounds += 1;

        let any_stabilizing = self
            .states
            .iter()
            .any(|s| matches!(s, BlockState::Stabilizing(_)));
        let periodic =
            cfg.refresh_every > 0 && self.round % cfg.refresh_every == 0;

        if any_stabilizing || periodic {
            // full no-cache forward: decode + refresh every cached row
            let full_valid = self.st.full_valid();
            let out = backend.prefill(&self.prefill_exec, params,
                                      &self.st.tokens, &full_valid)?;
            self.res.forwards += 1;
            self.res.mix.full_forwards += 1;

            self.cache.install_full(&out.kcache, &out.vcache, 0,
                                    self.st.prompt_len);
            for b in 0..nb {
                let (lo, hi) = self.st.block_range(b);
                match self.states[b] {
                    BlockState::Completed => {
                        self.cache.install_full(&out.kcache, &out.vcache,
                                                lo, hi);
                    }
                    BlockState::Stabilizing(n) => {
                        if n <= 1 {
                            self.cache.install_full(&out.kcache, &out.vcache,
                                                    lo, hi);
                            self.states[b] = BlockState::Completed;
                        } else {
                            self.states[b] = BlockState::Stabilizing(n - 1);
                        }
                    }
                    _ => {}
                }
            }
            let stats = RoundStatsOwned {
                argmax: out.argmax,
                conf: out.conf,
                entropy: out.entropy,
                w_lo: 0,
                w_hi: self.st.s_max,
                absolute: true,
            };
            unmask_round(&cfg, &mut self.st, &mut self.states, &stats, None);
        } else {
            // windowed forward over the active span
            let first = match (0..nb).find(|&b| self.states[b].is_active()) {
                Some(f) => f,
                None => {
                    match (0..nb)
                        .find(|&b| self.states[b] == BlockState::Inactive)
                    {
                        Some(b) => {
                            self.states[b] = BlockState::Activated;
                            return Ok(false);
                        }
                        None => {
                            self.done = true;
                            return Ok(true);
                        }
                    }
                }
            };
            let last =
                (0..nb).rev().find(|&b| self.states[b].is_active()).unwrap();
            let span = (last - first + 1).min(self.max_active_blocks);
            let (w_lo, _) = self.st.block_range(first);
            let w_hi = self.st.block_range(first + span - 1).1;
            let window = self.window;

            let mut win_tokens = vec![0i32; window];
            let mut win_pos = vec![0i32; window];
            let mut win_valid = vec![0.0f32; window];
            for (off, p) in (w_lo..w_hi).enumerate() {
                win_tokens[off] = self.st.tokens[p];
                win_pos[off] = p as i32;
                win_valid[off] =
                    if self.cache.valid[p] > 0.0 { 0.0 } else { 1.0 };
            }
            let out = backend.decode_window(&self.decode_exec, params,
                                            &win_tokens, &win_pos,
                                            &win_valid, &self.cache)?;
            self.res.forwards += 1;
            self.res.mix.window_forwards += 1;

            let stats = RoundStatsOwned {
                argmax: out.argmax.clone(),
                conf: out.conf.clone(),
                entropy: out.entropy.clone(),
                w_lo,
                w_hi,
                absolute: false,
            };
            let completed = unmask_round(&cfg, &mut self.st,
                                         &mut self.states, &stats,
                                         Some((first, first + span)));
            if cfg.stabilize_rounds == 0 {
                for b in completed {
                    let (lo, hi) = self.st.block_range(b);
                    let pairs: Vec<(usize, usize)> =
                        (lo..hi).map(|p| (p - w_lo, p)).collect();
                    if pairs.iter().all(|&(off, _)| off < window) {
                        self.cache.commit_window_rows(&out.k_win, &out.v_win,
                                                      window, &pairs);
                    }
                    self.states[b] = BlockState::Completed;
                }
            }
        }

        // transitions
        for b in 0..nb {
            let pred = if b == 0 { 1.0 } else { self.st.completion(b - 1) };
            match self.states[b] {
                BlockState::Inactive => {
                    let first_inc =
                        self.st.first_incomplete_block().unwrap_or(b);
                    let fits = b < first_inc + self.max_active_blocks;
                    let eos_done =
                        cfg.early_stop && self.st.first_eos().is_some();
                    if fits && !eos_done && pred >= cfg.block_add {
                        self.states[b] = BlockState::Activated;
                    }
                }
                BlockState::Activated => {
                    if pred >= cfg.fully_at {
                        self.states[b] = BlockState::FullyActivated;
                    }
                }
                _ => {}
            }
        }

        let finished = (cfg.early_stop && self.st.eos_settled())
            || (self.st.all_decoded()
                && self
                    .states
                    .iter()
                    .all(|s| *s == BlockState::Completed))
            || (self.st.all_decoded() && cfg.stabilize_rounds == 0);
        if finished {
            self.done = true;
        }
        if self.round > self.st.gen_len * 4 {
            anyhow::bail!("decode session failed to make progress");
        }
        Ok(self.done)
    }

    /// Consume the session into its final result.
    pub fn finish(mut self) -> GenResult {
        self.res.tokens = self.st.output();
        self.res.unmasked = self.st.unmasked_count();
        self.res.mix.gen_tokens = self.res.unmasked;
        self.res
    }
}
