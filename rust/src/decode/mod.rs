//! Decode strategies (paper §3.2 + every contender of §4.1).
//!
//! All strategies run against the same AOT executables and the same
//! `SeqState`; they differ only in *which* forward they issue per round and
//! *which* masked positions they unmask from its statistics:
//!
//!   * `Ar`        — autoregressive baseline, exact KV cache (Qwen analog)
//!   * `Vanilla`   — full no-cache forward, 1 token/step (LLaDA/Dream)
//!   * `FastDllm`  — single-block confidence-threshold parallel decoding
//!                   over the block-approximate cache (Fast-dLLM)
//!   * `DParallel` — FastDllm mechanics; pair with a distilled checkpoint
//!   * `D2f`       — multi-block, confidence threshold, no refresh (D2F)
//!   * `D3llm`     — entropy-based multi-block with the 5-state block
//!                   machine, KV-refresh, early stop (the paper's method)
//!   * `Spec`      — draft-model speculative decoding (EAGLE-3 analog)

pub mod ar;
pub mod backend;
pub mod multi_block;
pub mod seq_state;
pub mod session;
pub mod sim;
pub mod single_block;
pub mod spec;

use anyhow::Result;

pub use backend::Backend;
pub use seq_state::SeqState;
pub use session::{DecodeSession, SessionPhase, SessionProgress};
pub use sim::SimBackend;

use crate::metrics::ForwardMix;
use crate::runtime::Engine;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    Ar,
    Vanilla,
    FastDllm,
    DParallel,
    D2f,
    D3llm,
    Spec,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Ar => "ar",
            Strategy::Vanilla => "vanilla",
            Strategy::FastDllm => "fast-dllm",
            Strategy::DParallel => "dparallel",
            Strategy::D2f => "d2f",
            Strategy::D3llm => "d3llm",
            Strategy::Spec => "spec",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        Some(match s {
            "ar" => Strategy::Ar,
            "vanilla" => Strategy::Vanilla,
            "fast-dllm" => Strategy::FastDllm,
            "dparallel" => Strategy::DParallel,
            "d2f" => Strategy::D2f,
            "d3llm" => Strategy::D3llm,
            "spec" => Strategy::Spec,
            _ => return None,
        })
    }

    /// Whether this strategy decodes through the resumable multi-block
    /// `DecodeSession` (and can therefore be interleaved by the serving
    /// coordinator). Keep in sync when adding a strategy: a resumable
    /// strategy not listed here silently loses interleaving.
    pub fn is_resumable(&self) -> bool {
        matches!(self, Strategy::D2f | Strategy::D3llm)
    }
}

/// Token-selection rule applied to head statistics.
#[derive(Debug, Clone, Copy)]
pub enum SelMetric {
    /// Unmask positions with confidence >= threshold.
    Conf(f32),
    /// Unmask positions with entropy <= threshold (paper's rule).
    Entropy(f32),
}

impl SelMetric {
    #[inline]
    pub fn selects(&self, conf: f32, entropy: f32) -> bool {
        match self {
            SelMetric::Conf(t) => conf >= *t,
            SelMetric::Entropy(t) => entropy <= *t,
        }
    }

    /// Score for "most confident" fallback ordering (higher = better).
    #[inline]
    pub fn score(&self, conf: f32, entropy: f32) -> f32 {
        match self {
            SelMetric::Conf(_) => conf,
            SelMetric::Entropy(_) => -entropy,
        }
    }
}

/// Full decode configuration; presets below give each contender its
/// paper-default knobs, benches sweep the thresholds for AUP curves.
#[derive(Debug, Clone)]
pub struct DecodeCfg {
    pub strategy: Strategy,
    pub metric: SelMetric,
    /// block-add threshold (paper: 0.1)
    pub block_add: f64,
    /// fully-activated threshold (paper: 0.95)
    pub fully_at: f64,
    /// stabilizing rounds after a block completes (paper: 1-2)
    pub stabilize_rounds: usize,
    /// periodic KV refresh every N rounds (0 = off)
    pub refresh_every: usize,
    pub early_stop: bool,
    /// single-block strategies: whether to use the KV cache
    pub use_cache: bool,
    /// speculative decoding: draft proposals per verify round
    pub gamma: usize,
    /// executable variant for the dLLM hot path ("xla" | "pallas")
    pub variant: String,
}

impl DecodeCfg {
    pub fn preset(strategy: Strategy) -> DecodeCfg {
        let base = DecodeCfg {
            strategy,
            metric: SelMetric::Conf(0.85),
            block_add: 0.1,
            fully_at: 0.95,
            stabilize_rounds: 0,
            refresh_every: 0,
            early_stop: true,
            use_cache: true,
            gamma: 7,
            variant: "xla".to_string(),
        };
        match strategy {
            Strategy::Ar | Strategy::Spec => base,
            Strategy::Vanilla => DecodeCfg {
                metric: SelMetric::Conf(2.0), // unreachable => 1 token/step
                early_stop: false,
                use_cache: false,
                ..base
            },
            Strategy::FastDllm | Strategy::DParallel => base,
            Strategy::D2f => DecodeCfg {
                metric: SelMetric::Conf(0.85),
                ..base
            },
            Strategy::D3llm => DecodeCfg {
                metric: SelMetric::Entropy(0.45), // paper: 0.4-0.5
                stabilize_rounds: 1,
                refresh_every: 8,
                ..base
            },
        }
    }

    /// Set the sweep knob (confidence or entropy threshold, per metric).
    pub fn with_threshold(mut self, t: f32) -> DecodeCfg {
        self.metric = match self.metric {
            SelMetric::Conf(_) => SelMetric::Conf(t),
            SelMetric::Entropy(_) => SelMetric::Entropy(t),
        };
        self
    }
}

/// Outcome of decoding one request.
#[derive(Debug, Clone, Default)]
pub struct GenResult {
    /// Generated tokens up to & including EOS.
    pub tokens: Vec<i32>,
    /// Positions decoded during the run (TPF numerator, the paper's
    /// convention: tokens generated per forward, EOS truncation aside).
    pub unmasked: usize,
    /// Target-model decode forwards (TPF denominator).
    pub forwards: usize,
    pub draft_forwards: usize,
    /// Forward mix for the GPU cost model.
    pub mix: ForwardMix,
    pub wall_secs: f64,
    /// Decode rounds (multi-block scheduling iterations).
    pub rounds: usize,
}

impl GenResult {
    pub fn tpf(&self) -> f64 {
        if self.forwards == 0 {
            0.0
        } else {
            self.unmasked as f64 / self.forwards as f64
        }
    }
}

/// Decode one request with the configured strategy.
///
/// `params` is the target checkpoint; `draft_params` is only used by
/// `Strategy::Spec`.
pub fn generate(eng: &Engine, cfg: &DecodeCfg, params: &[f32],
                draft_params: Option<&[f32]>, prompt: &[i32],
                gen_len: usize) -> Result<GenResult> {
    let t0 = std::time::Instant::now();
    let mut result = match cfg.strategy {
        Strategy::Ar => ar::decode_ar(eng, params, prompt, gen_len)?,
        Strategy::Spec => spec::decode_spec(
            eng,
            params,
            draft_params.ok_or_else(|| {
                anyhow::anyhow!("spec decoding needs --draft checkpoint")
            })?,
            prompt,
            gen_len,
            cfg.gamma,
        )?,
        Strategy::Vanilla | Strategy::FastDllm | Strategy::DParallel => {
            single_block::decode_single_block(eng, cfg, params, prompt,
                                              gen_len)?
        }
        Strategy::D2f | Strategy::D3llm => {
            multi_block::decode_multi_block(eng, cfg, params, prompt,
                                            gen_len)?
        }
    };
    result.wall_secs = t0.elapsed().as_secs_f64();
    Ok(result)
}

/// Executable names for a hot-path variant.
pub fn exec_names(variant: &str) -> (String, String) {
    (format!("prefill_{variant}"), format!("decode_{variant}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_defaults() {
        let d3 = DecodeCfg::preset(Strategy::D3llm);
        assert!(matches!(d3.metric, SelMetric::Entropy(_)));
        assert!(d3.stabilize_rounds >= 1);
        assert!(d3.refresh_every > 0);
        assert!(d3.early_stop);
        assert!((d3.block_add - 0.1).abs() < 1e-9);
        assert!((d3.fully_at - 0.95).abs() < 1e-9);

        let v = DecodeCfg::preset(Strategy::Vanilla);
        assert!(!v.use_cache);
        assert!(!v.early_stop);

        let d2f = DecodeCfg::preset(Strategy::D2f);
        assert_eq!(d2f.stabilize_rounds, 0);
        assert_eq!(d2f.refresh_every, 0);
    }

    #[test]
    fn metric_selection() {
        let c = SelMetric::Conf(0.9);
        assert!(c.selects(0.95, 1.0));
        assert!(!c.selects(0.85, 0.0));
        let e = SelMetric::Entropy(0.5);
        assert!(e.selects(0.1, 0.4));
        assert!(!e.selects(0.99, 0.6));
    }

    #[test]
    fn threshold_override() {
        let cfg = DecodeCfg::preset(Strategy::D3llm).with_threshold(0.8);
        match cfg.metric {
            SelMetric::Entropy(t) => assert!((t - 0.8).abs() < 1e-6),
            _ => panic!("metric kind must be preserved"),
        }
    }
}
