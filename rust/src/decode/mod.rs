//! Decode strategies (paper §3.2 + every contender of §4.1).
//!
//! All strategies are `DecodePolicy` implementations (decode/policy.rs)
//! over the same `Backend` forwards and the same `SeqState`; they differ
//! only in *which* forward they plan per round and *which* masked
//! positions they unmask from its statistics:
//!
//!   * `Ar`        — autoregressive baseline, exact KV cache (Qwen analog)
//!   * `Vanilla`   — full no-cache forward, 1 token/step (LLaDA/Dream)
//!   * `FastDllm`  — single-block confidence-threshold parallel decoding
//!                   over the block-approximate cache (Fast-dLLM)
//!   * `DParallel` — FastDllm mechanics; pair with a distilled checkpoint
//!   * `D2f`       — multi-block, confidence threshold, no refresh (D2F)
//!   * `D3llm`     — entropy-based multi-block with the 5-state block
//!                   machine, KV-refresh, early stop (the paper's method)
//!   * `Spec`      — draft-model speculative decoding (EAGLE-3 analog)
//!
//! Every strategy decodes through the resumable `DecodeSession`, so every
//! strategy interleaves in the serving coordinator and runs against the
//! deterministic `SimBackend`; `generate` is the one-shot run-to-
//! completion wrapper kept for the CLI / eval / bench paths.

pub mod adaptive;
pub mod ar;
pub mod backend;
pub mod multi_block;
pub mod policy;
pub mod seq_state;
pub mod session;
pub mod sim;
pub mod single_block;
pub mod spec;

use anyhow::Result;

pub use adaptive::{AdaptiveCfg, AdaptiveController, AdaptiveMode,
                   LoadSignal, RoundBudget, WIDTH_HIST_BUCKETS};
pub use backend::{Backend, PrefillItem, WindowItem};
pub use policy::{DecodePolicy, PolicyCtx, RoundOut, RoundPlan};
pub use seq_state::SeqState;
pub use session::{kv_admission_geometry, DecodeSession,
                  KvAdmissionGeometry, SessionPhase, SessionProgress};
pub use sim::SimBackend;

use crate::metrics::ForwardMix;
use crate::runtime::manifest::Constants;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Ar,
    Vanilla,
    FastDllm,
    DParallel,
    D2f,
    D3llm,
    Spec,
}

impl Strategy {
    /// Every strategy, in the paper's presentation order. The exhaustive
    /// `match` in `name()` keeps this list honest — adding a variant
    /// without extending both is a compile error there and a test failure
    /// in `tests/policy_api.rs` (round-trip + session construction per
    /// variant).
    pub const ALL: [Strategy; 7] = [
        Strategy::Ar,
        Strategy::Vanilla,
        Strategy::FastDllm,
        Strategy::DParallel,
        Strategy::D2f,
        Strategy::D3llm,
        Strategy::Spec,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Ar => "ar",
            Strategy::Vanilla => "vanilla",
            Strategy::FastDllm => "fast-dllm",
            Strategy::DParallel => "dparallel",
            Strategy::D2f => "d2f",
            Strategy::D3llm => "d3llm",
            Strategy::Spec => "spec",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        Some(match s {
            "ar" => Strategy::Ar,
            "vanilla" => Strategy::Vanilla,
            "fast-dllm" => Strategy::FastDllm,
            "dparallel" => Strategy::DParallel,
            "d2f" => Strategy::D2f,
            "d3llm" => Strategy::D3llm,
            "spec" => Strategy::Spec,
            _ => return None,
        })
    }

    /// Sequence block granularity for this strategy's `SeqState`:
    /// token-at-a-time strategies (exact-cache AR, speculative) have no
    /// block structure — granularity 1 frees them from the
    /// `gen_len % block == 0` constraint — while diffusion strategies use
    /// the lowered block size. Exhaustive on purpose: a new strategy must
    /// choose its granularity here.
    pub fn block_granularity(&self, c: &Constants) -> usize {
        match self {
            Strategy::Ar | Strategy::Spec => 1,
            Strategy::Vanilla
            | Strategy::FastDllm
            | Strategy::DParallel
            | Strategy::D2f
            | Strategy::D3llm => c.block,
        }
    }
}

/// Paper-default d3LLM entropy threshold (paper: 0.4–0.5). The single
/// source of truth shared by the `Strategy::D3llm` preset, the CLI parse
/// fallback in `config`, and the sweep grid in `bench/sweep.rs`.
pub const DEFAULT_ENTROPY_THRESHOLD: f32 = 0.45;

/// Token-selection rule applied to head statistics.
#[derive(Debug, Clone, Copy)]
pub enum SelMetric {
    /// Unmask positions with confidence >= threshold.
    Conf(f32),
    /// Unmask positions with entropy <= threshold (paper's rule).
    Entropy(f32),
}

impl SelMetric {
    #[inline]
    pub fn selects(&self, conf: f32, entropy: f32) -> bool {
        match self {
            SelMetric::Conf(t) => conf >= *t,
            SelMetric::Entropy(t) => entropy <= *t,
        }
    }

    /// Score for "most confident" fallback ordering (higher = better).
    #[inline]
    pub fn score(&self, conf: f32, entropy: f32) -> f32 {
        match self {
            SelMetric::Conf(_) => conf,
            SelMetric::Entropy(_) => -entropy,
        }
    }

    /// The raw threshold value, on this metric's own scale.
    #[inline]
    pub fn threshold(&self) -> f32 {
        match self {
            SelMetric::Conf(t) | SelMetric::Entropy(t) => *t,
        }
    }

    /// Same metric kind with a different threshold.
    #[inline]
    pub fn with_threshold(&self, t: f32) -> SelMetric {
        match self {
            SelMetric::Conf(_) => SelMetric::Conf(t),
            SelMetric::Entropy(_) => SelMetric::Entropy(t),
        }
    }
}

/// Full decode configuration; presets below give each contender its
/// paper-default knobs, benches sweep the thresholds for AUP curves.
#[derive(Debug, Clone)]
pub struct DecodeCfg {
    pub strategy: Strategy,
    pub metric: SelMetric,
    /// block-add threshold (paper: 0.1)
    pub block_add: f64,
    /// fully-activated threshold (paper: 0.95)
    pub fully_at: f64,
    /// stabilizing rounds after a block completes (paper: 1-2)
    pub stabilize_rounds: usize,
    /// periodic KV refresh every N rounds (0 = off)
    pub refresh_every: usize,
    pub early_stop: bool,
    /// single-block strategies: whether to use the KV cache
    pub use_cache: bool,
    /// speculative decoding: draft proposals per verify round
    pub gamma: usize,
    /// executable variant for the dLLM hot path ("xla" | "pallas")
    pub variant: String,
}

impl DecodeCfg {
    pub fn preset(strategy: Strategy) -> DecodeCfg {
        let base = DecodeCfg {
            strategy,
            metric: SelMetric::Conf(0.85),
            block_add: 0.1,
            fully_at: 0.95,
            stabilize_rounds: 0,
            refresh_every: 0,
            early_stop: true,
            use_cache: true,
            gamma: 7,
            variant: "xla".to_string(),
        };
        match strategy {
            Strategy::Ar | Strategy::Spec => base,
            Strategy::Vanilla => DecodeCfg {
                metric: SelMetric::Conf(2.0), // unreachable => 1 token/step
                early_stop: false,
                use_cache: false,
                ..base
            },
            Strategy::FastDllm | Strategy::DParallel => base,
            Strategy::D2f => DecodeCfg {
                metric: SelMetric::Conf(0.85),
                ..base
            },
            Strategy::D3llm => DecodeCfg {
                metric: SelMetric::Entropy(DEFAULT_ENTROPY_THRESHOLD),
                stabilize_rounds: 1,
                refresh_every: 8,
                ..base
            },
        }
    }

    /// Set the sweep knob (confidence or entropy threshold, per metric).
    pub fn with_threshold(mut self, t: f32) -> DecodeCfg {
        self.metric = self.metric.with_threshold(t);
        self
    }
}

/// Outcome of decoding one request.
#[derive(Debug, Clone, Default)]
pub struct GenResult {
    /// Generated tokens up to & including EOS.
    pub tokens: Vec<i32>,
    /// Positions decoded during the run (TPF numerator, the paper's
    /// convention: tokens generated per forward, EOS truncation aside).
    pub unmasked: usize,
    /// Target-model decode forwards (TPF denominator).
    pub forwards: usize,
    pub draft_forwards: usize,
    /// Forward mix for the GPU cost model.
    pub mix: ForwardMix,
    /// Engine + host time attributable to this request: planning, its
    /// share of (possibly batched) forwards, and unmask application.
    /// Recorded by `DecodeSession` itself, so interleaved sessions report
    /// it too; `generate` overwrites it with end-to-end elapsed time.
    pub wall_secs: f64,
    /// Decode rounds (scheduling iterations; one main forward at most).
    pub rounds: usize,
    /// Rounds a width-pressured scheduler paused this session (EDF
    /// preemption-by-pausing; zero outside SLO serving).
    pub paused_rounds: usize,
    /// Sum of selection-time entropies over committed tokens (windowed
    /// selection paths; the adaptive controller's per-session quality
    /// signal — see `decode::adaptive`).
    pub entropy_sum: f64,
    /// Sum of selection-time confidences over committed tokens (windowed
    /// selection paths; commit-quality proxy for AUP-under-load benches).
    pub conf_sum: f64,
    /// Commits covered by `entropy_sum`/`conf_sum` (updated live, unlike
    /// `unmasked` which is finalized at `finish`).
    pub quality_commits: usize,
    /// Teacher-extraction sessions: the scan step at which each
    /// generation offset was unmasked (`None` for decode strategies).
    pub unmask_ranks: Option<Vec<i32>>,
}

impl GenResult {
    pub fn tpf(&self) -> f64 {
        if self.forwards == 0 {
            0.0
        } else {
            self.unmasked as f64 / self.forwards as f64
        }
    }

    /// Mean selection-time entropy over committed tokens; 0.0 until the
    /// session commits (or for strategies that don't record it).
    pub fn mean_commit_entropy(&self) -> f64 {
        if self.quality_commits == 0 {
            0.0
        } else {
            self.entropy_sum / self.quality_commits as f64
        }
    }

    /// Mean selection-time confidence over committed tokens (see
    /// `mean_commit_entropy`).
    pub fn mean_commit_conf(&self) -> f64 {
        if self.quality_commits == 0 {
            0.0
        } else {
            self.conf_sum / self.quality_commits as f64
        }
    }
}

/// Decode one request with the configured strategy: a thin run-to-
/// completion wrapper over `DecodeSession`, kept for CLI / eval / bench
/// compatibility.
///
/// `params` is the target checkpoint; `draft_params` is only used by
/// `Strategy::Spec`.
pub fn generate(backend: &dyn Backend, cfg: &DecodeCfg, params: &[f32],
                draft_params: Option<&[f32]>, prompt: &[i32],
                gen_len: usize) -> Result<GenResult> {
    let t0 = std::time::Instant::now();
    let mut session = DecodeSession::with_draft(backend, cfg.clone(), prompt,
                                                gen_len, draft_params)?;
    while !session.step(backend, params)? {}
    let mut result = session.finish();
    result.wall_secs = t0.elapsed().as_secs_f64();
    Ok(result)
}

/// Executable names for a hot-path variant.
pub fn exec_names(variant: &str) -> (String, String) {
    (format!("prefill_{variant}"), format!("decode_{variant}"))
}

/// Every executable a strategy's sessions may request (the serving
/// coordinator pre-compiles these so first-request latency is decode,
/// not XLA compilation).
pub fn strategy_exec_names(strategy: Strategy, variant: &str) -> Vec<String> {
    let (prefill, dec) = exec_names(variant);
    match strategy {
        Strategy::Ar => vec!["ar_prefill".into(), "ar_step".into()],
        Strategy::Spec => vec![
            "ar_prefill".into(),
            "ar_verify".into(),
            "draft_ar_prefill".into(),
            "draft_ar_step".into(),
        ],
        Strategy::Vanilla => vec![prefill],
        Strategy::FastDllm
        | Strategy::DParallel
        | Strategy::D2f
        | Strategy::D3llm => vec![prefill, dec],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_defaults() {
        let d3 = DecodeCfg::preset(Strategy::D3llm);
        assert!(matches!(d3.metric, SelMetric::Entropy(_)));
        assert!(d3.stabilize_rounds >= 1);
        assert!(d3.refresh_every > 0);
        assert!(d3.early_stop);
        assert!((d3.block_add - 0.1).abs() < 1e-9);
        assert!((d3.fully_at - 0.95).abs() < 1e-9);

        let v = DecodeCfg::preset(Strategy::Vanilla);
        assert!(!v.use_cache);
        assert!(!v.early_stop);

        let d2f = DecodeCfg::preset(Strategy::D2f);
        assert_eq!(d2f.stabilize_rounds, 0);
        assert_eq!(d2f.refresh_every, 0);
    }

    #[test]
    fn metric_selection() {
        let c = SelMetric::Conf(0.9);
        assert!(c.selects(0.95, 1.0));
        assert!(!c.selects(0.85, 0.0));
        let e = SelMetric::Entropy(0.5);
        assert!(e.selects(0.1, 0.4));
        assert!(!e.selects(0.99, 0.6));
    }

    #[test]
    fn threshold_override() {
        let cfg = DecodeCfg::preset(Strategy::D3llm).with_threshold(0.8);
        match cfg.metric {
            SelMetric::Entropy(t) => assert!((t - 0.8).abs() < 1e-6),
            _ => panic!("metric kind must be preserved"),
        }
    }

    #[test]
    fn strategy_exec_names_cover_every_variant() {
        for s in Strategy::ALL {
            let names = strategy_exec_names(s, "xla");
            assert!(!names.is_empty(), "{} has no executables", s.name());
        }
    }
}
