//! Entropy-based multi-block decoding with the 5-state block machine and
//! the KV-cache refresh mechanism (paper §3.2). Also covers D2F
//! (confidence metric, no stabilizing, no refresh) via configuration.
//!
//! Block lifecycle:
//!   Inactive -> Activated            predecessor >= block_add (10%)
//!   Activated -> FullyActivated      predecessor >= fully_at (95%)
//!   (any active, fully unmasked) -> Stabilizing(stabilize_rounds)
//!   Stabilizing(0) -> Completed      rows frozen into the cache
//!
//! While any block is Stabilizing — and every `refresh_every`-th round —
//! the round's forward is a full no-cache forward whose KV output also
//! *refreshes every previously cached row* (the KV-refresh mechanism).
//! Otherwise the round is a windowed forward over the active span against
//! the approximate cache.
//!
//! `MultiBlockPolicy` implements the `DecodePolicy` plan/apply split: the
//! round's forward is returned as a batchable plan, and the unmask /
//! state-transition mechanics run in `apply`. This module holds the block
//! state machine, the selection rule, and the one-request driver; the
//! generic round loop lives in `DecodeSession` (decode/session.rs).

use anyhow::Result;

use crate::tokenizer::MASK;

use super::adaptive::RoundBudget;
use super::backend::Backend;
use super::policy::{mismatch, DecodePolicy, PolicyCtx, RoundOut, RoundPlan};
use super::session::DecodeSession;
use super::{exec_names, DecodeCfg, GenResult, SelMetric, SeqState};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BlockState {
    Inactive,
    Activated,
    FullyActivated,
    /// Completed but stabilizing: n full-forward rounds remain before the
    /// block's KV rows are frozen.
    Stabilizing(usize),
    Completed,
}

impl BlockState {
    pub fn is_active(&self) -> bool {
        matches!(self, BlockState::Activated | BlockState::FullyActivated)
    }

    pub fn is_done(&self) -> bool {
        matches!(self, BlockState::Stabilizing(_) | BlockState::Completed)
    }
}

/// Head statistics for one round, from either a windowed forward
/// (positions w_lo..w_hi map to slice offsets) or a full forward
/// (absolute indexing).
pub struct RoundStatsOwned {
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
    pub w_lo: usize,
    pub w_hi: usize,
    pub absolute: bool,
}

impl RoundStatsOwned {
    #[inline]
    pub fn index(&self, p: usize) -> Option<usize> {
        if self.absolute {
            (p < self.argmax.len()).then_some(p)
        } else {
            (p >= self.w_lo && p < self.w_hi).then(|| p - self.w_lo)
        }
    }
}

/// One-request driver over the resumable session. Accepts any forward
/// provider: the PJRT `Engine` or the deterministic `SimBackend`.
pub fn decode_multi_block(backend: &dyn Backend, cfg: &DecodeCfg,
                          params: &[f32], prompt: &[i32], gen_len: usize)
                          -> Result<GenResult> {
    let mut session =
        DecodeSession::new(backend, cfg.clone(), prompt, gen_len)?;
    while !session.step(backend, params)? {}
    Ok(session.finish())
}

/// Apply one round of threshold selection. Active blocks decode
/// conservatively (threshold only); FullyActivated blocks decode at least
/// one token per forward. Returns blocks that became fully unmasked.
pub fn unmask_round(cfg: &DecodeCfg, st: &mut SeqState,
                    states: &mut [BlockState], stats: &RoundStatsOwned,
                    restrict: Option<(usize, usize)>) -> Vec<usize> {
    unmask_round_budgeted(cfg, None, st, states, stats, restrict, None)
}

/// Budget-aware [`unmask_round`]: an adaptive [`RoundBudget`] substitutes
/// its threshold into the selection metric and caps the round's commits
/// at `max_unmask` (highest-score commits win; the progress guarantees
/// still land at least one token). `res`, when provided, accumulates the
/// selection-time entropy/confidence of every commit — the controller's
/// quality signal. With `budget == None` the selection is bit-identical
/// to the static path.
pub fn unmask_round_budgeted(cfg: &DecodeCfg, budget: Option<RoundBudget>,
                             st: &mut SeqState, states: &mut [BlockState],
                             stats: &RoundStatsOwned,
                             restrict: Option<(usize, usize)>,
                             mut res: Option<&mut GenResult>)
                             -> Vec<usize> {
    let metric: SelMetric = match budget {
        Some(b) => cfg.metric.with_threshold(b.entropy_threshold),
        None => cfg.metric,
    };
    let cap = budget.map_or(usize::MAX, |b| b.max_unmask.max(1));
    let nb = st.n_blocks();
    let (b_lo, b_hi) = restrict.unwrap_or((0, nb));
    let mut newly_complete = Vec::new();
    let mut any_selected = false;
    let mut global_best: Option<(usize, f32)> = None;

    // (position, token, score) — the score orders the cap truncation
    let mut to_unmask: Vec<(usize, i32, f32)> = Vec::new();
    for b in b_lo..b_hi.min(nb) {
        if !states[b].is_active() {
            continue;
        }
        let (lo, hi) = st.block_range(b);
        let mut block_best: Option<(usize, f32)> = None;
        let mut block_selected = false;
        for p in lo..hi {
            if st.tokens[p] != MASK {
                continue;
            }
            let Some(i) = stats.index(p) else { continue };
            let (cf, en) = (stats.conf[i], stats.entropy[i]);
            let sc = metric.score(cf, en);
            if block_best.map(|(_, s)| sc > s).unwrap_or(true) {
                block_best = Some((p, sc));
            }
            if global_best.map(|(_, s)| sc > s).unwrap_or(true) {
                global_best = Some((p, sc));
            }
            if metric.selects(cf, en) {
                to_unmask.push((p, stats.argmax[i], sc));
                block_selected = true;
                any_selected = true;
            }
        }
        // aggressive mode: FullyActivated decodes >=1 token per forward
        if !block_selected && states[b] == BlockState::FullyActivated {
            if let Some((p, sc)) = block_best {
                let i = stats.index(p).unwrap();
                to_unmask.push((p, stats.argmax[i], sc));
                any_selected = true;
            }
        }
    }
    // global progress guarantee: never waste a forward entirely
    if !any_selected {
        if let Some((p, sc)) = global_best {
            let i = stats.index(p).unwrap();
            to_unmask.push((p, stats.argmax[i], sc));
        }
    }
    if to_unmask.len() > cap {
        // keep the best-scoring commits, deterministically (ties by
        // position), then restore positional order
        to_unmask.sort_by(|a, b| b.2.total_cmp(&a.2).then(a.0.cmp(&b.0)));
        to_unmask.truncate(cap);
        to_unmask.sort_by_key(|e| e.0);
    }
    for (p, t, _) in to_unmask {
        if let Some(r) = res.as_deref_mut() {
            let i = stats.index(p).unwrap();
            r.entropy_sum += stats.entropy[i] as f64;
            r.conf_sum += stats.conf[i] as f64;
            r.quality_commits += 1;
        }
        st.tokens[p] = t;
    }
    for b in 0..nb {
        if states[b].is_active() && st.block_complete(b) {
            newly_complete.push(b);
            if cfg.stabilize_rounds > 0 {
                states[b] = BlockState::Stabilizing(cfg.stabilize_rounds);
            }
        }
    }
    newly_complete
}

// ----------------------------------------------------------------- policy

/// Which forward the current round planned (so `apply` knows how to
/// consume the output).
enum Pending {
    None,
    Prefill,
    Refresh,
    Window { w_lo: usize, w_hi: usize, first: usize, span: usize },
}

pub struct MultiBlockPolicy {
    states: Vec<BlockState>,
    prefilled: bool,
    pending: Pending,
    max_active_blocks: usize,
    window: usize,
    prefill_exec: String,
    decode_exec: String,
}

impl MultiBlockPolicy {
    pub fn new(backend: &dyn Backend, cfg: &DecodeCfg, st: &SeqState)
               -> MultiBlockPolicy {
        let c = backend.constants();
        let (prefill_exec, decode_exec) = exec_names(&cfg.variant);
        let mut states = vec![BlockState::Inactive; st.n_blocks()];
        if let Some(s0) = states.first_mut() {
            *s0 = BlockState::FullyActivated; // prompt is "complete"
        }
        MultiBlockPolicy {
            states,
            prefilled: false,
            pending: Pending::None,
            max_active_blocks: c.window / c.block,
            window: c.window,
            prefill_exec,
            decode_exec,
        }
    }

    /// Post-round block transitions + termination check (identical for
    /// full-refresh and windowed rounds).
    fn finish_round(&mut self, ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        let cfg = ctx.cfg;
        let nb = ctx.st.n_blocks();
        for b in 0..nb {
            let pred = if b == 0 { 1.0 } else { ctx.st.completion(b - 1) };
            match self.states[b] {
                BlockState::Inactive => {
                    let first_inc =
                        ctx.st.first_incomplete_block().unwrap_or(b);
                    let fits = b < first_inc + self.max_active_blocks;
                    let eos_done =
                        cfg.early_stop && ctx.st.first_eos().is_some();
                    if fits && !eos_done && pred >= cfg.block_add {
                        self.states[b] = BlockState::Activated;
                    }
                }
                BlockState::Activated => {
                    if pred >= cfg.fully_at {
                        self.states[b] = BlockState::FullyActivated;
                    }
                }
                _ => {}
            }
        }

        let finished = (cfg.early_stop && ctx.st.eos_settled())
            || (ctx.st.all_decoded()
                && self
                    .states
                    .iter()
                    .all(|s| *s == BlockState::Completed))
            || (ctx.st.all_decoded() && cfg.stabilize_rounds == 0);
        if ctx.res.rounds > ctx.st.gen_len * 4 {
            anyhow::bail!("decode session failed to make progress");
        }
        Ok(finished)
    }
}

impl DecodePolicy for MultiBlockPolicy {
    fn plan(&mut self, _backend: &dyn Backend, _params: &[f32],
            ctx: &mut PolicyCtx<'_>) -> Result<RoundPlan> {
        if !self.prefilled {
            self.pending = Pending::Prefill;
            return Ok(RoundPlan::Full {
                exec: self.prefill_exec.clone(),
                tokens: ctx.st.tokens.clone(),
                valid: ctx.st.prompt_valid(),
            });
        }

        let cfg = ctx.cfg;
        let nb = ctx.st.n_blocks();
        let any_stabilizing = self
            .states
            .iter()
            .any(|s| matches!(s, BlockState::Stabilizing(_)));
        // `ctx.res.rounds` was already advanced for this round by the
        // session driver, so the periodic check sees the current round.
        let periodic =
            cfg.refresh_every > 0 && ctx.res.rounds % cfg.refresh_every == 0;

        if any_stabilizing || periodic {
            // full no-cache forward: decode + refresh every cached row
            self.pending = Pending::Refresh;
            return Ok(RoundPlan::Full {
                exec: self.prefill_exec.clone(),
                tokens: ctx.st.tokens.clone(),
                valid: ctx.st.full_valid(),
            });
        }

        // windowed forward over the active span
        let first = match (0..nb).find(|&b| self.states[b].is_active()) {
            Some(f) => f,
            None => {
                return match (0..nb)
                    .find(|&b| self.states[b] == BlockState::Inactive)
                {
                    Some(b) => {
                        self.states[b] = BlockState::Activated;
                        self.pending = Pending::None;
                        Ok(RoundPlan::Bookkeeping)
                    }
                    None => Ok(RoundPlan::Finished),
                };
            }
        };
        let last =
            (0..nb).rev().find(|&b| self.states[b].is_active()).unwrap();
        let span = (last - first + 1)
            .min(self.max_active_blocks)
            .min(ctx.block_width());
        let (w_lo, _) = ctx.st.block_range(first);
        let w_hi = ctx.st.block_range(first + span - 1).1;

        let mut win_tokens = vec![0i32; self.window];
        let mut win_pos = vec![0i32; self.window];
        let mut win_valid = vec![0.0f32; self.window];
        for (off, p) in (w_lo..w_hi).enumerate() {
            win_tokens[off] = ctx.st.tokens[p];
            win_pos[off] = p as i32;
            win_valid[off] =
                if ctx.cache.is_valid(p) { 0.0 } else { 1.0 };
        }
        self.pending = Pending::Window { w_lo, w_hi, first, span };
        Ok(RoundPlan::Window {
            exec: self.decode_exec.clone(),
            tokens: win_tokens,
            pos: win_pos,
            valid: win_valid,
        })
    }

    fn apply(&mut self, ctx: &mut PolicyCtx<'_>, out: RoundOut)
             -> Result<bool> {
        let pending = std::mem::replace(&mut self.pending, Pending::None);
        match (pending, out) {
            (Pending::Prefill, RoundOut::Full(pre)) => {
                ctx.cache.install_full(&pre.kcache, &pre.vcache, 0,
                                       ctx.st.prompt_len)?;
                self.prefilled = true;
                Ok(false)
            }
            (Pending::None, RoundOut::None) => Ok(false),
            (Pending::Refresh, RoundOut::Full(out)) => {
                ctx.res.forwards += 1;
                ctx.res.mix.full_forwards += 1;

                let nb = ctx.st.n_blocks();
                ctx.cache.install_full(&out.kcache, &out.vcache, 0,
                                       ctx.st.prompt_len)?;
                for b in 0..nb {
                    let (lo, hi) = ctx.st.block_range(b);
                    match self.states[b] {
                        BlockState::Completed => {
                            ctx.cache.install_full(&out.kcache, &out.vcache,
                                                   lo, hi)?;
                        }
                        BlockState::Stabilizing(n) => {
                            if n <= 1 {
                                ctx.cache.install_full(&out.kcache,
                                                       &out.vcache, lo,
                                                       hi)?;
                                self.states[b] = BlockState::Completed;
                            } else {
                                self.states[b] =
                                    BlockState::Stabilizing(n - 1);
                            }
                        }
                        _ => {}
                    }
                }
                let stats = RoundStatsOwned {
                    argmax: out.argmax,
                    conf: out.conf,
                    entropy: out.entropy,
                    w_lo: 0,
                    w_hi: ctx.st.s_max,
                    absolute: true,
                };
                unmask_round_budgeted(ctx.cfg, ctx.budget, ctx.st,
                                      &mut self.states, &stats, None,
                                      Some(&mut *ctx.res));
                self.finish_round(ctx)
            }
            (Pending::Window { w_lo, w_hi, first, span },
             RoundOut::Window(out)) => {
                ctx.res.forwards += 1;
                ctx.res.mix.window_forwards += 1;

                let stats = RoundStatsOwned {
                    argmax: out.argmax.clone(),
                    conf: out.conf.clone(),
                    entropy: out.entropy.clone(),
                    w_lo,
                    w_hi,
                    absolute: false,
                };
                let completed = unmask_round_budgeted(
                    ctx.cfg, ctx.budget, ctx.st, &mut self.states, &stats,
                    Some((first, first + span)), Some(&mut *ctx.res));
                if ctx.cfg.stabilize_rounds == 0 {
                    for b in completed {
                        let (lo, hi) = ctx.st.block_range(b);
                        let pairs: Vec<(usize, usize)> =
                            (lo..hi).map(|p| (p - w_lo, p)).collect();
                        if pairs.iter().all(|&(off, _)| off < self.window) {
                            ctx.cache.commit_window_rows(&out.k_win,
                                                         &out.v_win,
                                                         self.window,
                                                         &pairs)?;
                        }
                        self.states[b] = BlockState::Completed;
                    }
                }
                self.finish_round(ctx)
            }
            _ => Err(mismatch("multi-block")),
        }
    }

    fn prefilled(&self) -> bool {
        self.prefilled
    }

    /// Full-prefix pool hit: the cache already holds every prompt row the
    /// prefill would install, so skip the forward (its output is used for
    /// nothing else) and go straight to decode rounds.
    fn try_skip_prefill(&mut self, _backend: &dyn Backend,
                        ctx: &mut PolicyCtx<'_>) -> Result<bool> {
        if self.prefilled || !ctx.cache.prefix_ready(ctx.st.prompt_len) {
            return Ok(false);
        }
        self.prefilled = true;
        Ok(true)
    }

    fn block_states(&self) -> Option<&[BlockState]> {
        Some(&self.states)
    }
}
