//! Entropy-based multi-block decoding with the 5-state block machine and
//! the KV-cache refresh mechanism (paper §3.2). Also covers D2F
//! (confidence metric, no stabilizing, no refresh) via configuration.
//!
//! Block lifecycle:
//!   Inactive -> Activated            predecessor >= block_add (10%)
//!   Activated -> FullyActivated      predecessor >= fully_at (95%)
//!   (any active, fully unmasked) -> Stabilizing(stabilize_rounds)
//!   Stabilizing(0) -> Completed      rows frozen into the cache
//!
//! While any block is Stabilizing — and every `refresh_every`-th round —
//! the round's forward is a full no-cache forward whose KV output also
//! *refreshes every previously cached row* (the KV-refresh mechanism).
//! Otherwise the round is a windowed forward over the active span against
//! the approximate cache.
//!
//! The round mechanics live in `DecodeSession` (decode/session.rs) so the
//! coordinator can interleave several requests; this module holds the
//! block state machine, the selection rule, and the one-request driver.

use anyhow::Result;

use crate::tokenizer::MASK;

use super::backend::Backend;
use super::session::DecodeSession;
use super::{DecodeCfg, GenResult, SeqState};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BlockState {
    Inactive,
    Activated,
    FullyActivated,
    /// Completed but stabilizing: n full-forward rounds remain before the
    /// block's KV rows are frozen.
    Stabilizing(usize),
    Completed,
}

impl BlockState {
    pub fn is_active(&self) -> bool {
        matches!(self, BlockState::Activated | BlockState::FullyActivated)
    }

    pub fn is_done(&self) -> bool {
        matches!(self, BlockState::Stabilizing(_) | BlockState::Completed)
    }
}

/// Head statistics for one round, from either a windowed forward
/// (positions w_lo..w_hi map to slice offsets) or a full forward
/// (absolute indexing).
pub struct RoundStatsOwned {
    pub argmax: Vec<i32>,
    pub conf: Vec<f32>,
    pub entropy: Vec<f32>,
    pub w_lo: usize,
    pub w_hi: usize,
    pub absolute: bool,
}

impl RoundStatsOwned {
    #[inline]
    pub fn index(&self, p: usize) -> Option<usize> {
        if self.absolute {
            (p < self.argmax.len()).then_some(p)
        } else {
            (p >= self.w_lo && p < self.w_hi).then(|| p - self.w_lo)
        }
    }
}

/// One-request driver over the resumable session. Accepts any forward
/// provider: the PJRT `Engine` or the deterministic `SimBackend`.
pub fn decode_multi_block(backend: &dyn Backend, cfg: &DecodeCfg,
                          params: &[f32], prompt: &[i32], gen_len: usize)
                          -> Result<GenResult> {
    let mut session =
        DecodeSession::new(backend, cfg.clone(), prompt, gen_len)?;
    while !session.step(backend, params)? {}
    Ok(session.finish())
}

/// Apply one round of threshold selection. Active blocks decode
/// conservatively (threshold only); FullyActivated blocks decode at least
/// one token per forward. Returns blocks that became fully unmasked.
pub fn unmask_round(cfg: &DecodeCfg, st: &mut SeqState,
                    states: &mut [BlockState], stats: &RoundStatsOwned,
                    restrict: Option<(usize, usize)>) -> Vec<usize> {
    let nb = st.n_blocks();
    let (b_lo, b_hi) = restrict.unwrap_or((0, nb));
    let mut newly_complete = Vec::new();
    let mut any_selected = false;
    let mut global_best: Option<(usize, f32)> = None;

    let mut to_unmask: Vec<(usize, i32)> = Vec::new();
    for b in b_lo..b_hi.min(nb) {
        if !states[b].is_active() {
            continue;
        }
        let (lo, hi) = st.block_range(b);
        let mut block_best: Option<(usize, f32)> = None;
        let mut block_selected = false;
        for p in lo..hi {
            if st.tokens[p] != MASK {
                continue;
            }
            let Some(i) = stats.index(p) else { continue };
            let (cf, en) = (stats.conf[i], stats.entropy[i]);
            let sc = cfg.metric.score(cf, en);
            if block_best.map(|(_, s)| sc > s).unwrap_or(true) {
                block_best = Some((p, sc));
            }
            if global_best.map(|(_, s)| sc > s).unwrap_or(true) {
                global_best = Some((p, sc));
            }
            if cfg.metric.selects(cf, en) {
                to_unmask.push((p, stats.argmax[i]));
                block_selected = true;
                any_selected = true;
            }
        }
        // aggressive mode: FullyActivated decodes >=1 token per forward
        if !block_selected && states[b] == BlockState::FullyActivated {
            if let Some((p, _)) = block_best {
                let i = stats.index(p).unwrap();
                to_unmask.push((p, stats.argmax[i]));
                any_selected = true;
            }
        }
    }
    // global progress guarantee: never waste a forward entirely
    if !any_selected {
        if let Some((p, _)) = global_best {
            let i = stats.index(p).unwrap();
            to_unmask.push((p, stats.argmax[i]));
        }
    }
    for (p, t) in to_unmask {
        st.tokens[p] = t;
    }
    for b in 0..nb {
        if states[b].is_active() && st.block_complete(b) {
            newly_complete.push(b);
            if cfg.stabilize_rounds > 0 {
                states[b] = BlockState::Stabilizing(cfg.stabilize_rounds);
            }
        }
    }
    newly_complete
}
