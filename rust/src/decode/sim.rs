//! Deterministic simulated backend for the decode state machine.
//!
//! `SimBackend` implements `Backend` with a pure function of the call
//! inputs: head statistics (argmax / confidence / entropy) and KV rows are
//! seeded hashes of the visible token content, so
//!
//!   * the same session state always produces the same forward outputs —
//!     interleaving sessions in any order cannot change any single
//!     session's decode trajectory (the scheduler-determinism tests and
//!     `benches/interleave.rs` rely on this), and
//!   * outputs *re-roll* as tokens get unmasked (the hash covers the
//!     window content), so threshold selection makes geometric progress
//!     like a real model instead of degenerating to one token per round.
//!
//! The batched entry points (`prefill_batch` / `decode_window_batch`) are
//! overridden with a single-pass implementation over the stacked batch —
//! the sim analog of a lowered B>1 executable. Per-item outputs are pure
//! functions of that item's inputs, so they are bit-identical to the B=1
//! path; call/batch-size telemetry is recorded so scheduler tests can
//! assert that round coalescing actually happened.
//!
//! No artifacts, no PJRT, no I/O: this is the CI-safe harness for every
//! scheduler and block-state-machine property.

use std::cell::Cell;

use anyhow::{bail, Result};

use crate::model::exec::{DecodeOut, PrefillOut, TrainOut, TrajectoryOut};
use crate::model::KvView;
use crate::runtime::manifest::{Constants, ModelSpec, TensorSpec};

use super::backend::{Backend, PrefillItem, WindowItem};

/// Geometry matching the shipped artifacts (see python/compile/config.py
/// and the manifest loader's test fixture).
pub fn sim_constants() -> Constants {
    Constants {
        vocab: 128,
        pad_id: 0,
        mask_id: 1,
        eos_id: 2,
        bos_id: 3,
        sep_id: 4,
        s_max: 384,
        s_train: 192,
        gen_max: 128,
        gen_train: 96,
        window: 96,
        block: 32,
        verify_w: 16,
        b_train: 8,
        b_traj: 8,
        rank_never: 100000,
    }
}

/// Simulated parameter count: small but nonzero so the full training
/// pipeline (`ParamStore::init` -> `train_step` -> checkpoint round-trip)
/// runs on the sim geometry. The decode forwards only fingerprint the
/// parameter vector, so any length keeps working there.
pub const SIM_PARAMS: usize = 64;

fn sim_model_spec(c: &Constants) -> ModelSpec {
    ModelSpec {
        name: "sim".to_string(),
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 2,
        d_ff: 16,
        vocab: c.vocab,
        s_max: c.s_max,
        d_kv: 4,
        total_params: SIM_PARAMS,
        param_layout: vec![TensorSpec {
            name: "sim.w".to_string(),
            shape: vec![SIM_PARAMS],
            offset: 0,
            size: SIM_PARAMS,
            init: "normal".to_string(),
        }],
    }
}

pub struct SimBackend {
    constants: Constants,
    spec: ModelSpec,
    seed: u64,
    /// When set, roughly this fraction of positions argmax to EOS, for
    /// exercising the early-stop paths. Default: no EOS (full decodes).
    eos_rate: f64,
    // ---- telemetry (Cell: the backend is used single-threaded behind
    // `&dyn Backend`, like the RefCell-caching Engine)
    /// Individual full forwards computed (batch items included) — the
    /// prefix-sharing benches measure skipped prompt prefills with this.
    prefill_calls: Cell<usize>,
    /// Individual windowed forwards computed (batch items included).
    window_calls: Cell<usize>,
    prefill_batch_calls: Cell<usize>,
    prefill_batch_items: Cell<usize>,
    max_prefill_batch: Cell<usize>,
    window_batch_calls: Cell<usize>,
    window_batch_items: Cell<usize>,
    max_window_batch: Cell<usize>,
    /// Fused train steps executed.
    train_steps: Cell<usize>,
    /// Sample rows routed through the on-device-style `trajectory` scan.
    trajectory_rows: Cell<usize>,
}

impl SimBackend {
    pub fn new(seed: u64) -> SimBackend {
        let constants = sim_constants();
        let spec = sim_model_spec(&constants);
        SimBackend {
            constants,
            spec,
            seed,
            eos_rate: 0.0,
            prefill_calls: Cell::new(0),
            window_calls: Cell::new(0),
            prefill_batch_calls: Cell::new(0),
            prefill_batch_items: Cell::new(0),
            max_prefill_batch: Cell::new(0),
            window_batch_calls: Cell::new(0),
            window_batch_items: Cell::new(0),
            max_window_batch: Cell::new(0),
            train_steps: Cell::new(0),
            trajectory_rows: Cell::new(0),
        }
    }

    /// Enable EOS predictions at roughly `rate` of positions.
    pub fn with_eos_rate(mut self, rate: f64) -> SimBackend {
        self.eos_rate = rate;
        self
    }

    /// Individual full forwards computed so far (batch items included).
    pub fn prefill_calls(&self) -> usize {
        self.prefill_calls.get()
    }

    /// Individual windowed forwards computed so far (batch items
    /// included).
    pub fn window_calls(&self) -> usize {
        self.window_calls.get()
    }

    /// Batched full-forward calls taken (each covering >= 1 items).
    pub fn prefill_batch_calls(&self) -> usize {
        self.prefill_batch_calls.get()
    }

    /// Total items routed through `prefill_batch`.
    pub fn prefill_batch_items(&self) -> usize {
        self.prefill_batch_items.get()
    }

    /// Largest B seen by `prefill_batch`.
    pub fn max_prefill_batch(&self) -> usize {
        self.max_prefill_batch.get()
    }

    /// Batched windowed-forward calls taken (each covering >= 1 items).
    pub fn window_batch_calls(&self) -> usize {
        self.window_batch_calls.get()
    }

    /// Total items routed through `decode_window_batch`.
    pub fn window_batch_items(&self) -> usize {
        self.window_batch_items.get()
    }

    /// Largest B seen by `decode_window_batch`.
    pub fn max_window_batch(&self) -> usize {
        self.max_window_batch.get()
    }

    /// Fused train steps executed so far.
    pub fn train_steps(&self) -> usize {
        self.train_steps.get()
    }

    /// Sample rows routed through the whole-scan `trajectory` entry point
    /// so far (the pooled extraction path does not use it).
    pub fn trajectory_rows(&self) -> usize {
        self.trajectory_rows.get()
    }

    #[inline]
    fn mix(mut z: u64) -> u64 {
        z = z.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// FNV over the visible token content: the "model's view" fingerprint.
    fn context_hash(&self, tokens: &[i32], valid_or_pos: &[i32]) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325 ^ self.seed;
        for (&t, &m) in tokens.iter().zip(valid_or_pos.iter()) {
            h ^= (t as u64) ^ ((m as u64) << 32);
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Per-position head statistics: (argmax, conf, entropy).
    fn stats_at(&self, ctx: u64, pos: usize, token: i32)
                -> (i32, f32, f32) {
        let h = Self::mix(
            ctx ^ Self::mix((pos as u64) << 1 ^ ((token as u64) << 20)),
        );
        // uniform fractions from disjoint bit ranges
        let u1 = ((h >> 11) & 0x3FFFFF) as f64 / 0x400000 as f64;
        let u2 = ((h >> 33) & 0x3FFFFF) as f64 / 0x400000 as f64;
        let max_ent = (self.constants.vocab as f64).ln();
        // low entropy <-> high confidence, ~30% of draws under 0.45 ent
        let entropy = (u1 * u1 * max_ent) as f32;
        let conf = (1.0 - u1 * 0.9).min(1.0) as f32;
        let n_words = (self.constants.vocab - 5) as u64;
        let mut argmax = 5 + (h % n_words) as i32;
        if self.eos_rate > 0.0 && u2 < self.eos_rate {
            argmax = self.constants.eos_id;
        }
        (argmax, conf, entropy)
    }

    /// Deterministic KV row value, keyed by absolute position so windowed
    /// and full forwards agree on committed rows.
    fn kv_at(&self, layer: usize, pos: usize, j: usize, token: i32) -> f32 {
        let h = Self::mix(
            self.seed
                ^ ((layer as u64) << 48)
                ^ ((pos as u64) << 24)
                ^ ((j as u64) << 8)
                ^ (token as u64),
        );
        ((h >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }

    /// One full forward: the pure per-item function both `prefill` and
    /// the batched path share (bit-identity between B=1 and B>1).
    fn prefill_one(&self, params: &[f32], tokens: &[i32], valid: &[f32])
                   -> Result<PrefillOut> {
        self.prefill_calls.set(self.prefill_calls.get() + 1);
        let s = self.constants.s_max;
        if tokens.len() != s || valid.len() != s {
            bail!("sim prefill: tokens/valid must be length {s}");
        }
        let vmask: Vec<i32> =
            valid.iter().map(|&v| if v > 0.0 { 1 } else { 0 }).collect();
        let ctx = self.context_hash(tokens, &vmask)
            ^ Self::mix(params.first().map(|p| p.to_bits() as u64)
                .unwrap_or(0) ^ params.len() as u64);
        let (l, d) = (self.spec.n_layers, self.spec.d_kv);
        let mut out = PrefillOut {
            kcache: vec![0.0; l * s * d],
            vcache: vec![0.0; l * s * d],
            argmax: vec![0; s],
            conf: vec![0.0; s],
            entropy: vec![0.0; s],
        };
        for p in 0..s {
            let (a, c, e) = self.stats_at(ctx, p, tokens[p]);
            out.argmax[p] = a;
            out.conf[p] = c;
            out.entropy[p] = e;
            for layer in 0..l {
                for j in 0..d {
                    let v = self.kv_at(layer, p, j, tokens[p]);
                    out.kcache[(layer * s + p) * d + j] = v;
                    out.vcache[(layer * s + p) * d + j] = -v;
                }
            }
        }
        Ok(out)
    }

    /// Uniform fraction in [0, 1) from a mixed hash.
    #[inline]
    fn unit(h: u64) -> f64 {
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Closed-form training target for parameter `i`: the deterministic
    /// fixed point `train_step` pulls every parameter toward. Training
    /// "fits" when the residual to these targets vanishes.
    #[inline]
    fn param_target(&self, i: usize) -> f32 {
        (Self::unit(Self::mix(self.seed ^ 0x7261_494E ^ ((i as u64) << 17)))
            * 0.2) as f32
    }

    /// Window length the named executable was "lowered" with — mirrors
    /// the real engine, which looks the shape up per executable, so a
    /// policy that builds a wrong-length window fails in sim-based CI
    /// too, not just on PJRT.
    fn window_len_for(&self, exec: &str) -> usize {
        match exec {
            "ar_step" | "draft_ar_step" => 1,
            "ar_verify" => self.constants.verify_w,
            _ => self.constants.window, // decode_{xla,pallas}
        }
    }

    /// One windowed forward, validated against the executable's window
    /// length (`ar_step` is 1, `ar_verify` is `verify_w`, `decode_*` is
    /// `window`).
    fn decode_window_one(&self, exec: &str, params: &[f32],
                         win_tokens: &[i32], win_pos: &[i32],
                         win_valid: &[f32], cache: &dyn KvView)
                         -> Result<DecodeOut> {
        self.window_calls.set(self.window_calls.get() + 1);
        let w = win_tokens.len();
        let want = self.window_len_for(exec);
        if w != want || win_pos.len() != w || win_valid.len() != w {
            bail!("sim decode: `{exec}` window inputs must be length {want}");
        }
        // Paged-native cache read: the valid-row count is derived from
        // the view's page table (sum of per-page valid counters,
        // O(live-pages) per step — `KvView::for_each_page`), not from a
        // dense `[S_max]` mask. The sum equals `valid_count()` by
        // construction on both storage backends, so outputs stay
        // bit-identical to the dense baseline while the sim reads pages
        // in place exactly like the engine's staged path.
        let mut cache_rows = 0usize;
        cache.for_each_page(&mut |pg| cache_rows += pg.valid_rows);
        debug_assert_eq!(cache_rows, cache.valid_count(),
                         "page-table valid sum diverged from the counter");
        let ctx = self.context_hash(win_tokens, win_pos)
            ^ Self::mix(params.first().map(|p| p.to_bits() as u64)
                .unwrap_or(0) ^ params.len() as u64)
            ^ Self::mix(cache_rows as u64);
        let (l, d) = (self.spec.n_layers, self.spec.d_kv);
        let mut out = DecodeOut {
            argmax: vec![0; w],
            conf: vec![0.0; w],
            entropy: vec![0.0; w],
            k_win: vec![0.0; l * w * d],
            v_win: vec![0.0; l * w * d],
        };
        for i in 0..w {
            let pos = win_pos[i].max(0) as usize;
            let (a, c, e) = self.stats_at(ctx, pos, win_tokens[i]);
            out.argmax[i] = a;
            out.conf[i] = c;
            out.entropy[i] = e;
            for layer in 0..l {
                for j in 0..d {
                    let v = self.kv_at(layer, pos, j, win_tokens[i]);
                    out.k_win[(layer * w + i) * d + j] = v;
                    out.v_win[(layer * w + i) * d + j] = -v;
                }
            }
        }
        Ok(out)
    }
}

impl Backend for SimBackend {
    fn constants(&self) -> &Constants {
        &self.constants
    }

    fn model_spec(&self, _name: &str) -> Result<&ModelSpec> {
        // one sim geometry serves every model family (main/draft)
        Ok(&self.spec)
    }

    fn prefill(&self, _exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
        self.prefill_one(params, tokens, valid)
    }

    fn decode_window(&self, exec: &str, params: &[f32], win_tokens: &[i32],
                     win_pos: &[i32], win_valid: &[f32], cache: &dyn KvView)
                     -> Result<DecodeOut> {
        self.decode_window_one(exec, params, win_tokens, win_pos, win_valid,
                               cache)
    }

    /// Genuinely batched full forward: one pass over the stacked batch
    /// (the sim analog of a lowered B>1 prefill executable).
    fn prefill_batch(&self, params: &[f32], items: &[PrefillItem<'_>])
                     -> Result<Vec<PrefillOut>> {
        self.prefill_batch_calls.set(self.prefill_batch_calls.get() + 1);
        self.prefill_batch_items
            .set(self.prefill_batch_items.get() + items.len());
        self.max_prefill_batch
            .set(self.max_prefill_batch.get().max(items.len()));
        items
            .iter()
            .map(|it| self.prefill_one(params, it.tokens, it.valid))
            .collect()
    }

    /// Genuinely batched windowed forward: one pass over the stacked
    /// batch, each lane against its own session cache.
    fn decode_window_batch(&self, params: &[f32], items: &[WindowItem<'_>])
                           -> Result<Vec<DecodeOut>> {
        self.window_batch_calls.set(self.window_batch_calls.get() + 1);
        self.window_batch_items
            .set(self.window_batch_items.get() + items.len());
        self.max_window_batch
            .set(self.max_window_batch.get().max(items.len()));
        items
            .iter()
            .map(|it| {
                self.decode_window_one(it.exec, params, it.tokens, it.pos,
                                       it.valid, it.cache)
            })
            .collect()
    }

    /// Deterministic closed-form train step. Every parameter is pulled
    /// toward a seed-derived fixed point (`param_target`), and the loss is
    /// the residual to those targets scaled by a batch-content modulation,
    /// so:
    ///
    ///   * training is resumable and order-independent — the update is a
    ///     pure function of (params, lr), not of the step counter;
    ///   * loss decreases monotonically in expectation and deterministically
    ///     re-runs to the identical parameter vector;
    ///   * different batches (recipes, trajectories, curricula) produce
    ///     different loss curves through the batch fingerprint.
    fn train_step(&self, _exec: &str, params: &[f32], m: &[f32], v: &[f32],
                  _step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut> {
        let s = self.constants.s_train;
        let bs = tokens.len();
        if bs == 0 || bs % s != 0 || labels.len() != bs
            || loss_mask.len() != bs || attn_valid.len() != bs
        {
            bail!("sim train_step: batch buffers must be b*{s} aligned");
        }
        if m.len() != params.len() || v.len() != params.len() {
            bail!("sim train_step: optimiser state must match params");
        }
        self.train_steps.set(self.train_steps.get() + 1);

        // batch fingerprint -> mild deterministic loss modulation
        let mut bh: u64 = 0xcbf29ce484222325 ^ self.seed;
        for (&t, &l) in tokens.iter().zip(labels.iter()) {
            bh ^= (t as u64) ^ ((l as u64) << 32);
            bh = bh.wrapping_mul(0x100000001b3);
        }
        let modulation = 0.9 + 0.2 * Self::unit(Self::mix(bh));
        let masked = loss_mask.iter().filter(|&&x| x > 0.0).count();
        let mask_frac = masked as f64 / bs as f64;

        let rate = (lr as f64 * 100.0).clamp(0.01, 0.5) as f32;
        let n = params.len();
        let mut out = TrainOut {
            params: Vec::with_capacity(n),
            m: Vec::with_capacity(n),
            v: Vec::with_capacity(n),
            loss: 0.0,
        };
        let mut resid = 0.0f64;
        for i in 0..n {
            let g = params[i] - self.param_target(i);
            resid += (g as f64) * (g as f64);
            out.params.push(params[i] - rate * g);
            out.m.push(0.9 * m[i] + 0.1 * g);
            out.v.push(0.99 * v[i] + 0.01 * g * g);
        }
        let resid = if n > 0 { resid / n as f64 } else { 0.0 };
        out.loss = (modulation * (0.2 + mask_frac) * (resid * 400.0 + 0.08)
            + ent_weight as f64 * 0.02) as f32;
        Ok(out)
    }

    /// Deterministic whole-scan teacher extraction, mirroring the
    /// on-device `trajectory` executable step for step: each scan step
    /// takes the head statistics of the current sequence view, picks the
    /// highest-confidence masked position inside the earliest incomplete
    /// block of the generation region, unmasks it with its argmax token
    /// and records the step as that position's rank.
    fn trajectory(&self, params: &[f32], tokens: &[i32], attn_valid: &[f32],
                  gen_mask: &[f32]) -> Result<TrajectoryOut> {
        let c = &self.constants;
        let s = c.s_train;
        if tokens.is_empty() || tokens.len() % s != 0
            || attn_valid.len() != tokens.len()
            || gen_mask.len() != tokens.len()
        {
            bail!("sim trajectory: inputs must be b*{s} aligned");
        }
        let b = tokens.len() / s;
        self.trajectory_rows.set(self.trajectory_rows.get() + b);
        let phash = Self::mix(
            params.first().map(|p| p.to_bits() as u64).unwrap_or(0)
                ^ params.len() as u64,
        );
        let mut rank = vec![c.rank_never; b * s];
        let mut toks = tokens.to_vec();
        for bi in 0..b {
            let av = &attn_valid[bi * s..(bi + 1) * s];
            let gm = &gen_mask[bi * s..(bi + 1) * s];
            let vmask: Vec<i32> =
                av.iter().map(|&x| i32::from(x > 0.0)).collect();
            let Some(gen_start) = gm.iter().position(|&g| g > 0.0) else {
                continue; // padding row of a partial chunk: nothing to scan
            };
            for step in 0..c.gen_train as i32 {
                let row = &mut toks[bi * s..(bi + 1) * s];
                let ctx = self.context_hash(row, &vmask) ^ phash;
                // earliest incomplete block among masked gen positions,
                // then the highest-confidence masked position inside it
                let mut cur_block = usize::MAX;
                for i in gen_start..s {
                    if gm[i] > 0.0 && row[i] == c.mask_id {
                        cur_block = cur_block.min((i - gen_start) / c.block);
                    }
                }
                if cur_block == usize::MAX {
                    break; // every gen position unmasked
                }
                let mut best: Option<(usize, f32, i32)> = None;
                for i in gen_start..s {
                    if gm[i] <= 0.0 || row[i] != c.mask_id
                        || (i - gen_start) / c.block != cur_block
                    {
                        continue;
                    }
                    let (a, conf, _) = self.stats_at(ctx, i, row[i]);
                    if best.map(|(_, bc, _)| conf > bc).unwrap_or(true) {
                        best = Some((i, conf, a));
                    }
                }
                let (i, _, a) = best.expect("incomplete block has masks");
                row[i] = a;
                rank[bi * s + i] = step;
            }
        }
        Ok(TrajectoryOut { rank, final_tokens: toks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::KvCache;

    #[test]
    fn outputs_are_deterministic() {
        let sim = SimBackend::new(7);
        let s = sim.constants().s_max;
        let tokens: Vec<i32> = (0..s as i32).map(|i| 5 + i % 90).collect();
        let valid = vec![1.0f32; s];
        let a = sim.prefill("prefill_xla", &[0.5], &tokens, &valid).unwrap();
        let b = sim.prefill("prefill_xla", &[0.5], &tokens, &valid).unwrap();
        assert_eq!(a.argmax, b.argmax);
        assert_eq!(a.conf, b.conf);
        assert_eq!(a.kcache, b.kcache);
    }

    #[test]
    fn outputs_reroll_when_tokens_change() {
        let sim = SimBackend::new(7);
        let s = sim.constants().s_max;
        let mut tokens: Vec<i32> = (0..s as i32).map(|i| 5 + i % 90).collect();
        let valid = vec![1.0f32; s];
        let a = sim.prefill("p", &[0.5], &tokens, &valid).unwrap();
        tokens[10] = 77;
        let b = sim.prefill("p", &[0.5], &tokens, &valid).unwrap();
        assert_ne!(a.entropy, b.entropy, "context change must re-roll stats");
    }

    #[test]
    fn stats_are_well_formed() {
        let sim = SimBackend::new(3);
        let c = sim.constants().clone();
        let tokens: Vec<i32> = vec![1; c.s_max];
        let valid = vec![1.0f32; c.s_max];
        let out = sim.prefill("p", &[], &tokens, &valid).unwrap();
        let max_ent = (c.vocab as f32).ln();
        let mut selected = 0;
        for p in 0..c.s_max {
            assert!(out.conf[p] > 0.0 && out.conf[p] <= 1.0);
            assert!(out.entropy[p] >= 0.0 && out.entropy[p] <= max_ent);
            assert!(out.argmax[p] >= 5 && out.argmax[p] < c.vocab as i32);
            if out.entropy[p] <= 0.45 {
                selected += 1;
            }
        }
        // the entropy rule must select a healthy fraction (parallelism)
        assert!(selected > c.s_max / 10, "only {selected} selectable");
    }

    #[test]
    fn eos_rate_produces_eos() {
        let sim = SimBackend::new(3).with_eos_rate(0.2);
        let c = sim.constants().clone();
        let tokens: Vec<i32> = vec![1; c.s_max];
        let valid = vec![1.0f32; c.s_max];
        let out = sim.prefill("p", &[], &tokens, &valid).unwrap();
        assert!(out.argmax.contains(&c.eos_id));
    }

    #[test]
    fn batched_outputs_are_bit_identical_to_single_calls() {
        let sim = SimBackend::new(9);
        let c = sim.constants().clone();
        let spec = sim.model_spec("main").unwrap().clone();
        let w = c.window;
        let cache_a = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
        let mut cache_b = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
        cache_b.mark_valid(0); // different cache state per lane
        let ta: Vec<i32> = (0..w as i32).map(|i| 5 + i % 80).collect();
        let tb: Vec<i32> = (0..w as i32).map(|i| 7 + i % 60).collect();
        let pos: Vec<i32> = (0..w as i32).collect();
        let valid = vec![1.0f32; w];
        let params = [0.5f32];

        let single_a = sim
            .decode_window("d", &params, &ta, &pos, &valid, &cache_a)
            .unwrap();
        let single_b = sim
            .decode_window("d", &params, &tb, &pos, &valid, &cache_b)
            .unwrap();
        let items = [
            WindowItem { exec: "d", tokens: &ta, pos: &pos, valid: &valid,
                         cache: &cache_a },
            WindowItem { exec: "d", tokens: &tb, pos: &pos, valid: &valid,
                         cache: &cache_b },
        ];
        let batched = sim.decode_window_batch(&params, &items).unwrap();
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0].argmax, single_a.argmax);
        assert_eq!(batched[0].k_win, single_a.k_win);
        assert_eq!(batched[1].argmax, single_b.argmax);
        assert_eq!(batched[1].conf, single_b.conf);
        assert_eq!(sim.window_batch_calls(), 1);
        assert_eq!(sim.window_batch_items(), 2);
        assert_eq!(sim.max_window_batch(), 2);
    }

    #[test]
    fn train_step_is_deterministic_and_reduces_loss() {
        let sim = SimBackend::new(6);
        let c = sim.constants().clone();
        let spec = sim.model_spec("main").unwrap().clone();
        assert!(spec.total_params > 0, "sim must have trainable params");
        let n = spec.total_params;
        let bs = c.b_train * c.s_train;
        let tokens = vec![5i32; bs];
        let labels = vec![6i32; bs];
        let mut mask = vec![0.0f32; bs];
        for x in mask.iter_mut().take(bs / 3) {
            *x = 1.0;
        }
        let valid = vec![1.0f32; bs];

        let mut p = vec![0.3f32; n];
        let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        let first = sim
            .train_step("train_diff", &p, &m, &v, 1, &tokens, &labels,
                        &mask, &valid, 3e-3, 0.0)
            .unwrap();
        let again = sim
            .train_step("train_diff", &p, &m, &v, 1, &tokens, &labels,
                        &mask, &valid, 3e-3, 0.0)
            .unwrap();
        assert_eq!(first.params, again.params, "update must be deterministic");
        assert_eq!(first.loss, again.loss);

        let mut last = first.loss;
        p = first.params;
        m = first.m;
        v = first.v;
        for step in 2..=20 {
            let out = sim
                .train_step("train_diff", &p, &m, &v, step, &tokens,
                            &labels, &mask, &valid, 3e-3, 0.0)
                .unwrap();
            p = out.params;
            m = out.m;
            v = out.v;
            last = out.loss;
        }
        assert!(last < first.loss,
                "loss must fall on a fixed batch: {} -> {last}", first.loss);
        assert_eq!(sim.train_steps(), 21);
    }

    #[test]
    fn trajectory_ranks_are_a_gen_region_permutation() {
        let sim = SimBackend::new(12);
        let c = sim.constants().clone();
        let s = c.s_train;
        let p = 11usize;
        let mut tokens = vec![1i32; s]; // MASK everywhere
        for (i, t) in tokens.iter_mut().enumerate().take(p) {
            *t = 5 + i as i32;
        }
        let mut attn_valid = vec![0.0f32; s];
        let mut gen_mask = vec![0.0f32; s];
        for i in 0..p + c.gen_train {
            attn_valid[i] = 1.0;
        }
        for i in p..p + c.gen_train {
            gen_mask[i] = 1.0;
        }
        let a = sim
            .trajectory(&[0.4], &tokens, &attn_valid, &gen_mask)
            .unwrap();
        let b = sim
            .trajectory(&[0.4], &tokens, &attn_valid, &gen_mask)
            .unwrap();
        assert_eq!(a.rank, b.rank, "scan must be deterministic");
        // gen ranks are a permutation of 0..gen_train; elsewhere NEVER
        let mut gen_ranks: Vec<i32> = a.rank[p..p + c.gen_train].to_vec();
        gen_ranks.sort();
        assert_eq!(gen_ranks, (0..c.gen_train as i32).collect::<Vec<_>>());
        for i in 0..p {
            assert_eq!(a.rank[i], c.rank_never);
        }
        // final tokens: every gen position unmasked
        for i in p..p + c.gen_train {
            assert_ne!(a.final_tokens[i], c.mask_id);
        }
        // a different teacher re-rolls the decoding order
        let other = sim
            .trajectory(&[0.9], &tokens, &attn_valid, &gen_mask)
            .unwrap();
        assert_ne!(a.rank, other.rank, "teacher params must steer the scan");
        assert_eq!(sim.trajectory_rows(), 3);
    }

    #[test]
    fn window_length_follows_the_executable() {
        // ar_step (w=1) and ar_verify (w=verify_w) shapes must both work
        let sim = SimBackend::new(4);
        let cache = KvCache::new(2, sim.constants().s_max, 4);
        let one = sim
            .decode_window("ar_step", &[0.1], &[5], &[0], &[1.0], &cache)
            .unwrap();
        assert_eq!(one.argmax.len(), 1);
        let w = sim.constants().verify_w;
        let toks = vec![5i32; w];
        let pos: Vec<i32> = (0..w as i32).collect();
        let v = vec![1.0f32; w];
        let ver = sim
            .decode_window("ar_verify", &[0.1], &toks, &pos, &v, &cache)
            .unwrap();
        assert_eq!(ver.argmax.len(), w);
        assert!(sim
            .decode_window("d", &[0.1], &[], &[], &[], &cache)
            .is_err());
    }
}
