//! Typed configuration system: every tunable of the serving coordinator,
//! decode strategies and training runs as a JSON-loadable config with
//! defaults, validation and round-trip serialization. The CLI flags are
//! thin overrides on top of these.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::decode::{AdaptiveCfg, AdaptiveMode, DecodeCfg, SelMetric,
                    Strategy, DEFAULT_ENTROPY_THRESHOLD};
use crate::util::json::{self, Json};

/// Upper bound on the engine worker's interleaving width.
pub const MAX_SESSIONS_LIMIT: usize = 256;

/// Upper bound on the serving fleet's replica count (each replica owns a
/// full engine + KV pool, so this is a sanity rail, not a tuning target).
pub const MAX_WORKERS_LIMIT: usize = 64;

/// Shared bounds for the serving knobs; enforced identically for CLI
/// flags and config files.
pub fn validate_service_limits(max_queue: usize,
                               max_concurrent_sessions: usize)
                               -> Result<()> {
    if max_queue == 0 {
        bail!("max_queue must be positive");
    }
    if max_concurrent_sessions == 0
        || max_concurrent_sessions > MAX_SESSIONS_LIMIT
    {
        bail!("max_concurrent_sessions must be in 1..={MAX_SESSIONS_LIMIT}");
    }
    Ok(())
}

/// Bounds for the fleet knob, shared by CLI flags and config files.
pub fn validate_workers(workers: usize) -> Result<()> {
    if workers == 0 || workers > MAX_WORKERS_LIMIT {
        bail!("workers must be in 1..={MAX_WORKERS_LIMIT}");
    }
    Ok(())
}

/// Top-level service configuration (repro serve --config file.json).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub host: String,
    pub port: u16,
    pub ckpt: String,
    pub draft_ckpt: Option<String>,
    pub max_queue: usize,
    /// Interleaving width of the engine worker (live sessions; 1 = the
    /// classic batch=1 serving loop).
    pub max_concurrent_sessions: usize,
    /// Shared paged KV pool budget in MiB (0 = dense per-session caches).
    pub kv_budget_mb: usize,
    /// Sessions stepped per round under EDF deadline pressure
    /// (0 = unlimited: every runnable session steps every round).
    pub slo_round_width: usize,
    /// Engine-worker replicas behind the fleet router (data parallel,
    /// each with its own engine + KV pool; 1 = single-worker topology).
    pub workers: usize,
    /// Preemption spill threshold: a session paused this many consecutive
    /// rounds releases its paged KV to the reclaimable set and re-prefills
    /// on resume (0 = disabled).
    pub spill_after_rounds: usize,
    /// Adaptive parallelism controller (`decode::adaptive`): mode `off`
    /// (default) preserves the static decode path; `load` couples
    /// thresholds/widths to backlog, bounded by the hard accuracy floor.
    pub adaptive: AdaptiveCfg,
    pub decode: DecodeCfg,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            host: "127.0.0.1".into(),
            port: 7070,
            ckpt: "d3llm-llada".into(),
            draft_ckpt: None,
            max_queue: 256,
            max_concurrent_sessions: 4,
            kv_budget_mb: 256,
            slo_round_width: 0,
            workers: 1,
            spill_after_rounds: 0,
            adaptive: AdaptiveCfg::default(),
            decode: DecodeCfg::preset(Strategy::D3llm),
        }
    }
}

/// Bounds for the adaptive-controller knobs, shared by CLI flags and
/// config files. The floor bounds match `validate_decode`'s threshold
/// ranges — the controller interpolates between a valid static threshold
/// and this bound, so a valid floor keeps every emitted threshold valid.
pub fn validate_adaptive(cfg: &AdaptiveCfg) -> Result<()> {
    if !(0.0..=2.0).contains(&cfg.conf_floor) {
        bail!("adaptive conf_floor {} out of [0, 2]", cfg.conf_floor);
    }
    if !(0.0..=10.0).contains(&cfg.entropy_ceiling) {
        bail!("adaptive entropy_ceiling {} out of [0, 10]",
              cfg.entropy_ceiling);
    }
    if cfg.max_block_width == 0 || cfg.max_block_width > 16 {
        bail!("adaptive max_block_width must be in 1..=16");
    }
    if !(cfg.alpha > 0.0 && cfg.alpha <= 1.0) {
        bail!("adaptive alpha must be in (0, 1]");
    }
    Ok(())
}

fn get_str(j: &Json, key: &str, default: &str) -> String {
    j.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
}

fn get_f64(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
}

fn get_usize(j: &Json, key: &str, default: usize) -> usize {
    j.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
}

fn get_bool(j: &Json, key: &str, default: bool) -> bool {
    j.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
}

/// Parse a decode config object (all fields optional over the preset).
pub fn decode_from_json(j: &Json) -> Result<DecodeCfg> {
    let strategy_name = get_str(j, "strategy", "d3llm");
    let strategy = Strategy::parse(&strategy_name)
        .ok_or_else(|| anyhow!("unknown strategy `{strategy_name}`"))?;
    let mut cfg = DecodeCfg::preset(strategy);

    if let Some(m) = j.get("metric").and_then(|v| v.as_str()) {
        let t = get_f64(j, "threshold", 0.0) as f32;
        cfg.metric = match m {
            "conf" => SelMetric::Conf(if t > 0.0 { t } else { 0.85 }),
            "entropy" => SelMetric::Entropy(if t > 0.0 {
                t
            } else {
                DEFAULT_ENTROPY_THRESHOLD
            }),
            other => bail!("unknown metric `{other}`"),
        };
    } else if let Some(t) = j.get("threshold").and_then(|v| v.as_f64()) {
        cfg = cfg.with_threshold(t as f32);
    }
    cfg.block_add = get_f64(j, "block_add", cfg.block_add);
    cfg.fully_at = get_f64(j, "fully_at", cfg.fully_at);
    cfg.stabilize_rounds =
        get_usize(j, "stabilize_rounds", cfg.stabilize_rounds);
    cfg.refresh_every = get_usize(j, "refresh_every", cfg.refresh_every);
    cfg.early_stop = get_bool(j, "early_stop", cfg.early_stop);
    cfg.use_cache = get_bool(j, "use_cache", cfg.use_cache);
    cfg.gamma = get_usize(j, "gamma", cfg.gamma);
    cfg.variant = get_str(j, "variant", &cfg.variant);
    validate_decode(&cfg)?;
    Ok(cfg)
}

pub fn validate_decode(cfg: &DecodeCfg) -> Result<()> {
    match cfg.metric {
        SelMetric::Conf(t) => {
            if !(0.0..=2.0).contains(&t) {
                bail!("confidence threshold {t} out of [0, 2]");
            }
        }
        SelMetric::Entropy(t) => {
            if !(0.0..=10.0).contains(&t) {
                bail!("entropy threshold {t} out of [0, 10]");
            }
        }
    }
    if !(0.0..=1.0).contains(&cfg.block_add) {
        bail!("block_add must be in [0,1]");
    }
    if !(0.0..=1.0).contains(&cfg.fully_at) {
        bail!("fully_at must be in [0,1]");
    }
    if cfg.block_add > cfg.fully_at {
        bail!("block_add must not exceed fully_at");
    }
    if cfg.stabilize_rounds > 8 {
        bail!("stabilize_rounds > 8 is pathological");
    }
    if cfg.gamma == 0 || cfg.gamma > 15 {
        bail!("gamma must be in 1..=15 (verify window is 16)");
    }
    if cfg.variant != "xla" && cfg.variant != "pallas" {
        bail!("variant must be `xla` or `pallas`");
    }
    Ok(())
}

pub fn decode_to_json(cfg: &DecodeCfg) -> Json {
    let (metric, threshold) = match cfg.metric {
        SelMetric::Conf(t) => ("conf", t),
        SelMetric::Entropy(t) => ("entropy", t),
    };
    Json::obj(vec![
        ("strategy", Json::str(cfg.strategy.name())),
        ("metric", Json::str(metric)),
        ("threshold", Json::num(threshold as f64)),
        ("block_add", Json::num(cfg.block_add)),
        ("fully_at", Json::num(cfg.fully_at)),
        ("stabilize_rounds", Json::num(cfg.stabilize_rounds as f64)),
        ("refresh_every", Json::num(cfg.refresh_every as f64)),
        ("early_stop", Json::Bool(cfg.early_stop)),
        ("use_cache", Json::Bool(cfg.use_cache)),
        ("gamma", Json::num(cfg.gamma as f64)),
        ("variant", Json::str(cfg.variant.clone())),
    ])
}

impl ServiceConfig {
    pub fn from_json(j: &Json) -> Result<ServiceConfig> {
        let d = ServiceConfig::default();
        let decode = match j.get("decode") {
            Some(dj) => decode_from_json(dj)?,
            None => d.decode.clone(),
        };
        let cfg = ServiceConfig {
            host: get_str(j, "host", &d.host),
            port: get_usize(j, "port", d.port as usize) as u16,
            ckpt: get_str(j, "ckpt", &d.ckpt),
            draft_ckpt: j
                .get("draft_ckpt")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            max_queue: get_usize(j, "max_queue", d.max_queue),
            max_concurrent_sessions: get_usize(
                j,
                "max_concurrent_sessions",
                d.max_concurrent_sessions,
            ),
            kv_budget_mb: get_usize(j, "kv_budget_mb", d.kv_budget_mb),
            slo_round_width: get_usize(j, "slo_round_width",
                                       d.slo_round_width),
            workers: get_usize(j, "workers", d.workers),
            spill_after_rounds: get_usize(j, "spill_after_rounds",
                                          d.spill_after_rounds),
            adaptive: {
                let mode_name =
                    get_str(j, "adaptive", d.adaptive.mode.name());
                let mode = AdaptiveMode::parse(&mode_name).ok_or_else(
                    || anyhow!("unknown adaptive mode `{mode_name}`"))?;
                AdaptiveCfg {
                    mode,
                    conf_floor: get_f64(j, "adaptive_conf_floor",
                                        d.adaptive.conf_floor as f64)
                        as f32,
                    entropy_ceiling:
                        get_f64(j, "adaptive_entropy_ceiling",
                                d.adaptive.entropy_ceiling as f64)
                            as f32,
                    max_block_width:
                        get_usize(j, "adaptive_max_block_width",
                                  d.adaptive.max_block_width),
                    ..d.adaptive.clone()
                }
            },
            decode,
        };
        validate_service_limits(cfg.max_queue,
                                cfg.max_concurrent_sessions)?;
        validate_workers(cfg.workers)?;
        validate_adaptive(&cfg.adaptive)?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ServiceConfig> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let j = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("host", Json::str(self.host.clone())),
            ("port", Json::num(self.port as f64)),
            ("ckpt", Json::str(self.ckpt.clone())),
            ("draft_ckpt", match &self.draft_ckpt {
                Some(s) => Json::str(s.clone()),
                None => Json::Null,
            }),
            ("max_queue", Json::num(self.max_queue as f64)),
            ("max_concurrent_sessions",
             Json::num(self.max_concurrent_sessions as f64)),
            ("kv_budget_mb", Json::num(self.kv_budget_mb as f64)),
            ("slo_round_width", Json::num(self.slo_round_width as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("spill_after_rounds",
             Json::num(self.spill_after_rounds as f64)),
            ("adaptive", Json::str(self.adaptive.mode.name())),
            ("adaptive_conf_floor",
             Json::num(self.adaptive.conf_floor as f64)),
            ("adaptive_entropy_ceiling",
             Json::num(self.adaptive.entropy_ceiling as f64)),
            ("adaptive_max_block_width",
             Json::num(self.adaptive.max_block_width as f64)),
            ("decode", decode_to_json(&self.decode)),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips() {
        let c = ServiceConfig::default();
        let j = c.to_json();
        let c2 = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(c2.host, c.host);
        assert_eq!(c2.port, c.port);
        assert_eq!(c2.max_queue, c.max_queue);
        assert_eq!(c2.max_concurrent_sessions, c.max_concurrent_sessions);
        assert_eq!(c2.kv_budget_mb, c.kv_budget_mb);
        assert_eq!(c2.slo_round_width, c.slo_round_width);
        assert_eq!(c2.workers, c.workers);
        assert_eq!(c2.spill_after_rounds, c.spill_after_rounds);
        assert_eq!(c2.decode.strategy, c.decode.strategy);
        assert_eq!(c2.decode.refresh_every, c.decode.refresh_every);
    }

    #[test]
    fn rejects_bad_worker_count() {
        for bad in [r#"{"workers":0}"#, r#"{"workers":1000}"#] {
            let j = json::parse(bad).unwrap();
            assert!(ServiceConfig::from_json(&j).is_err(), "{bad}");
        }
        let j = json::parse(r#"{"workers":4,"spill_after_rounds":6}"#)
            .unwrap();
        let c = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.spill_after_rounds, 6);
    }

    #[test]
    fn decode_overrides_apply() {
        let j = json::parse(
            r#"{"strategy":"d3llm","threshold":0.3,"refresh_every":4,
                "stabilize_rounds":2,"early_stop":false}"#,
        )
        .unwrap();
        let cfg = decode_from_json(&j).unwrap();
        match cfg.metric {
            SelMetric::Entropy(t) => assert!((t - 0.3).abs() < 1e-6),
            _ => panic!("d3llm preset keeps the entropy metric"),
        }
        assert_eq!(cfg.refresh_every, 4);
        assert_eq!(cfg.stabilize_rounds, 2);
        assert!(!cfg.early_stop);
    }

    #[test]
    fn validation_rejects_nonsense() {
        for bad in [
            r#"{"strategy":"nope"}"#,
            r#"{"strategy":"d3llm","block_add":1.5}"#,
            r#"{"strategy":"d3llm","block_add":0.99,"fully_at":0.5}"#,
            r#"{"strategy":"spec","gamma":99}"#,
            r#"{"strategy":"d3llm","variant":"cuda"}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert!(decode_from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn metric_kind_override() {
        let j = json::parse(r#"{"strategy":"fast-dllm","metric":"entropy",
                                "threshold":0.5}"#).unwrap();
        let cfg = decode_from_json(&j).unwrap();
        assert!(matches!(cfg.metric, SelMetric::Entropy(_)));
    }

    #[test]
    fn rejects_bad_session_width() {
        for bad in [
            r#"{"max_concurrent_sessions":0}"#,
            r#"{"max_concurrent_sessions":1000}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert!(ServiceConfig::from_json(&j).is_err(), "{bad}");
        }
        let j = json::parse(r#"{"max_concurrent_sessions":8}"#).unwrap();
        assert_eq!(
            ServiceConfig::from_json(&j).unwrap().max_concurrent_sessions,
            8
        );
    }

    #[test]
    fn adaptive_roundtrips_and_validates() {
        // default: off, floors at the sweep-grid bounds
        let c = ServiceConfig::default();
        assert_eq!(c.adaptive.mode, AdaptiveMode::Off);
        let c2 = ServiceConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.adaptive.mode, c.adaptive.mode);
        assert_eq!(c2.adaptive.conf_floor, c.adaptive.conf_floor);
        assert_eq!(c2.adaptive.entropy_ceiling, c.adaptive.entropy_ceiling);

        // load mode with explicit floors round-trips
        let j = json::parse(
            r#"{"adaptive":"load","adaptive_conf_floor":0.6,
                "adaptive_entropy_ceiling":1.1,
                "adaptive_max_block_width":2}"#,
        )
        .unwrap();
        let c = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(c.adaptive.mode, AdaptiveMode::Load);
        assert!((c.adaptive.conf_floor - 0.6).abs() < 1e-6);
        assert!((c.adaptive.entropy_ceiling - 1.1).abs() < 1e-6);
        assert_eq!(c.adaptive.max_block_width, 2);
        let c2 = ServiceConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.adaptive.mode, AdaptiveMode::Load);
        assert_eq!(c2.adaptive.max_block_width, 2);

        // bad mode / out-of-range floors rejected
        for bad in [
            r#"{"adaptive":"warp"}"#,
            r#"{"adaptive":"load","adaptive_conf_floor":-0.1}"#,
            r#"{"adaptive":"load","adaptive_entropy_ceiling":99.0}"#,
            r#"{"adaptive":"load","adaptive_max_block_width":0}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert!(ServiceConfig::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn entropy_metric_fallback_uses_the_shared_default() {
        let j = json::parse(r#"{"strategy":"d3llm","metric":"entropy"}"#)
            .unwrap();
        let cfg = decode_from_json(&j).unwrap();
        match cfg.metric {
            SelMetric::Entropy(t) => {
                assert_eq!(t, DEFAULT_ENTROPY_THRESHOLD)
            }
            _ => panic!("entropy metric requested"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("d3llm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("svc.json");
        let mut c = ServiceConfig::default();
        c.port = 9999;
        c.save(&path).unwrap();
        let c2 = ServiceConfig::load(&path).unwrap();
        assert_eq!(c2.port, 9999);
    }
}
