//! Minimal, offline, API-compatible subset of `anyhow`.
//!
//! The build environment has no crates.io registry, so this vendored crate
//! provides exactly the surface the repo uses: `Error`, `Result`,
//! `anyhow!`, `bail!`, and the `Context` extension trait for `Result` and
//! `Option`. Semantics match upstream where it matters:
//!
//!   * `{}` prints the outermost message, `{:#}` prints the whole chain
//!     joined by ": " (the repo's `{e:#}` error reporting relies on this);
//!   * `Error` deliberately does NOT implement `std::error::Error`, which
//!     is what makes the blanket `From<E: std::error::Error>` impl (and
//!     therefore `?` on io/parse errors) coherent;
//!   * context wraps the previous error as the new outermost message.

// Vendored API mirror: style lints are judged against the upstream crate's
// surface, not this stand-in (CI runs `clippy --workspace -D warnings`).
#![allow(clippy::all)]

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Boxed error chain: an outermost message plus optional causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(src) = cur.source.as_deref() {
            cur = src;
        }
        cur
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // flatten the std source chain into ours
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let v = 7;
        let e = anyhow!("value {v} bad {}", 9);
        assert_eq!(format!("{e}"), "value 7 bad 9");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 1");
    }

    #[test]
    fn root_cause_is_innermost() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(format!("{}", e.root_cause()), "inner");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "mid", "inner"]);
    }
}
