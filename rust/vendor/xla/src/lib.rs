//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! The build environment has no crates.io registry and no libxla, so this
//! crate mirrors the exact API surface `runtime::engine` uses — literals,
//! host buffers, HLO-text module loading, client/executable lifecycle —
//! with faithful host-side semantics (shapes, dtypes, tuple decomposition)
//! but **no graph execution**: `execute`/`execute_b` return a descriptive
//! error. Everything engine-dependent in the repo already skips politely
//! when `artifacts/` is missing, and the deterministic `SimBackend`
//! (`d3llm::decode::sim`) covers scheduler and state-machine behavior
//! without a real accelerator. To run real artifacts, point the `xla`
//! dependency in the workspace manifest at the actual `xla-rs` crate — the
//! call sites compile against either.

// Vendored API mirror: style lints are judged against the upstream crate's
// surface, not this stand-in (CI runs `clippy --workspace -D warnings`).
#![allow(clippy::all)]

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, XlaError>;

#[derive(Debug, Clone)]
pub struct XlaError {
    pub msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(XlaError { msg: msg.into() })
}

// -------------------------------------------------------------- literals

/// Element storage for a non-tuple literal.
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Supported element types (the repo's graphs are f32/i32 only).
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> LiteralData;
    #[doc(hidden)]
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// Host-side literal: flat data plus dimensions (row-major).
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(t: T) -> Literal {
        Literal { data: T::wrap(vec![t]), dims: vec![] }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { data: LiteralData::Tuple(parts), dims: vec![n] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, LiteralData::Tuple(_)) {
            return err("reshape: cannot reshape a tuple literal");
        }
        if n as usize != self.element_count() {
            return err(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            ));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(parts) => Ok(parts),
            _ => err("to_tuple: literal is not a tuple"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| XlaError {
            msg: format!("to_vec: literal is not {}", T::type_name()),
        })
    }
}

// --------------------------------------------------------------- buffers

/// Device buffer; in this offline stand-in it is a host literal.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

// ------------------------------------------------------------ HLO loading

/// Parsed-enough HLO module: the module name and the source text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    name: String,
    text_len: usize,
}

impl HloModuleProto {
    /// Load HLO text from a file. Validates existence and extracts the
    /// module name (`HloModule <name>`), matching xla-rs behavior closely
    /// enough for manifest-driven loading.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return err(format!("reading {path:?}: {e}")),
        };
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule"))
            .map(|rest| {
                rest.trim().split([' ', ',']).next().unwrap_or("").to_string()
            })
            .unwrap_or_else(|| {
                path.file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "module".to_string())
            });
        Ok(HloModuleProto { name, text_len: text.len() })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        let _ = proto.text_len;
        XlaComputation { name: proto.name.clone() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

// ------------------------------------------------------------ client/exec

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-offline-stub".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { name: comp.name.clone() })
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if data.len() != n {
            return err(format!(
                "buffer_from_host_buffer: {} elements vs shape {:?}",
                data.len(),
                dims
            ));
        }
        let lit = Literal::vec1(data);
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let literal =
            if dims.len() <= 1 { lit } else { lit.reshape(&dims)? };
        Ok(PjRtBuffer { literal })
    }
}

pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn execute<T: Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(format!(
            "offline xla stub cannot execute `{}`: link the real xla-rs \
             crate (see rust/vendor/xla) to run compiled artifacts",
            self.name
        ))
    }

    pub fn execute_b<T: Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(format!(
            "offline xla stub cannot execute `{}` (buffered): link the real \
             xla-rs crate (see rust/vendor/xla) to run compiled artifacts",
            self.name
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![
            Literal::scalar(1i32),
            Literal::vec1(&[0.5f32]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }

    #[test]
    fn buffer_validates_shape() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 6], &[2, 3], None).is_ok());
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 3], None).is_err());
    }

    #[test]
    fn execution_is_a_descriptive_error() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { name: "prefill_xla".into() };
        let exe = c.compile(&comp).unwrap();
        let e = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(e.msg.contains("prefill_xla"));
    }
}
