//! CLI: `cargo run -p d3lint [-- FLAGS]`
//!
//!   (no flags)            list findings, exit 1 if any
//!   --check-baseline      ratchet against lint-baseline.toml, exit 1 on
//!                         drift in either direction
//!   --write-baseline      regenerate lint-baseline.toml from the tree
//!   --abi-spec FILE.json  use entry points from `aot.py --dump-specs`
//!                         output instead of scraping aot.py source
//!   --root DIR            repo root (default: relative to this crate)
//!
//! Exit codes: 0 clean, 1 findings/drift, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut abi_spec: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut check_baseline = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--write-baseline" => write_baseline = true,
            "--check-baseline" => check_baseline = true,
            "--root" => match it.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => return usage("--root needs a directory"),
            },
            "--abi-spec" => match it.next() {
                Some(f) => abi_spec = Some(PathBuf::from(f)),
                None => return usage("--abi-spec needs a file"),
            },
            other => return usage(&format!("unknown flag '{other}'")),
        }
    }

    // default root: rust/tools/d3lint/ -> repo root, so the binary works
    // both via `cargo run -p d3lint` (cwd = workspace root) and from a
    // checkout subdirectory via CARGO_MANIFEST_DIR.
    let root = root.unwrap_or_else(|| {
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        manifest
            .ancestors()
            .nth(3)
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."))
    });

    let (spec_names, spec_fv) = match &abi_spec {
        Some(p) => match std::fs::read_to_string(p) {
            Ok(text) => {
                let (names, fv) = d3lint::abi::read_spec_json(&text);
                if names.is_empty() {
                    eprintln!(
                        "d3lint: no entry points in {}",
                        p.display()
                    );
                    return ExitCode::from(2);
                }
                (Some(names), fv)
            }
            Err(e) => {
                eprintln!("d3lint: cannot read {}: {e}", p.display());
                return ExitCode::from(2);
            }
        },
        None => (None, None),
    };

    let findings = d3lint::run(&root, spec_names.as_deref(), spec_fv);
    let baseline_path = root.join("lint-baseline.toml");

    if write_baseline {
        let counts = d3lint::baseline::counts_of(&findings);
        if let Err(e) =
            d3lint::baseline::write_baseline(&baseline_path, &counts)
        {
            eprintln!(
                "d3lint: cannot write {}: {e}",
                baseline_path.display()
            );
            return ExitCode::from(2);
        }
        println!(
            "wrote {} ({} findings)",
            baseline_path.display(),
            findings.len()
        );
        return ExitCode::SUCCESS;
    }

    if check_baseline {
        let base = match d3lint::baseline::read_baseline(&baseline_path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!(
                    "d3lint: cannot read {}: {e}",
                    baseline_path.display()
                );
                return ExitCode::from(2);
            }
        };
        let cur = d3lint::baseline::counts_of(&findings);
        let drifts = d3lint::baseline::check(&base, &cur);
        for d in &drifts {
            println!("{}", d.render());
        }
        println!(
            "{} findings, {} baseline keys, {} drift(s)",
            findings.len(),
            base.len(),
            drifts.len()
        );
        return if drifts.is_empty() {
            ExitCode::SUCCESS
        } else {
            ExitCode::FAILURE
        };
    }

    for f in &findings {
        println!("{}", f.render());
    }
    println!("{} findings", findings.len());
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!(
        "d3lint: {msg}\nusage: d3lint [--check-baseline | \
         --write-baseline] [--abi-spec FILE.json] [--root DIR]"
    );
    ExitCode::from(2)
}
