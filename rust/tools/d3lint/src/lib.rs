//! d3lint: repo-invariant static analysis for the d3llm tree.
//!
//! Four rules, all at the source-token level (no rustc plugin, zero
//! dependencies):
//!
//! - `determinism`    — no `HashMap`/`HashSet`/`Instant::now()`/
//!   `SystemTime` in the replay-deterministic paths (decode/, the
//!   scheduler, the batcher, the KV pool) except via
//!   `// lint: allow(determinism)`.
//! - `panic-path`     — no `.unwrap()`/`.expect(`/`panic!`/
//!   `unreachable!`/direct indexing in serving paths (coordinator/,
//!   decode/session.rs): a panic there kills a replica mid-request.
//! - `atomic-ordering` — any non-Relaxed `Ordering::` use in
//!   coordinator/ needs an `// ordering:` justification comment.
//! - `abi-drift`      — AOT entry points built by python/compile/aot.py
//!   (names, arity, format_version) must match their consumption in
//!   runtime/manifest.rs and model/exec.rs.
//!
//! Findings print as `file:line rule message`. The committed
//! `lint-baseline.toml` accepts pre-existing violations and ratchets in
//! CI: counts only go down. `mirror.py` in this directory is a
//! byte-for-byte Python port for containers without cargo.

pub mod abi;
pub mod baseline;
pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use rules::Finding;

/// All `.rs` files under the linted roots, as sorted repo-relative
/// forward-slash paths.
pub fn walk(root: &Path) -> Vec<String> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/benches", "rust/tests"] {
        collect_rs(&root.join(sub), root, &mut files);
    }
    files.sort();
    files
}

fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<String>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut paths: Vec<PathBuf> =
        entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(&p, root, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            if let Ok(rel) = p.strip_prefix(root) {
                out.push(
                    rel.components()
                        .map(|c| c.as_os_str().to_string_lossy())
                        .collect::<Vec<_>>()
                        .join("/"),
                );
            }
        }
    }
}

/// Full lint run: rule scan over the tree plus the ABI cross-check,
/// sorted by (file, line, rule, message).
pub fn run(
    root: &Path,
    spec_names: Option<&[String]>,
    spec_fv: Option<u64>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for rel in walk(root) {
        if let Ok(text) = std::fs::read_to_string(root.join(&rel)) {
            findings.extend(rules::scan_rust_file(&rel, &text));
        }
    }
    findings.extend(abi::abi_check(root, spec_names, spec_fv));
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message)
            .cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    findings
}
