//! Rust source line model: comment/string stripping and `#[cfg(test)]`
//! region tracking, at the source-token level (no rustc plugin).
//!
//! Every heuristic here is mirrored byte-for-byte by
//! `rust/tools/d3lint/mirror.py` (used to regenerate the baseline in
//! containers without a Rust toolchain) — change both together; the
//! baseline test in tests/lint_rules.rs is the drift alarm.

/// One source line after stripping.
pub struct Line {
    /// Source text with comment text removed and string/char literal
    /// *contents* removed (delimiters kept), so token rules never match
    /// inside a string or a comment.
    pub code: String,
    /// Concatenated text of all comments on the line (`//` and `/* */`),
    /// where `lint: allow(...)` / `ordering:` markers live.
    pub comment: String,
    /// Contents of string literals that *start* on this line (the ABI
    /// check reads exec-name literals from these).
    pub strings: Vec<String>,
    /// Line is inside a `#[cfg(test)]`-gated item (rules skip it).
    pub in_test: bool,
}

fn close_string(lines: &mut [Line], current: &mut Line, start: usize,
                buf: String) {
    if start == lines.len() {
        current.strings.push(buf);
    } else {
        lines[start].strings.push(buf);
    }
}

/// Split `text` into stripped [`Line`]s. State (block comments, raw and
/// normal strings, brace depth, cfg(test) regions) carries across lines.
pub fn strip_rust(text: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = Vec::new();
    let mut block_depth = 0usize; // /* */ nesting
    let mut raw_hashes: Option<usize> = None; // inside r#".."#
    let mut in_str = false; // inside a normal "..." string
    let mut str_start = 0usize; // line index the open string started on
    let mut str_buf = String::new();
    let mut depth = 0i64; // brace depth over code
    let mut test_depth: Option<i64> = None; // depth a cfg(test) opened at
    let mut pending_test = false; // saw #[cfg(test)], awaiting its '{'

    for raw_line in text.split('\n') {
        let raw: Vec<char> = raw_line.chars().collect();
        let mut ln = Line {
            code: String::new(),
            comment: String::new(),
            strings: Vec::new(),
            in_test: false,
        };
        let was_in_test = test_depth.is_some();
        let n = raw.len();
        let mut i = 0usize;
        while i < n {
            let c = raw[i];
            if in_str {
                if c == '\\' && i + 1 < n {
                    str_buf.push(raw[i]);
                    str_buf.push(raw[i + 1]);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    in_str = false;
                    ln.code.push('"');
                    let buf = std::mem::take(&mut str_buf);
                    close_string(&mut lines, &mut ln, str_start, buf);
                } else {
                    str_buf.push(c);
                }
                i += 1;
                continue;
            }
            if let Some(h) = raw_hashes {
                let terminated = c == '"'
                    && raw[i + 1..].iter().take(h).filter(|&&x| x == '#')
                        .count() == h
                    && i + 1 + h <= n;
                if terminated {
                    ln.code.push('"');
                    for _ in 0..h {
                        ln.code.push('#');
                    }
                    let buf = std::mem::take(&mut str_buf);
                    close_string(&mut lines, &mut ln, str_start, buf);
                    i += 1 + h;
                    raw_hashes = None;
                } else {
                    str_buf.push(c);
                    i += 1;
                }
                continue;
            }
            if block_depth > 0 {
                if c == '*' && i + 1 < n && raw[i + 1] == '/' {
                    block_depth -= 1;
                    i += 2;
                } else if c == '/' && i + 1 < n && raw[i + 1] == '*' {
                    block_depth += 1;
                    i += 2;
                } else {
                    ln.comment.push(c);
                    i += 1;
                }
                continue;
            }
            // ---- code context
            if c == '/' && i + 1 < n && raw[i + 1] == '/' {
                ln.comment.extend(&raw[i + 2..]);
                break;
            }
            if c == '/' && i + 1 < n && raw[i + 1] == '*' {
                block_depth += 1;
                i += 2;
                continue;
            }
            if c == 'r' {
                let mut j = i + 1;
                while j < n && raw[j] == '#' {
                    j += 1;
                }
                if j < n && raw[j] == '"' {
                    let h = j - i - 1;
                    raw_hashes = Some(h);
                    ln.code.push('r');
                    for _ in 0..h {
                        ln.code.push('#');
                    }
                    ln.code.push('"');
                    str_start = lines.len();
                    str_buf.clear();
                    i = j + 1;
                    continue;
                }
            }
            if c == '"' {
                in_str = true;
                ln.code.push('"');
                str_start = lines.len();
                str_buf.clear();
                i += 1;
                continue;
            }
            if c == '\'' {
                // char literal vs lifetime: '\x..' or 'x' is a literal
                if i + 1 < n && raw[i + 1] == '\\' {
                    let close = raw[i + 2..].iter().position(|&x| x == '\'');
                    ln.code.push_str("''");
                    i = match close {
                        Some(k) => i + 2 + k + 1,
                        None => n,
                    };
                    continue;
                }
                if i + 2 < n && raw[i + 2] == '\'' {
                    ln.code.push_str("''");
                    i += 3;
                    continue;
                }
                ln.code.push(c); // lifetime
                i += 1;
                continue;
            }
            ln.code.push(c);
            i += 1;
        }
        // cfg(test) tracking: the region starts at its opening brace
        if test_depth.is_none() && ln.code.contains("cfg(test)") {
            pending_test = true;
        }
        for ch in ln.code.chars() {
            if ch == '{' {
                if pending_test && test_depth.is_none() {
                    test_depth = Some(depth);
                    pending_test = false;
                }
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                if test_depth == Some(depth) {
                    test_depth = None;
                }
            }
        }
        ln.in_test = was_in_test || test_depth.is_some();
        lines.push(ln);
    }
    lines
}

pub fn count_occurrences(hay: &str, needle: &str) -> usize {
    let mut c = 0usize;
    let mut start = 0usize;
    while let Some(k) = hay[start..].find(needle) {
        c += 1;
        start += k + needle.len();
    }
    c
}

pub fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `[` counts as direct indexing when glued to an identifier tail, `)` or
/// `]` — `x[i]`, `f()[0]`, `m[a][b]` — but not attributes (`#[..]`),
/// macros (`vec![..]`), slice types (`&[f32]`) or array literals.
pub fn is_index_bracket(code: &[char], i: usize) -> bool {
    i > 0 && (is_ident_char(code[i - 1]) || code[i - 1] == ')'
              || code[i - 1] == ']')
}

pub fn allowed(rule: &str, comment: &str, prev_comment: &str) -> bool {
    let marker = format!("lint: allow({rule})");
    comment.contains(&marker) || prev_comment.contains(&marker)
}
