//! The repo-invariant rules, applied per stripped line. Scopes and token
//! lists are the contract — keep them identical to mirror.py.

use crate::scan::{allowed, count_occurrences, is_index_bracket, strip_rust};

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{} {} {}", self.file, self.line, self.rule,
                self.message)
    }
}

/// Determinism scope: every bit-identity / virtual-clock pin lives here.
pub const DET_SCOPES: &[&str] = &[
    "rust/src/decode/",
    "rust/src/coordinator/scheduler.rs",
    "rust/src/coordinator/batcher.rs",
    "rust/src/model/kv_pool.rs",
];
/// Panic scope: request-handling code where a panic kills a replica.
pub const PANIC_SCOPES: &[&str] =
    &["rust/src/coordinator/", "rust/src/decode/session.rs"];
/// Ordering scope: the cross-thread handshake atomics (router alive
/// flags, drain, replica gauges) live under coordinator/.
pub const ORDERING_SCOPES: &[&str] = &["rust/src/coordinator/"];

pub const DET_TOKENS: &[&str] =
    &["HashMap", "HashSet", "Instant::now()", "SystemTime"];
pub const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!("];
/// `Ordering::Relaxed` is the documented default for advisory counters
/// and gauges; any *stronger* ordering marks a handshake and must carry
/// an `// ordering:` justification (same line or the comment block
/// directly above).
pub const ORDERING_TOKENS: &[&str] = &[
    "Ordering::SeqCst",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
];

pub fn in_scope(rel: &str, scopes: &[&str]) -> bool {
    scopes.iter().any(|s| rel == *s || rel.starts_with(s))
}

/// Run the determinism / panic-path / atomic-ordering rules over one
/// Rust file. `rel` is the repo-relative path (forward slashes).
pub fn scan_rust_file(rel: &str, text: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let lines = strip_rust(text);
    // `prev_comment` carries the whole comment block directly above the
    // line: consecutive code-less lines accumulate, any code line resets
    let mut prev_comment = String::new();
    fn carry(prev: &mut String, ln: &crate::scan::Line) {
        if ln.code.trim().is_empty() {
            prev.push_str(&ln.comment);
        } else {
            *prev = ln.comment.clone();
        }
    }
    for (idx, ln) in lines.iter().enumerate() {
        let lineno = idx + 1;
        if ln.in_test {
            carry(&mut prev_comment, ln);
            continue;
        }
        if in_scope(rel, DET_SCOPES)
            && !allowed("determinism", &ln.comment, &prev_comment)
        {
            for tok in DET_TOKENS {
                for _ in 0..count_occurrences(&ln.code, tok) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "determinism",
                        message: format!(
                            "'{tok}' in a determinism-scoped path \
                             (virtual clock / ordered maps only)"
                        ),
                    });
                }
            }
        }
        if in_scope(rel, PANIC_SCOPES)
            && !allowed("panic-path", &ln.comment, &prev_comment)
        {
            for tok in PANIC_TOKENS {
                for _ in 0..count_occurrences(&ln.code, tok) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "panic-path",
                        message: format!(
                            "'{tok}' in a serving path (degrade to an \
                             error reply instead)"
                        ),
                    });
                }
            }
            let code: Vec<char> = ln.code.chars().collect();
            for (i, &ch) in code.iter().enumerate() {
                if ch == '[' && is_index_bracket(&code, i) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "panic-path",
                        message: "direct indexing in a serving path \
                                  (use .get())"
                            .to_string(),
                    });
                }
            }
        }
        if in_scope(rel, ORDERING_SCOPES) {
            let justified = ln.comment.contains("ordering:")
                || prev_comment.contains("ordering:");
            if !justified {
                for tok in ORDERING_TOKENS {
                    for _ in 0..count_occurrences(&ln.code, tok) {
                        findings.push(Finding {
                            file: rel.to_string(),
                            line: lineno,
                            rule: "atomic-ordering",
                            message: format!(
                                "'{tok}' without an '// ordering:' \
                                 justification comment"
                            ),
                        });
                    }
                }
            }
        }
        carry(&mut prev_comment, ln);
    }
    findings
}
