//! The CI ratchet: per-(file, rule) finding counts against a committed
//! `lint-baseline.toml`. New violations fail; so does a stale baseline
//! (current < baseline), which forces fixes to shrink it in the same PR.

use std::collections::BTreeMap;
use std::path::Path;

use crate::abi::int_after;
use crate::rules::Finding;

pub type Counts = BTreeMap<(String, String), usize>;

pub fn counts_of(findings: &[Finding]) -> Counts {
    let mut counts = Counts::new();
    for f in findings {
        *counts
            .entry((f.file.clone(), f.rule.to_string()))
            .or_insert(0) += 1;
    }
    counts
}

pub fn write_baseline(path: &Path, counts: &Counts) -> std::io::Result<()> {
    let mut out = String::from(
        "# d3lint baseline: accepted pre-existing violations, counted\n\
         # per (file, rule). CI ratchets against this file — new\n\
         # violations fail, and fixing violations requires shrinking\n\
         # the matching count here (a stale baseline also fails).\n\
         # Regenerate: cargo run -p d3lint -- --write-baseline\n\
         \n[counts]\n",
    );
    for ((file, rule), n) in counts {
        out.push_str(&format!("\"{file}:{rule}\" = {n}\n"));
    }
    std::fs::write(path, out)
}

pub fn read_baseline(path: &Path) -> std::io::Result<Counts> {
    let text = std::fs::read_to_string(path)?;
    let mut counts = Counts::new();
    for raw in text.split('\n') {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line == "[counts]" {
            continue;
        }
        if !line.starts_with('"') {
            continue;
        }
        let b = match line[1..].find('"') {
            Some(k) => 1 + k,
            None => continue,
        };
        let key = &line[1..b];
        let val = match int_after(line, "\" =") {
            Some(v) => v as usize,
            None => continue,
        };
        let (file, rule) = match key.rfind(':') {
            Some(k) => (&key[..k], &key[k + 1..]),
            None => continue,
        };
        counts.insert((file.to_string(), rule.to_string()), val);
    }
    Ok(counts)
}

/// One drift line for the report; `new_violation` distinguishes "count
/// went up" from "stale baseline" (count went down).
pub struct Drift {
    pub file: String,
    pub rule: String,
    pub baseline: usize,
    pub current: usize,
    pub new_violation: bool,
}

impl Drift {
    pub fn render(&self) -> String {
        if self.new_violation {
            format!(
                "{}: {} new '{}' violation(s) (baseline {}, current {})",
                self.file,
                self.current - self.baseline,
                self.rule,
                self.baseline,
                self.current
            )
        } else {
            format!(
                "{}: stale baseline for '{}' (baseline {}, current {}) \
                 — shrink it",
                self.file, self.rule, self.baseline, self.current
            )
        }
    }
}

pub fn check(baseline: &Counts, current: &Counts) -> Vec<Drift> {
    let mut keys: Vec<&(String, String)> =
        baseline.keys().chain(current.keys()).collect();
    keys.sort();
    keys.dedup();
    let mut drifts = Vec::new();
    for key in keys {
        let b = *baseline.get(key).unwrap_or(&0);
        let c = *current.get(key).unwrap_or(&0);
        if b != c {
            drifts.push(Drift {
                file: key.0.clone(),
                rule: key.1.clone(),
                baseline: b,
                current: c,
                new_violation: c > b,
            });
        }
    }
    drifts
}
