//! Cross-layer ABI check: entry-point names/arities built by
//! python/compile/aot.py vs their consumption in runtime/manifest.rs and
//! model/exec.rs. Pure source-token scraping — no Python interpreter
//! needed. Mirrored by mirror.py; keep in lockstep.

use std::collections::BTreeSet;
use std::path::Path;

use crate::rules::Finding;
use crate::scan::{count_occurrences, strip_rust};

/// Rust files whose exec-name string literals are checked against the
/// Python-built set. Deliberately narrow: elsewhere names like
/// "decode_ms" are metric labels, not exec references.
pub const ABI_RUST_FILES: &[&str] =
    &["rust/src/model/exec.rs", "rust/src/runtime/manifest.rs"];
pub const EXEC_NAME_PREFIXES: &[&str] =
    &["prefill", "decode", "train", "trajectory", "ar_", "draft_"];

fn is_name_char(c: char) -> bool {
    c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
}

/// Classify a string literal as an exec-name reference:
/// `Some(("exact", name))`, `Some(("prefix", p))`, or `None`.
pub fn exec_name_ref(s: &str) -> Option<(&'static str, String)> {
    if s.is_empty() || !s.chars().all(|c| is_name_char(c) || c == '{' || c == '}') {
        return None;
    }
    if !EXEC_NAME_PREFIXES.iter().any(|p| s.starts_with(p)) {
        return None;
    }
    if let Some(b) = s.find('{') {
        let p = &s[..b];
        return if p.is_empty() {
            None
        } else {
            Some(("prefix", p.to_string()))
        };
    }
    if s.ends_with('_') {
        return Some(("prefix", s.to_string()));
    }
    if s.contains('_') || s == "trajectory" {
        return Some(("exact", s.to_string()));
    }
    None
}

/// Collect the text of a call from its '(' to the matching ')'.
fn balanced_call(lines: &[&str], start_idx: usize, open_pos: usize) -> String {
    let mut depth = 0i64;
    let mut out = String::new();
    let mut idx = start_idx;
    let mut pos = open_pos;
    while idx < lines.len() {
        let line: Vec<char> = lines[idx].chars().collect();
        while pos < line.len() {
            let ch = line[pos];
            out.push(ch);
            if ch == '(' || ch == '[' {
                depth += 1;
            } else if ch == ')' || ch == ']' {
                depth -= 1;
                if depth == 0 {
                    return out;
                }
            }
            pos += 1;
        }
        out.push(' ');
        idx += 1;
        pos = 0;
    }
    out
}

/// Sequentially paired "..." contents with the index just past the
/// closing quote (values never contain quotes in the files this parses).
fn quoted_strings(line: &str) -> Vec<(String, usize)> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    loop {
        let a = match chars[i..].iter().position(|&c| c == '"') {
            Some(k) => i + k,
            None => return out,
        };
        let b = match chars[a + 1..].iter().position(|&c| c == '"') {
            Some(k) => a + 1 + k,
            None => return out,
        };
        out.push((chars[a + 1..b].iter().collect(), b + 1));
        i = b + 1;
    }
}

fn lowercase_names(line: &str) -> Vec<String> {
    quoted_strings(line)
        .into_iter()
        .filter(|(s, _)| s.chars().all(is_name_char))
        .map(|(s, _)| s)
        .collect()
}

/// Quoted strings immediately followed by ':' (dict keys).
fn quoted_keys(line: &str) -> Vec<String> {
    let chars: Vec<char> = line.chars().collect();
    quoted_strings(line)
        .into_iter()
        .filter(|(s, end)| {
            *end < chars.len()
                && chars[*end] == ':'
                && !s.is_empty()
                && s.chars().all(is_name_char)
        })
        .map(|(s, _)| s)
        .collect()
}

fn is_ident_byte(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `var = ...` at a token boundary.
fn has_assignment(line: &str, var: &str) -> bool {
    let chars: Vec<char> = line.chars().collect();
    let vlen = var.chars().count();
    let mut i = 0usize;
    loop {
        let k = match find_from(&chars, var, i) {
            Some(k) => k,
            None => return false,
        };
        let before_ok = k == 0 || !is_ident_byte(chars[k - 1]);
        let mut j = k + vlen;
        while j < chars.len() && chars[j] == ' ' {
            j += 1;
        }
        if before_ok
            && j < chars.len()
            && chars[j] == '='
            && (j + 1 >= chars.len() || chars[j + 1] != '=')
        {
            return true;
        }
        i = k + vlen;
    }
}

fn find_from(chars: &[char], needle: &str, start: usize) -> Option<usize> {
    let nd: Vec<char> = needle.chars().collect();
    if nd.is_empty() || start > chars.len() {
        return None;
    }
    (start..chars.len().saturating_sub(nd.len() - 1))
        .find(|&k| chars[k..k + nd.len()] == nd[..])
}

pub fn int_after(line: &str, marker: &str) -> Option<u64> {
    let chars: Vec<char> = line.chars().collect();
    let k = find_from(&chars, marker, 0)?;
    let mut j = k + marker.chars().count();
    while j < chars.len() && chars[j] == ' ' {
        j += 1;
    }
    let mut d = String::new();
    while j < chars.len() && chars[j].is_ascii_digit() {
        d.push(chars[j]);
        j += 1;
    }
    d.parse().ok()
}

#[derive(Default)]
pub struct PySpecs {
    /// name -> (line, arity_ok)
    pub names: Vec<(String, usize, bool)>,
    pub exec_meta: Vec<(String, usize)>,
    pub constants: Vec<String>,
    pub format_version: Option<u64>,
    pub fv_line: usize,
    pub errors: Vec<Finding>,
}

pub fn parse_aot(rel: &str, text: &str) -> PySpecs {
    let mut out = PySpecs::default();
    let lines: Vec<&str> = text.split('\n').collect();
    let mut variants: Vec<String> = Vec::new();
    let mut prefixes: Vec<String> = Vec::new();
    let mut wnames: Vec<String> = Vec::new();
    let mut tnames: Vec<String> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if line.contains("for variant in") {
            let got = lowercase_names(line);
            if !got.is_empty() {
                variants = got;
            }
        }
        if has_assignment(line, "prefix") {
            // model-name prefixes are "" or end in '_' ("draft_"); drop
            // the condition's other literals ("main")
            let got: Vec<String> = lowercase_names(line)
                .into_iter()
                .filter(|s| s.is_empty() || s.ends_with('_'))
                .collect();
            if !got.is_empty() {
                prefixes = got;
            }
        }
        if line.contains("for wname") {
            let got = lowercase_names(line);
            if !got.is_empty() {
                wnames = got;
            }
        }
        if line.contains("for tname") {
            let mut block = line.to_string();
            let mut j = idx;
            while !block.trim_end().ends_with(':') && j + 1 < lines.len() {
                j += 1;
                block.push_str(lines[j]);
            }
            tnames = lowercase_names(&block)
                .into_iter()
                .filter(|s| {
                    exec_name_ref(s) == Some(("exact", s.clone()))
                })
                .collect();
        }
        if let Some(v) = int_after(line, "FORMAT_VERSION =") {
            out.format_version = Some(v);
            out.fv_line = idx + 1;
        }
        if out.format_version.is_none() {
            if let Some(v) = int_after(line, "\"format_version\":") {
                out.format_version = Some(v);
                out.fv_line = idx + 1;
            }
        }
    }

    fn subst<'a>(
        var: &str,
        variants: &'a [String],
        prefixes: &'a [String],
        wnames: &'a [String],
    ) -> &'a [String] {
        match var {
            "variant" => variants,
            "prefix" => prefixes,
            "wname" => wnames,
            _ => &[],
        }
    }

    for (idx, line) in lines.iter().enumerate() {
        let stripped = line.trim_start();
        if !stripped.starts_with("add(") {
            continue;
        }
        let lineno = idx + 1;
        let chars: Vec<char> = line.chars().collect();
        let open_pos = find_from(&chars, "add(", 0).unwrap() + 3;
        let call = balanced_call(&lines, idx, open_pos);
        let call_chars: Vec<char> = call.chars().collect();
        let inner: String = call_chars
            .get(1..call_chars.len().saturating_sub(1))
            .unwrap_or(&[])
            .iter()
            .collect();
        let first = inner.split(',').next().unwrap_or("").trim().to_string();
        let f_template = first
            .strip_prefix("f\"")
            .and_then(|s| s.strip_suffix('"'))
            .map(str::to_string);
        let plain = first
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .map(str::to_string);
        let names: Vec<String> = if let Some(template) = &f_template {
            // expand f-string placeholders against the loop variables
            let template = template.as_str();
            let mut names = vec![String::new()];
            let mut pos = 0usize;
            let mut failed = false;
            while pos < template.len() {
                match template[pos..].find('{') {
                    None => {
                        for n in names.iter_mut() {
                            n.push_str(&template[pos..]);
                        }
                        break;
                    }
                    Some(boff) => {
                        let b = pos + boff;
                        let e = match template[b..].find('}') {
                            Some(eoff) => b + eoff,
                            None => template.len(),
                        };
                        let var = &template[b + 1..e];
                        let vals = subst(var, &variants, &prefixes, &wnames);
                        if vals.is_empty() {
                            out.errors.push(Finding {
                                file: rel.to_string(),
                                line: lineno,
                                rule: "abi-drift",
                                message: format!(
                                    "cannot resolve placeholder '{{{var}}}' \
                                     in an AOT entry-point name"
                                ),
                            });
                            failed = true;
                            break;
                        }
                        let mut next = Vec::new();
                        for n in &names {
                            for v in vals {
                                next.push(format!("{n}{}{v}", &template[pos..b]));
                            }
                        }
                        names = next;
                        pos = e + 1;
                    }
                }
            }
            if failed { Vec::new() } else { names }
        } else if let Some(lit) = plain {
            vec![lit]
        } else if first == "tname" {
            if tnames.is_empty() {
                out.errors.push(Finding {
                    file: rel.to_string(),
                    line: lineno,
                    rule: "abi-drift",
                    message: "cannot resolve 'tname' entry-point names"
                        .to_string(),
                });
            }
            tnames.clone()
        } else {
            out.errors.push(Finding {
                file: rel.to_string(),
                line: lineno,
                rule: "abi-drift",
                message: format!(
                    "cannot resolve entry-point name expression '{first}'"
                ),
            });
            Vec::new()
        };
        // arity: count of _spec() lowering args vs declared input _sig()s
        let mut groups: Vec<String> = Vec::new();
        let mut depth = 0i64;
        let mut gstart: Option<usize> = None;
        let inner_chars: Vec<char> = inner.chars().collect();
        for (p, &ch) in inner_chars.iter().enumerate() {
            if ch == '[' && depth == 0 {
                gstart = Some(p);
            }
            if ch == '(' || ch == '[' {
                depth += 1;
            } else if ch == ')' || ch == ']' {
                depth -= 1;
                if ch == ']' && depth == 0 {
                    if let Some(g) = gstart {
                        groups.push(inner_chars[g..=p].iter().collect());
                    }
                }
            }
        }
        let mut arity_ok = true;
        if groups.len() >= 2 {
            let n_spec = count_occurrences(&groups[0], "_spec(");
            let n_sig = count_occurrences(&groups[1], "_sig(");
            arity_ok = n_spec == n_sig;
            if !arity_ok {
                out.errors.push(Finding {
                    file: rel.to_string(),
                    line: lineno,
                    rule: "abi-drift",
                    message: format!(
                        "entry point declares {n_spec} lowering args but \
                         {n_sig} input signatures"
                    ),
                });
            }
        }
        for nm in names {
            if !out.names.iter().any(|(n, _, _)| *n == nm) {
                out.names.push((nm, lineno, arity_ok));
            }
        }
    }

    let mut in_meta = false;
    let mut in_const = false;
    for (idx, line) in lines.iter().enumerate() {
        if line.trim_start().starts_with("EXEC_META") && line.contains('{') {
            in_meta = true;
            continue;
        }
        if in_meta {
            if line.trim() == "}" {
                in_meta = false;
                continue;
            }
            let keys = quoted_keys(line);
            if !keys.is_empty() && line.trim_start().starts_with('"') {
                out.exec_meta.push((keys[0].clone(), idx + 1));
            }
        }
        if line.contains("\"constants\": {") {
            in_const = true;
            continue;
        }
        if in_const {
            if line.trim().starts_with('}') {
                in_const = false;
                continue;
            }
            out.constants.extend(quoted_keys(line));
        }
    }
    out
}

/// What the manifest loader consumes: the accepted format_version range
/// and the constants keys read on the `c` object.
pub struct ManifestReads {
    pub vrange: Option<(u64, u64)>,
    pub vline: usize,
    pub keys: Vec<(String, usize)>,
}

/// Parse manifest.rs consumption, skipping cfg(test) code.
pub fn parse_manifest_rs(text: &str) -> ManifestReads {
    let lines = strip_rust(text);
    let mut out = ManifestReads {
        vrange: None,
        vline: 0,
        keys: Vec::new(),
    };
    for (idx, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        if let Some(k) = ln.code.find(").contains(&version)") {
            if let Some(a) = ln.code[..k].rfind('(') {
                let lo_hi: Vec<&str> = ln.code[a + 1..k].split("..=").collect();
                if lo_hi.len() == 2 {
                    if let (Ok(lo), Ok(hi)) =
                        (lo_hi[0].parse::<u64>(), lo_hi[1].parse::<u64>())
                    {
                        out.vrange = Some((lo, hi));
                        out.vline = idx + 1;
                    }
                }
            }
        }
        // string contents are stripped out of code; pair get_usize/get_i32
        // calls on `c` with the string literals that start on the line
        let ncalls = count_occurrences(&ln.code, "get_usize(c, \"")
            + count_occurrences(&ln.code, "get_i32(c, \"");
        for s in ln.strings.iter().take(ncalls) {
            out.keys.push((s.clone(), idx + 1));
        }
    }
    out
}

/// One exec-name-shaped string literal found in non-test Rust code.
pub struct NameRef {
    /// "exact" or "prefix" per [`exec_name_ref`]
    pub kind: &'static str,
    pub val: String,
    pub line: usize,
}

/// Exec-name-shaped string literals in non-test code.
pub fn rust_name_refs(text: &str) -> Vec<NameRef> {
    let mut refs = Vec::new();
    for (idx, ln) in strip_rust(text).iter().enumerate() {
        if ln.in_test {
            continue;
        }
        for s in &ln.strings {
            if let Some((kind, val)) = exec_name_ref(s) {
                refs.push(NameRef {
                    kind,
                    val,
                    line: idx + 1,
                });
            }
        }
    }
    refs
}

/// Run the full ABI drift check rooted at `root`. When `spec_names` /
/// `spec_fv` are given (from `aot.py --dump-specs` via --abi-spec), they
/// replace the source-scraped name set and format version.
pub fn abi_check(
    root: &Path,
    spec_names: Option<&[String]>,
    spec_fv: Option<u64>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let aot_rel = "python/compile/aot.py";
    let aot_path = root.join(aot_rel);
    let aot_text = match std::fs::read_to_string(&aot_path) {
        Ok(t) => t,
        Err(_) => return findings,
    };
    let specs = parse_aot(aot_rel, &aot_text);
    findings.extend(specs.errors.iter().cloned());
    let built: BTreeSet<String> = match spec_names {
        Some(ns) => ns.iter().cloned().collect(),
        None => specs.names.iter().map(|(n, _, _)| n.clone()).collect(),
    };
    let fv = spec_fv.or(specs.format_version);

    for (key, lineno) in &specs.exec_meta {
        if !built.contains(key) {
            findings.push(Finding {
                file: aot_rel.to_string(),
                line: *lineno,
                rule: "abi-drift",
                message: format!(
                    "EXEC_META key '{key}' does not match any built entry \
                     point"
                ),
            });
        }
    }

    let man_rel = "rust/src/runtime/manifest.rs";
    let man_path = root.join(man_rel);
    if let Ok(man_text) = std::fs::read_to_string(&man_path) {
        let reads = parse_manifest_rs(&man_text);
        if let (Some((lo, hi)), Some(v)) = (reads.vrange, fv) {
            if !(lo..=hi).contains(&v) {
                findings.push(Finding {
                    file: man_rel.to_string(),
                    line: reads.vline,
                    rule: "abi-drift",
                    message: format!(
                        "manifest.rs accepts format_version {lo}..={hi} \
                         but python/compile emits {v}"
                    ),
                });
            }
        }
        let cset: BTreeSet<&String> = specs.constants.iter().collect();
        for (key, lineno) in &reads.keys {
            if !cset.is_empty() && !cset.contains(key) {
                findings.push(Finding {
                    file: man_rel.to_string(),
                    line: *lineno,
                    rule: "abi-drift",
                    message: format!(
                        "manifest.rs reads constant '{key}' that \
                         python/compile does not emit"
                    ),
                });
            }
        }
    }

    for rf in ABI_RUST_FILES {
        let path = root.join(rf);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => continue,
        };
        for r in rust_name_refs(&text) {
            if r.kind == "exact" && !built.contains(&r.val) {
                findings.push(Finding {
                    file: rf.to_string(),
                    line: r.line,
                    rule: "abi-drift",
                    message: format!(
                        "exec name '{}' is not built by \
                         python/compile/aot.py",
                        r.val
                    ),
                });
            } else if r.kind == "prefix"
                && !built.iter().any(|n| n.starts_with(&r.val))
            {
                findings.push(Finding {
                    file: rf.to_string(),
                    line: r.line,
                    rule: "abi-drift",
                    message: format!(
                        "no built entry point matches exec-name prefix \
                         '{}'",
                        r.val
                    ),
                });
            }
        }
    }
    findings
}

///// Minimal reader for the JSON emitted by `aot.py --dump-specs`:
/// `{"format_version": N, "entry_points": [{"name": "...", ...}, ...]}`.
/// Not a general JSON parser — the emitter writes one entry per line.
pub fn read_spec_json(text: &str) -> (Vec<String>, Option<u64>) {
    let mut names = Vec::new();
    let mut fv = None;
    for line in text.split('\n') {
        if fv.is_none() {
            fv = int_after(line, "\"format_version\":");
        }
        let chars: Vec<char> = line.chars().collect();
        let mut i = 0usize;
        while let Some(k) = find_from(&chars, "\"name\":", i) {
            let rest: String = chars[k + 7..].iter().collect();
            for (s, _) in quoted_strings(&rest).into_iter().take(1) {
                names.push(s);
            }
            i = k + 7;
        }
    }
    (names, fv)
}
