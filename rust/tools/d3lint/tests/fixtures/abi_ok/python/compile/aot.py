"""Mini AOT builder fixture (shape of python/compile/aot.py)."""

FORMAT_VERSION = 2

EXEC_META = {
    "prefill_pallas": {"kind": "prefill"},
    "decode_step": {"kind": "decode"},
}


def build_specs():
    specs = []

    def add(name, fn, args, insig):
        specs.append((name, fn, args, insig))

    for variant in ("pallas", "xla"):
        add(f"prefill_{variant}", prefill,
            [tok_spec(), len_spec()],
            [tok_sig(), len_sig()])
    add("decode_step", decode,
        [tok_spec()],
        [tok_sig()])
    for tname in ("trajectory", "trajectory_paged"):
        add(tname, traj,
            [tok_spec()],
            [tok_sig()])
    return specs


def manifest():
    return {
        "format_version": FORMAT_VERSION,
        "constants": {
            "vocab": 32,
            "block": 4,
        },
    }
