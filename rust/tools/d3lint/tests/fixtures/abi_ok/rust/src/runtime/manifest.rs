pub fn load(j: &Json) -> Result<Manifest, String> {
    let version = get_usize(&j, "format_version")?;
    if !(1..=2).contains(&version) {
        return Err("unsupported manifest version".to_string());
    }
    let c = json_obj(&j, "constants")?;
    let vocab = get_usize(c, "vocab")?;
    let block = get_usize(c, "block")?;
    Ok(Manifest { vocab, block })
}
