pub fn prefill_name(variant: &str) -> String {
    format!("prefill_{variant}")
}

pub const DECODE_EXEC: &str = "decode_step";
pub const TRAJ_EXEC: &str = "trajectory";
pub const TRAJ_PAGED_EXEC: &str = "trajectory_paged";
