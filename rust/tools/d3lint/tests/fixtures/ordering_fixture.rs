use std::sync::atomic::{AtomicBool, Ordering};

pub fn flip(b: &AtomicBool) {
    b.store(true, Ordering::SeqCst);
    let _ = b.load(Ordering::Relaxed);
    // ordering: SeqCst pairs with the drain handshake under the senders lock
    b.swap(false, Ordering::SeqCst);
    let _ = b.load(Ordering::Acquire); // ordering: pairs with the Release store
    let _ = b.load(Ordering::Relaxed);
    let _ = b.load(Ordering::Acquire);
    // ordering: a justification block may span several comment lines —
    // the whole contiguous block above the operation counts, not just
    // the line immediately adjacent to it.
    b.store(true, Ordering::Release);
}
