use std::collections::HashMap;
use std::time::SystemTime;

pub fn stamp() -> u64 {
    let _t = Instant::now();
    let _m: HashMap<u8, u8> = HashMap::new();
    0
}

pub fn pinned() {
    let _m: HashMap<u8, u8> = HashMap::new(); // lint: allow(determinism) pinned order
}

// lint: allow(determinism) wall-clock is display-only here
pub fn display_time() -> SystemTime {
    let s = "HashMap inside a string literal is fine";
    let _ = s;
    SystemTime::now()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_gated_map_is_fine() {
        let _m: HashMap<u8, u8> = HashMap::new();
    }
}
