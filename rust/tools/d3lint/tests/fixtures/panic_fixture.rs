pub fn reply(v: Option<u32>, xs: &[u32]) -> u32 {
    let a = v.unwrap();
    let b = v.expect("value");
    let c = xs[0];
    if a + b + c == 0 {
        panic!("zero");
    }
    unreachable!()
}

pub fn tolerated(v: Option<u32>) -> u32 {
    // lint: allow(panic-path) invariant: v is Some by construction
    v.unwrap()
}

pub fn not_indexing(slice: &[f32]) -> Vec<f32> {
    let v = vec![1.0f32];
    let _attr: &[f32] = slice;
    v
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_unwrap_is_fine_here() {
        super::reply(Some(0), &[0]).to_string().pop().unwrap();
    }
}
