//! d3lint's own tests: every rule has a positive and an
//! allowlisted-negative fixture, the ABI check has ok / renamed-python /
//! renamed-rust fixture trees, and `repo_baseline_matches_tree` asserts
//! the committed lint-baseline.toml matches the real tree exactly (a
//! stale baseline fails CI here even before the ratchet job runs).

use std::path::{Path, PathBuf};

use d3lint::abi;
use d3lint::baseline;
use d3lint::rules::scan_rust_file;

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn fixture_text(name: &str) -> String {
    std::fs::read_to_string(fixtures().join(name)).unwrap()
}

fn renders(findings: &[d3lint::rules::Finding]) -> Vec<String> {
    findings.iter().map(|f| f.render()).collect()
}

// ----------------------------------------------------------- rule scans

#[test]
fn determinism_rule_fixture() {
    let text = fixture_text("det_fixture.rs");
    let got = renders(&scan_rust_file("rust/src/model/kv_pool.rs", &text));
    let want = vec![
        "rust/src/model/kv_pool.rs:1 determinism 'HashMap' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
        "rust/src/model/kv_pool.rs:2 determinism 'SystemTime' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
        "rust/src/model/kv_pool.rs:5 determinism 'Instant::now()' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
        "rust/src/model/kv_pool.rs:6 determinism 'HashMap' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
        "rust/src/model/kv_pool.rs:6 determinism 'HashMap' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
        // the allow marker is line-scoped: line 14's comment covers line
        // 15, not the SystemTime::now() three lines later
        "rust/src/model/kv_pool.rs:18 determinism 'SystemTime' in a \
         determinism-scoped path (virtual clock / ordered maps only)",
    ];
    assert_eq!(got, want);
}

#[test]
fn panic_rule_fixture() {
    let text = fixture_text("panic_fixture.rs");
    let got =
        renders(&scan_rust_file("rust/src/coordinator/protocol.rs", &text));
    let want = vec![
        "rust/src/coordinator/protocol.rs:2 panic-path '.unwrap()' in a \
         serving path (degrade to an error reply instead)",
        "rust/src/coordinator/protocol.rs:3 panic-path '.expect(' in a \
         serving path (degrade to an error reply instead)",
        "rust/src/coordinator/protocol.rs:4 panic-path direct indexing \
         in a serving path (use .get())",
        "rust/src/coordinator/protocol.rs:6 panic-path 'panic!(' in a \
         serving path (degrade to an error reply instead)",
        "rust/src/coordinator/protocol.rs:8 panic-path 'unreachable!(' \
         in a serving path (degrade to an error reply instead)",
    ];
    assert_eq!(got, want);
}

#[test]
fn ordering_rule_fixture() {
    // Lines 7/8 are justified by same-line / previous-line comments and
    // line 14's Release by a multi-line comment block; only the bare
    // SeqCst (line 4) and Acquire (line 10) fire.
    let text = fixture_text("ordering_fixture.rs");
    let got =
        renders(&scan_rust_file("rust/src/coordinator/router.rs", &text));
    let want = vec![
        "rust/src/coordinator/router.rs:4 atomic-ordering \
         'Ordering::SeqCst' without an '// ordering:' justification \
         comment",
        "rust/src/coordinator/router.rs:10 atomic-ordering \
         'Ordering::Acquire' without an '// ordering:' justification \
         comment",
    ];
    assert_eq!(got, want);
}

#[test]
fn rules_only_fire_in_scope() {
    for name in
        ["det_fixture.rs", "panic_fixture.rs", "ordering_fixture.rs"]
    {
        let text = fixture_text(name);
        let got = scan_rust_file("rust/src/runtime/manifest.rs", &text);
        assert!(
            got.is_empty(),
            "{name} produced {} findings out of scope",
            got.len()
        );
    }
}

// ------------------------------------------------------------ ABI drift

#[test]
fn abi_ok_tree_is_clean() {
    let findings = d3lint::run(&fixtures().join("abi_ok"), None, None);
    assert_eq!(renders(&findings), Vec::<String>::new());
}

#[test]
fn renaming_a_python_entry_point_fails_with_file_line() {
    let findings =
        d3lint::run(&fixtures().join("abi_renamed_py"), None, None);
    let want = vec![
        "python/compile/aot.py:8 abi-drift EXEC_META key 'decode_step' \
         does not match any built entry point",
        "rust/src/model/exec.rs:5 abi-drift exec name 'decode_step' is \
         not built by python/compile/aot.py",
    ];
    assert_eq!(renders(&findings), want);
}

#[test]
fn renaming_a_rust_exec_ref_fails_with_file_line() {
    let findings =
        d3lint::run(&fixtures().join("abi_renamed_rs"), None, None);
    let want = vec![
        "rust/src/model/exec.rs:5 abi-drift exec name 'decode_stepx' is \
         not built by python/compile/aot.py",
    ];
    assert_eq!(renders(&findings), want);
}

#[test]
fn spec_json_overrides_scraped_names_and_version() {
    let json = "{\n  \"format_version\": 3,\n  \"entry_points\": [\n    \
                {\"name\": \"prefill_pallas\", \"model\": \"main\"},\n    \
                {\"name\": \"prefill_xla\"},\n    \
                {\"name\": \"trajectory\"},\n    \
                {\"name\": \"trajectory_paged\"}\n  ]\n}\n";
    let (names, fv) = abi::read_spec_json(json);
    assert_eq!(
        names,
        vec!["prefill_pallas", "prefill_xla", "trajectory",
             "trajectory_paged"]
    );
    assert_eq!(fv, Some(3));

    // against the ok tree the freshly-dumped specs are missing
    // decode_step and bump the format version: both must be reported
    let mut findings =
        abi::abi_check(&fixtures().join("abi_ok"), Some(names.as_slice()), fv);
    findings.sort();
    let got = renders(&findings);
    assert_eq!(
        got,
        vec![
            "python/compile/aot.py:7 abi-drift EXEC_META key \
             'decode_step' does not match any built entry point",
            "rust/src/model/exec.rs:5 abi-drift exec name 'decode_step' \
             is not built by python/compile/aot.py",
            "rust/src/runtime/manifest.rs:3 abi-drift manifest.rs \
             accepts format_version 1..=2 but python/compile emits 3",
        ]
    );
}

#[test]
fn exec_name_ref_grammar() {
    assert_eq!(
        abi::exec_name_ref("decode_step"),
        Some(("exact", "decode_step".to_string()))
    );
    assert_eq!(
        abi::exec_name_ref("trajectory"),
        Some(("exact", "trajectory".to_string()))
    );
    assert_eq!(
        abi::exec_name_ref("decode_paged_{variant}"),
        Some(("prefix", "decode_paged_".to_string()))
    );
    assert_eq!(
        abi::exec_name_ref("prefill_"),
        Some(("prefix", "prefill_".to_string()))
    );
    // not exec names: wrong charset, wrong prefix, bare single word
    assert_eq!(abi::exec_name_ref("decode_MS"), None);
    assert_eq!(abi::exec_name_ref("latency_ms"), None);
    assert_eq!(abi::exec_name_ref("decode"), None);
    assert_eq!(abi::exec_name_ref(""), None);
}

// ------------------------------------------------------------- baseline

#[test]
fn baseline_roundtrip_and_ratchet() {
    let text = fixture_text("panic_fixture.rs");
    let findings =
        scan_rust_file("rust/src/coordinator/protocol.rs", &text);
    let counts = baseline::counts_of(&findings);
    assert_eq!(
        counts.get(&(
            "rust/src/coordinator/protocol.rs".to_string(),
            "panic-path".to_string()
        )),
        Some(&5)
    );

    let tmp = std::env::temp_dir()
        .join(format!("d3lint-baseline-{}.toml", std::process::id()));
    baseline::write_baseline(&tmp, &counts).unwrap();
    let read = baseline::read_baseline(&tmp).unwrap();
    std::fs::remove_file(&tmp).unwrap();
    assert_eq!(read, counts);

    // identical counts: no drift
    assert!(baseline::check(&counts, &counts).is_empty());

    // one more finding than the baseline: a new violation
    let mut grown = counts.clone();
    for v in grown.values_mut() {
        *v += 1;
    }
    let drifts = baseline::check(&counts, &grown);
    assert_eq!(drifts.len(), 1);
    assert!(drifts[0].new_violation);
    assert_eq!(
        drifts[0].render(),
        "rust/src/coordinator/protocol.rs: 1 new 'panic-path' \
         violation(s) (baseline 5, current 6)"
    );

    // fewer findings than the baseline: stale baseline also drifts
    let drifts = baseline::check(&grown, &counts);
    assert_eq!(drifts.len(), 1);
    assert!(!drifts[0].new_violation);
    assert_eq!(
        drifts[0].render(),
        "rust/src/coordinator/protocol.rs: stale baseline for \
         'panic-path' (baseline 6, current 5) — shrink it"
    );

    // a fully fixed (file, rule) key must be deleted from the baseline
    let drifts = baseline::check(&counts, &baseline::Counts::new());
    assert_eq!(drifts.len(), 1);
    assert!(!drifts[0].new_violation);
}

/// The committed baseline must match the tree exactly — new violations
/// AND stale entries both fail, so every fix shrinks the baseline in the
/// same PR that lands it.
#[test]
fn repo_baseline_matches_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(3)
        .unwrap()
        .to_path_buf();
    let findings = d3lint::run(&root, None, None);
    let current = baseline::counts_of(&findings);
    let committed =
        baseline::read_baseline(&root.join("lint-baseline.toml"))
            .expect("lint-baseline.toml is committed at the repo root");
    let drifts = baseline::check(&committed, &current);
    let report: Vec<String> =
        drifts.iter().map(|d| d.render()).collect();
    assert!(
        drifts.is_empty(),
        "lint-baseline.toml does not match the tree:\n{}",
        report.join("\n")
    );
}
